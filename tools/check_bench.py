#!/usr/bin/env python
"""CI perf-regression gate (stdlib-only).

Compares a ``benchmarks.run`` CSV (the bench-smoke job's output) against
the committed ``BENCH_<suite>.json`` baselines in the repo root. For each
baselined suite:

  * rows are matched by name between baseline and CSV (``us <= 0`` rows
    are informational — cache stats, speedup summaries — and skipped);
  * the gate metric is the MEDIAN of per-row ratios ``csv_us / base_us``
    (robust to one noisy row, scale-free across row magnitudes);
  * the gate fails with exit code 1 when the median ratio exceeds
    ``1 + threshold`` (default 0.30: a >30% median slowdown);
  * a baseline may additionally name ``gate_rows``: rows gated
    INDIVIDUALLY at the same threshold, for SLO-style metrics (a p99
    latency row) where a regression must not hide behind a healthy
    median. A gated row absent from the fresh CSV is a coverage failure
    (exit 3) even when enough other rows matched;
  * it fails with the distinct exit code 3 when a baselined suite is
    missing from the CSV or fewer than half its baseline rows matched —
    a renamed/dropped suite is a *coverage* failure, not a perf
    regression, and needs a baseline refresh (or the rename reverted),
    not an optimization hunt. When both failures occur in one run, the
    regression verdict (exit 1) wins; all failures are printed either way.

Baselines are absolute wall times, so they are only comparable on the
machine class that recorded them — refresh them from the runner class
that enforces them (README "Benchmark baselines"):

  PYTHONPATH=src python -m benchmarks.run --tiny | tee bench.csv
  python tools/check_bench.py --csv bench.csv --update throughput

Usage:
  python tools/check_bench.py --csv bench-smoke.csv               # gate
  python tools/check_bench.py --csv b.csv --update suite[,suite]  # refresh
  python tools/check_bench.py --csv b.csv --update-all            # all suites
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys
from pathlib import Path

DEFAULT_THRESHOLD = 0.30
EXIT_REGRESSED = 1
EXIT_MISSING_SUITE = 3  # baselined suite/rows absent from the fresh run


def parse_csv(path: Path):
    """CSV -> {suite: {row_name: us}}. Suites come from the ``# --- name
    ---`` markers ``benchmarks.run`` prints before each suite."""
    suites, current = {}, None
    for line in path.read_text().splitlines():
        line = line.strip()
        if line.startswith("# ---") and line.endswith("---"):
            current = line.strip("# -").strip()
            suites.setdefault(current, {})
            continue
        if not line or line.startswith("#") or line.startswith("name,"):
            continue
        parts = line.split(",", 2)
        if len(parts) < 2 or current is None:
            continue
        try:
            us = float(parts[1])
        except ValueError:
            continue
        if us > 0:  # us <= 0 marks informational rows
            suites[current][parts[0]] = us
    return suites


def load_baselines(root: Path):
    """{suite: (path, rows, gate_rows)} for every BENCH_*.json in the repo
    root. ``gate_rows`` (optional in the JSON) lists row names gated
    individually in addition to the suite median."""
    out = {}
    for f in sorted(root.glob("BENCH_*.json")):
        data = json.loads(f.read_text())
        out[data["suite"]] = (f, data["rows"], data.get("gate_rows", []))
    return out


def check(suites, baselines, threshold: float) -> int:
    if not baselines:
        print("check_bench: no BENCH_*.json baselines committed; "
              "nothing to gate", file=sys.stderr)
        return 0
    regressions, missing = [], []
    for suite, (path, base_rows, gate_rows) in baselines.items():
        if suite not in suites:
            missing.append(
                f"{suite}: baselined suite missing from the CSV — was it "
                f"renamed or dropped from benchmarks/run.py? Either revert "
                f"the rename, or re-record with "
                f"`check_bench.py --csv <csv> --update {suite}` and delete "
                f"the stale {path.name}")
            continue
        csv_rows = suites[suite]
        shared = sorted(set(base_rows) & set(csv_rows))
        if len(shared) * 2 < len(base_rows):
            missing.append(
                f"{suite}: only {len(shared)}/{len(base_rows)} baseline rows "
                f"present in the CSV — renamed rows? refresh {path.name} "
                f"with `check_bench.py --csv <csv> --update {suite}`")
            continue
        ratios = [csv_rows[r] / base_rows[r] for r in shared
                  if base_rows[r] > 0]
        med = statistics.median(ratios)
        status = "ok" if med <= 1 + threshold else "REGRESSED"
        print(f"check_bench: {suite}: median ratio {med:.3f} over "
              f"{len(ratios)} rows (threshold {1 + threshold:.2f}) {status}")
        if med > 1 + threshold:
            worst = sorted(shared, key=lambda r: csv_rows[r] / base_rows[r],
                           reverse=True)[:5]
            detail = "; ".join(
                f"{r} {base_rows[r]:.0f}->{csv_rows[r]:.0f}us" for r in worst)
            regressions.append(f"{suite}: median ratio {med:.3f} > "
                               f"{1 + threshold:.2f} (worst: {detail})")
        # SLO rows: gated one-by-one — a p99 blowup must not hide behind
        # a healthy median over the other rows.
        for r in gate_rows:
            if r not in base_rows or base_rows[r] <= 0:
                continue  # stale gate entry; the update path prunes these
            if r not in csv_rows:
                missing.append(
                    f"{suite}: gated row {r!r} missing from the CSV — "
                    f"renamed? refresh {path.name} with "
                    f"`check_bench.py --csv <csv> --update {suite}`")
                continue
            ratio = csv_rows[r] / base_rows[r]
            status = "ok" if ratio <= 1 + threshold else "REGRESSED"
            print(f"check_bench: {suite}: gated row {r}: ratio {ratio:.3f} "
                  f"({base_rows[r]:.0f}->{csv_rows[r]:.0f}us) {status}")
            if ratio > 1 + threshold:
                regressions.append(
                    f"{suite}: gated row {r} ratio {ratio:.3f} > "
                    f"{1 + threshold:.2f} "
                    f"({base_rows[r]:.0f}->{csv_rows[r]:.0f}us)")
    if regressions or missing:
        print("check_bench: FAILED", file=sys.stderr)
        for f in regressions + missing:
            print(f"  {f}", file=sys.stderr)
        # Coverage failures (missing suites/rows) get their own exit code so
        # CI and humans can tell "slower" from "not measured at all" — but a
        # confirmed regression is the more severe verdict and wins when both
        # occur (otherwise the exit-3 "refresh baselines" playbook would
        # bake the regressed numbers into the new baseline).
        return EXIT_REGRESSED if regressions else EXIT_MISSING_SUITE
    return 0


def update(suites, names, root: Path) -> int:
    missing = [n for n in names if n not in suites]
    if missing:
        print(f"check_bench: --update suites not in the CSV: {missing} "
              f"(available: {sorted(suites)})", file=sys.stderr)
        return 2
    for name in names:
        path = root / f"BENCH_{name}.json"
        rows = suites[name]
        # Refreshing a baseline keeps its SLO row gates (pruned to rows
        # that still exist); a brand-new baseline auto-gates p99 rows.
        if path.is_file():
            prev = json.loads(path.read_text()).get("gate_rows", [])
            gate_rows = [r for r in prev if r in rows]
        else:
            gate_rows = sorted(r for r in rows if "p99" in r)
        data = {"suite": name, "rows": rows}
        if gate_rows:
            data["gate_rows"] = gate_rows
        path.write_text(json.dumps(data, indent=2, sort_keys=True) + "\n")
        gated = f", {len(gate_rows)} gated" if gate_rows else ""
        print(f"check_bench: wrote {path} ({len(rows)} rows{gated})")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--csv", required=True, type=Path,
                    help="benchmarks.run output to gate / take baselines from")
    ap.add_argument("--baseline-dir", type=Path,
                    default=Path(__file__).resolve().parents[1],
                    help="where BENCH_*.json live (default: repo root)")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="allowed fractional median slowdown (default 0.30)")
    ap.add_argument("--update", default=None,
                    help="comma-separated suites: write BENCH_<suite>.json "
                         "from the CSV instead of gating")
    ap.add_argument("--update-all", action="store_true",
                    help="write baselines for every suite in the CSV")
    args = ap.parse_args()
    if not args.csv.is_file():
        print(f"check_bench: no such CSV: {args.csv}", file=sys.stderr)
        return 2
    suites = parse_csv(args.csv)
    if args.update_all:
        return update(suites, sorted(n for n, r in suites.items() if r),
                      args.baseline_dir)
    if args.update:
        return update(suites, [n for n in args.update.split(",") if n],
                      args.baseline_dir)
    return check(suites, load_baselines(args.baseline_dir), args.threshold)


if __name__ == "__main__":
    sys.exit(main())
