#!/usr/bin/env python
"""Lint: no raw ``REPRO_*`` environment reads outside ``repro/config.py``.

The ``KernelPolicy`` consolidation (DESIGN.md §14) made
``src/repro/config.py`` the single module allowed to read the engine's
``REPRO_*`` environment variables — everywhere else resolves behavior
through ``config.current_policy()`` / ``config.bench_tiny()``, so an
``override(...)`` context or a per-call ``policy=`` can never be bypassed
by a stray env read. This lint keeps it that way: any line outside
config.py where ``os.environ``/``os.getenv`` co-occurs with a ``REPRO_``
variable name fails the build.

Setting (``monkeypatch.setenv``, ``os.environ["REPRO_..."] = ...`` in
tests/CI) is fine — only *reads* route through config; but rather than
parse access direction, the lint flags any same-line co-occurrence and
tests use ``config.override(...)`` or env-set helpers instead.

Usage:  python tools/check_env.py [repo_root]
Exit 1 and list offending lines on violation.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

_SCAN_DIRS = ("src", "benchmarks", "examples", "tests", "tools")
_ALLOWED = ("src/repro/config.py", "tools/check_env.py")
_READ = re.compile(r"os\.(?:environ|getenv)")
_VAR = re.compile(r"REPRO_[A-Z_]*")


def violations(root: Path):
    out = []
    for d in _SCAN_DIRS:
        base = root / d
        if not base.is_dir():
            continue
        for path in sorted(base.rglob("*.py")):
            rel = path.relative_to(root).as_posix()
            if rel in _ALLOWED:
                continue
            for i, line in enumerate(
                    path.read_text().splitlines(), start=1):
                if _READ.search(line) and _VAR.search(line):
                    out.append((rel, i, line.strip()))
    return out


def main(root: str = ".") -> int:
    bad = violations(Path(root).resolve())
    if bad:
        print("check_env: raw REPRO_* env reads outside repro/config.py "
              "(route through config.current_policy / bench_tiny):",
              file=sys.stderr)
        for rel, i, line in bad:
            print(f"  {rel}:{i}: {line}", file=sys.stderr)
        return 1
    print("check_env: no raw REPRO_* env reads outside config.py ok")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1] if len(sys.argv) > 1 else "."))
