#!/usr/bin/env python
"""Lint: every section citation of DESIGN.md in the source tree must
resolve to a section heading in DESIGN.md.

A citation is any ``§<token>`` on a line that mentions DESIGN.md (so
"DESIGN.md §3/§4" yields two citations, §3 and §4). A section is declared
by a markdown heading containing ``§<token>``. Exit 1 and list dangling
citations otherwise.

Usage:  python tools/check_docs.py [repo_root]
Also run as part of the tier-1 suite via tests/test_docs.py.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

# Trailing dots are sentence punctuation, not part of the section token.
_CITE = re.compile(r"§([A-Za-z0-9][A-Za-z0-9.-]*?)(?=[^A-Za-z0-9.-]|$)")
_SCAN_DIRS = ("src", "benchmarks", "examples", "tests", "tools")


def _tokens(line: str):
    for m in _CITE.finditer(line):
        yield m.group(1).rstrip(".-")


def collect_citations(root: Path):
    """(file, lineno, token) for every DESIGN.md § citation under root."""
    out = []
    for d in _SCAN_DIRS:
        base = root / d
        if not base.is_dir():
            continue
        for path in sorted(base.rglob("*.py")):
            for i, line in enumerate(path.read_text().splitlines(), 1):
                if "DESIGN.md" not in line:
                    continue
                for tok in _tokens(line):
                    out.append((path.relative_to(root), i, tok))
    return out


def collect_sections(design: Path):
    sections = set()
    for line in design.read_text().splitlines():
        if line.lstrip().startswith("#"):
            sections.update(_tokens(line))
    return sections


def main(root: str = ".") -> int:
    rootp = Path(root).resolve()
    design = rootp / "DESIGN.md"
    if not design.is_file():
        print(f"check_docs: {design} does not exist", file=sys.stderr)
        return 1
    sections = collect_sections(design)
    cites = collect_citations(rootp)
    dangling = [(f, i, t) for f, i, t in cites if t not in sections]
    if dangling:
        print("check_docs: dangling DESIGN.md citations:", file=sys.stderr)
        for f, i, t in dangling:
            print(f"  {f}:{i}: DESIGN.md §{t} (no such section)",
                  file=sys.stderr)
        print(f"  declared sections: {sorted(sections)}", file=sys.stderr)
        return 1
    print(f"check_docs: OK — {len(cites)} citations over "
          f"{len(sections)} sections")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1] if len(sys.argv) > 1 else "."))
