#!/usr/bin/env python
"""CI public-API drift gate (mirrors tools/check_bench.py's verdicts).

Renders the public surface of ``repro.engine``, ``repro.data`` and
``repro.core`` — every ``__all__`` export plus ``inspect.signature``
strings for callables and per-class public methods/properties — and
compares it against the committed ``API.md`` snapshot:

  * any mismatch (a renamed export, a changed signature, a new public
    method) fails with exit code 1 and prints a unified diff — an API
    change must land TOGETHER with its regenerated snapshot, so review
    sees the surface change explicitly;
  * a missing ``API.md`` fails with the distinct exit code 3 (coverage
    loss, not drift — same taxonomy as check_bench);
  * ``--update`` regenerates the snapshot in place.

Unlike check_bench this tool imports the live modules (it needs jax), so
CI runs it in the test job after dependencies are installed:

  PYTHONPATH=src python tools/check_api.py            # gate
  PYTHONPATH=src python tools/check_api.py --update   # refresh API.md
"""
from __future__ import annotations

import argparse
import difflib
import importlib
import inspect
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
if str(ROOT / "src") not in sys.path:  # usable without PYTHONPATH=src
    sys.path.insert(0, str(ROOT / "src"))

MODULES = ("repro.engine", "repro.data", "repro.core", "repro.config")
DEFAULT_BASELINE = ROOT / "API.md"
EXIT_DRIFT = 1
EXIT_MISSING_BASELINE = 3  # no snapshot committed at all

# Default values whose repr embeds an object address would make the
# snapshot nondeterministic; scrub them.
_ADDR = re.compile(r" at 0x[0-9a-fA-F]+")

HEADER = """\
# Public API surface

Snapshot of the public exports (`__all__`) of `repro.engine`,
`repro.data` and `repro.core`, with signatures for callables and the
public methods/properties defined on each exported class. CI re-renders
this from the live modules and fails on any difference
(`tools/check_api.py`), so an API change must land together with its
regenerated snapshot. Refresh with:

    PYTHONPATH=src python tools/check_api.py --update

Generated file — do not edit by hand.
"""


def _sig(obj) -> str:
    try:
        s = str(inspect.signature(obj))
    except (ValueError, TypeError):
        return "(...)"  # builtins / C-level callables without signatures
    return _ADDR.sub("", s)


def _describe(name: str, obj) -> list[str]:
    """Render one export. Classes list their OWN public methods and
    properties (``vars(cls)``, not inherited ones) so a facade class
    growing a method shows up as drift without dragging in base-class
    noise."""
    if inspect.ismodule(obj):
        return [f"module {name}"]
    if inspect.isclass(obj):
        lines = [f"class {name}{_sig(obj)}"]
        for attr, raw in sorted(vars(obj).items()):
            if attr.startswith("_"):
                continue
            if isinstance(raw, property):
                lines.append(f"    property {attr}")
            elif isinstance(raw, staticmethod):
                lines.append(f"    staticmethod {attr}{_sig(raw.__func__)}")
            elif isinstance(raw, classmethod):
                lines.append(f"    classmethod {attr}{_sig(raw.__func__)}")
            elif callable(raw):
                lines.append(f"    def {attr}{_sig(raw)}")
        return lines
    if callable(obj):
        return [f"def {name}{_sig(obj)}"]
    return [f"{name}: {type(obj).__name__}"]


def render() -> str:
    """The full snapshot text, deterministically ordered."""
    parts = [HEADER]
    for modname in MODULES:
        mod = importlib.import_module(modname)
        exported = getattr(mod, "__all__", None)
        if exported is None:
            print(f"check_api: {modname} defines no __all__ — the public "
                  f"surface must be explicit to be snapshottable",
                  file=sys.stderr)
            raise SystemExit(2)
        body: list[str] = []
        for name in sorted(exported):
            body.extend(_describe(name, getattr(mod, name)))
        parts.append(f"\n## {modname}\n\n```text\n" + "\n".join(body)
                     + "\n```\n")
    return "".join(parts)


def check(baseline: Path) -> int:
    if not baseline.is_file():
        print(f"check_api: no snapshot at {baseline} — record one with "
              f"`PYTHONPATH=src python tools/check_api.py --update`",
              file=sys.stderr)
        return EXIT_MISSING_BASELINE
    live = render()
    committed = baseline.read_text()
    if live == committed:
        n = sum(1 for ln in live.splitlines()
                if ln.startswith(("class ", "def ", "module ")))
        print(f"check_api: {baseline.name} matches the live surface "
              f"({n} top-level exports across {len(MODULES)} modules) ok")
        return 0
    diff = difflib.unified_diff(
        committed.splitlines(keepends=True), live.splitlines(keepends=True),
        fromfile=f"{baseline.name} (committed)", tofile="live surface")
    print("check_api: FAILED — public API drifted from the committed "
          "snapshot", file=sys.stderr)
    sys.stderr.writelines(diff)
    print("check_api: if the change is intentional, refresh with "
          "`PYTHONPATH=src python tools/check_api.py --update` and commit "
          "the new API.md", file=sys.stderr)
    return EXIT_DRIFT


def update(baseline: Path) -> int:
    baseline.write_text(render())
    print(f"check_api: wrote {baseline}")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", type=Path, default=DEFAULT_BASELINE,
                    help="snapshot path (default: repo-root API.md)")
    ap.add_argument("--update", action="store_true",
                    help="regenerate the snapshot instead of gating")
    args = ap.parse_args()
    return update(args.baseline) if args.update else check(args.baseline)


if __name__ == "__main__":
    sys.exit(main())
