"""Training data plane: engine-batched source vs the legacy per-step loop.

The pre-§13 data pipeline drew ONE ``engine.sample`` per training step and
gathered token rows on the host — a host dispatch plus a device->host
count sync per step, which dominates once the plan cache is warm (the
same dispatch-bound regime as bench_throughput's serving rows).
``data.PoissonJoinSource`` replaces it with one ``sample_batch`` dispatch
per ``window`` steps and a jitted on-device gather (DESIGN.md §13), so
the per-step cost is the amortized window dispatch.

Rows (per-step microseconds, batch held constant across sizes so row
names are stable for the baseline):

  pipeline/legacy-per-step   one sample + host gather per step
  pipeline/batched-per-step  windowed source, eager ring prefetch

The headline claim — batched >= 5x legacy in the dispatch-bound regime —
is reported as a derived speedup and enforced by the committed
``BENCH_pipeline.json`` baseline: ``pipeline/batched-per-step`` is listed
in ``gate_rows``, so tools/check_bench.py gates it individually and a
regression back toward per-step dispatch cannot hide behind the suite
median.
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from repro.data import PoissonJoinSource, make_corpus_db
from repro.engine import QueryEngine
from .timing import row, tiny

BATCH = 8
WINDOW = 32  # throughput-oriented window (the source default, 8, favors
             # latency; per-step cost is the window dispatch amortized)


def _median_us_per_step(consume, start: int, steps: int, reps: int) -> float:
    """Wall-time per step over ``reps`` disjoint step ranges (windows are
    consumed once; re-running the same steps would hit the ring)."""
    times = []
    cursor = start
    for _ in range(reps):
        t0 = time.perf_counter()
        consume(cursor, steps)
        times.append(time.perf_counter() - t0)
        cursor += steps
    times.sort()
    return times[len(times) // 2] / steps * 1e6


def run(out):
    steps = 32 if tiny() else 96
    seq = 33 if tiny() else 65
    db = make_corpus_db(512 if tiny() else 4096, 16 if tiny() else 64,
                        seq, 1000, seed=0)
    engine = QueryEngine(db)
    src = PoissonJoinSource(None, seq, BATCH, seed=0, engine=engine,
                            window=WINDOW)

    # -- legacy per-step loop: sample, sync the count, gather on host ------
    key = jax.random.key(0)
    tokens_np = np.asarray(
        engine.db.relations["_tokens"].column("flat")).reshape(-1, seq)

    def legacy(s0, n):
        for s in range(s0, s0 + n):
            smp = engine.sample(src.query, jax.random.fold_in(key, s),
                                cap=src.cap)
            k = max(int(smp.count), 1)           # host sync per step
            docs = np.asarray(smp.columns["doc"])[:k]
            sel = docs[np.arange(BATCH) % k]
            toks = tokens_np[sel].astype(np.int32)
            _ = toks[:, :-1], toks[:, 1:]

    legacy(0, 2)  # warm the single-draw trace
    us_legacy = _median_us_per_step(legacy, 2, steps, reps=3)
    out(row("pipeline/legacy-per-step", us_legacy,
            f"steps_per_s={1e6 / us_legacy:.0f};batch={BATCH}"))

    # -- engine-batched source: one dispatch per window, device gather -----
    def batched(s0, n):
        last = None
        for s in range(s0, s0 + n):
            last = src.batch_at(s)
        jax.block_until_ready(last["tokens"])

    batched(0, WINDOW)  # warm: batched trace + gather jit + ring fill
    us_batched = _median_us_per_step(batched, WINDOW, steps, reps=3)
    speedup = us_legacy / us_batched
    out(row("pipeline/batched-per-step", us_batched,
            f"steps_per_s={1e6 / us_batched:.0f};window={WINDOW};"
            f"vs_legacy={speedup:.1f}x"))
    out(row("pipeline/speedup-vs-legacy", 0.0,
            f"batched/legacy={speedup:.1f}x"))
    if speedup < 5.0:
        # Enforcement lives in tools/check_bench.py against the committed
        # baseline (robust to one noisy run); this is the loud local hint.
        print(f"# pipeline: batched source only {speedup:.2f}x the legacy "
              "per-step loop (expected >= 5x dispatch-bound)",
              file=sys.stderr)
