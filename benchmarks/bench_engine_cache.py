"""Engine compiled-plan cache: cold vs warm latency (DESIGN.md §7).

Measures what the ``QueryEngine`` cache actually buys on the serving path:

  cold  — first call on a query fingerprint: GYO + shred build + jit trace
          + dispatch (everything a naive per-request executor pays);
  warm  — same query again: dict lookup + cached-trace dispatch;
  rebuild — the no-cache baseline: a fresh engine per request.

Reported per workload for both entry points (poisson_sample / full_join).
The cold/warm ratio is the multi-tenant serving argument: with Q query
shapes and R >> Q requests, total work is Q colds + (R - Q) warms.
"""
from __future__ import annotations

import time

import jax

from repro.engine import QueryEngine
from .timing import row, time_fn, tiny
from .workloads import job_like, stats_like


def _once(fn) -> float:
    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    return (time.perf_counter() - t0) * 1e6


def run(out):
    s1, s2 = (120, 150) if tiny() else (1200, 1500)
    for name, (db, q) in (("job_like", job_like(scale=s1)),
                          ("stats_like", stats_like(scale=s2))):
        key = jax.random.key(0)

        engine = QueryEngine(db)
        us_cold = _once(lambda: engine.poisson_sample(q, key).positions)
        us_warm = time_fn(lambda: engine.poisson_sample(q, key), reps=5)
        us_rebuild = time_fn(
            lambda: QueryEngine(db).poisson_sample(q, key), reps=3)
        out(row(f"engine/{name}/sample-cold", us_cold))
        out(row(f"engine/{name}/sample-warm", us_warm,
                f"cold/warm={us_cold/us_warm:.1f}x"))
        out(row(f"engine/{name}/sample-rebuild", us_rebuild,
                f"rebuild/warm={us_rebuild/us_warm:.1f}x"))

        engine2 = QueryEngine(db)
        us_fj_cold = _once(lambda: next(iter(engine2.full_join(q).values())))
        us_fj_warm = time_fn(lambda: engine2.full_join(q), reps=5)
        out(row(f"engine/{name}/fulljoin-cold", us_fj_cold))
        out(row(f"engine/{name}/fulljoin-warm", us_fj_warm,
                f"cold/warm={us_fj_cold/us_fj_warm:.1f}x"))

        st = engine.stats
        out(row(f"engine/{name}/cache-stats", 0.0,
                f"builds={st.shred_builds};hits={st.plan_hits};"
                f"misses={st.plan_misses}"))
