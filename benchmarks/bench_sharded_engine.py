"""Sharded engine: 1-vs-N device throughput and cache behavior (DESIGN.md §8).

Measures what the sharded plan path costs and buys on one host:

  cold    — first sharded call: semijoin pre-filter + N per-shard index
            builds + shard_map trace (the sharded analogue of the engine's
            cold path);
  warm    — same (fingerprint, mesh) again: dict lookup + cached dispatch,
            zero stacked-shred rebuilds (asserted via CacheStats);
  1-vs-N  — warm single-device vs warm sharded sample/full-join latency.

On CPU the N "devices" are virtual (one physical socket), so the 1-vs-N
ratio here measures sharding *overhead*, not speedup; on a real mesh the
same plan path is the paper's multi-pod scaling argument. Run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for a real stacked
path; on one device the suite still exercises it via explicit axes.
"""
from __future__ import annotations

import time

import jax

from repro.engine import QueryEngine, ShardedPlan
from .timing import row, time_fn, tiny
from .workloads import qc_workload


def _once(fn) -> float:
    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    return (time.perf_counter() - t0) * 1e6


def run(out):
    devices = len(jax.devices())
    mesh = jax.make_mesh((devices,), ("data",))
    db, q = qc_workload(n_persons=500 if tiny() else 4000,
                        n_pools=12 if tiny() else 80)
    key = jax.random.key(0)

    engine = QueryEngine(db)
    us_1_cold = _once(lambda: engine.sample(q, key).positions)
    us_1_warm = time_fn(lambda: engine.sample(q, key), reps=5)
    out(row("sharded/sample-1dev-cold", us_1_cold))
    out(row("sharded/sample-1dev-warm", us_1_warm))

    # Explicit axes force the stacked path even on a single device (labels
    # say "Nshard" so they never collide with the 1dev baseline rows).
    smesh = dict(mesh=mesh, axes=("data",))
    before = engine.stats.snapshot()
    us_n_cold = _once(lambda: engine.sample(q, key, **smesh).positions)
    us_n_warm = time_fn(lambda: engine.sample(q, key, **smesh), reps=5)
    plan = engine.compile_sharded(q, mesh, axes=("data",))
    assert isinstance(plan, ShardedPlan)
    rebuilt = engine.stats.shred_builds - before.shred_builds
    assert rebuilt == 1, \
        f"warm sharded calls rebuilt the stacked shred ({rebuilt - 1}x)"
    out(row(f"sharded/sample-{plan.num_shards}shard-cold", us_n_cold,
            f"devices={devices}"))
    out(row(f"sharded/sample-{plan.num_shards}shard-warm", us_n_warm,
            f"1dev/sharded={us_1_warm/us_n_warm:.2f}x"))
    out(row("sharded/sample-warm-rebuilds", 0.0,
            f"builds_after_cold={rebuilt - 1}"))  # cold pays exactly one

    us_fj_1 = time_fn(lambda: engine.full_join(q), reps=3)
    us_fj_n = time_fn(lambda: engine.full_join(q, **smesh), reps=3)
    out(row("sharded/fulljoin-1dev-warm", us_fj_1))
    out(row(f"sharded/fulljoin-{plan.num_shards}shard-warm", us_fj_n,
            f"1dev/sharded={us_fj_1/us_fj_n:.2f}x"))

    st = engine.stats
    out(row("sharded/cache-stats", 0.0,
            f"devices={devices};builds={st.shred_builds};"
            f"hits={st.shred_hits};plan_hits={st.plan_hits}"))
