"""Synthetic benchmark workloads mirroring the paper's three suites.

The paper's JOB / STATS-CEB inputs are real-world datasets we cannot ship
offline; these generators reproduce their *shape characteristics* used in
the paper's analysis (Table 1): JOB-like = small join outputs (1e2..1e6,
median ~4e2), star joins around a central Title-like relation carrying the
probability attribute; STATS-like = larger outputs (up to 1e8 here), deeper
chains with skewed degrees; Q_c = the EpiQL contact query on a synthetic
population with ContactProb from a Beta distribution (avg p ~= 2.4% like the
paper's diary-study data).
"""
from __future__ import annotations

import numpy as np

from repro.core import Atom, Database, JoinQuery

__all__ = ["job_like", "stats_like", "qc_workload", "degree_sweep_workload",
           "PROB_DISTS"]

# the paper's low / medium / high probability distributions (§6)
PROB_DISTS = {
    "low": lambda rng, n: rng.beta(2, 10, n),        # E~0.167
    "medium": lambda rng, n: np.clip(rng.normal(0.5, 0.2, n), 0, 1),
    "high": lambda rng, n: rng.beta(10, 2, n),       # E~0.833
}


def job_like(seed: int = 0, scale: int = 2000, dist: str = "low"):
    """Star join: Title |><| Cast |><| Companies, probability on Title."""
    rng = np.random.default_rng(seed)
    n_t = scale
    n_c = scale * 4
    n_m = scale * 2
    db = Database.from_columns({
        "Title": {"t": np.arange(n_t), "kind": rng.integers(0, 7, n_t),
                  "p": PROB_DISTS[dist](rng, n_t)},
        "Cast": {"t": rng.choice(n_t, n_c, replace=True),
                 "person": rng.integers(0, scale * 2, n_c)},
        "Comp": {"t": rng.choice(n_t, n_m, replace=True),
                 "comp": rng.integers(0, 50, n_m)},
    })
    q = JoinQuery((Atom.of("Title", "t", "kind", "p"),
                   Atom.of("Cast", "t", "person"),
                   Atom.of("Comp", "t", "comp")), prob_var="p")
    return db, q


def stats_like(seed: int = 0, scale: int = 4000, dist: str = "low"):
    """Chain with skew: Users |><| Posts |><| Votes (Zipf-ish degrees)."""
    rng = np.random.default_rng(seed)
    n_u = scale
    n_p = scale * 3
    n_v = scale * 8
    upop = rng.zipf(1.6, n_p) % n_u
    ppop = rng.zipf(1.4, n_v) % n_p
    db = Database.from_columns({
        "Users": {"u": np.arange(n_u), "rep": rng.integers(0, 100, n_u),
                  "p": PROB_DISTS[dist](rng, n_u)},
        "Posts": {"post": np.arange(n_p), "u": upop},
        "Votes": {"post": ppop, "vtype": rng.integers(0, 5, n_v)},
    })
    q = JoinQuery((Atom.of("Users", "u", "rep", "p"),
                   Atom.of("Posts", "post", "u"),
                   Atom.of("Votes", "post", "vtype")), prob_var="p")
    return db, q


def qc_workload(seed: int = 0, n_persons: int = 2000, n_pools: int = 60,
                n_ages: int = 6, mean_p: float = 0.024):
    """The paper's Q_c (Example 1.1/2.1): Person self-join x ContactProb,
    avg contact probability ~2.4% as measured on the Belgian diary data."""
    rng = np.random.default_rng(seed)
    grid = [(g, a1, a2) for g in range(n_pools) for a1 in range(n_ages)
            for a2 in range(n_ages)]
    probs = np.clip(rng.gamma(2.0, mean_p / 2.0, len(grid)), 0, 1)
    db = Database.from_columns({
        "Person": {"pers": np.arange(n_persons),
                   "age": rng.integers(0, n_ages, n_persons),
                   "pool": rng.integers(0, n_pools, n_persons)},
        "ContactProb": {"pool": [g for g, _, _ in grid],
                        "age1": [a for _, a, _ in grid],
                        "age2": [a for _, _, a in grid],
                        "prob": probs},
    })
    q = JoinQuery((
        Atom.of("ContactProb", "pool", "age1", "age2", "prob"),
        Atom.of("Person", "per1", "age1", "pool", alias="P1"),
        Atom.of("Person", "per2", "age2", "pool", alias="P2"),
    ), prob_var="prob")
    return db, q


def degree_sweep_workload(seed: int, out_size: int, degree: int):
    """§6.3 synthetic: beta_p(S(x,y) |><| T(y,z)) with |S|*deg = out_size,
    every S key matching exactly ``degree`` T rows, T randomly permuted."""
    rng = np.random.default_rng(seed)
    n_s = out_size // degree
    t_y = np.repeat(np.arange(n_s), degree)
    perm = rng.permutation(out_size)
    db = Database.from_columns({
        "S": {"x": np.arange(n_s), "y": np.arange(n_s),
              "p": np.full(n_s, 0.01)},
        "T": {"y": t_y[perm], "z": np.arange(out_size)[perm]},
    })
    q = JoinQuery((Atom.of("S", "x", "y", "p"), Atom.of("T", "y", "z")),
                  prob_var="p")
    return db, q
