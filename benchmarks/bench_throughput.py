"""Repeated-draw throughput: single-draw loop vs batched vs sharded-batched.

The paper's Monte-Carlo usage pattern — one index, an unbounded stream of
independent Poisson draws — is dominated by per-draw dispatch overhead
once the plan cache is warm. *Subset Sampling over Joins* (Esmailpour et
al.) frames exactly this repeated-draw throughput as the workload that
separates index-based samplers from per-trial baselines. This suite
measures draws/sec as a function of batch size for

  loop     — B sequential warm ``engine.sample`` dispatches (the
             pre-batching serving path);
  batched  — ONE ``engine.sample_batch`` dispatch (vmapped executor,
             DESIGN.md §10);
  sharded  — the sharded batched path (shard_map outside, vmap inside,
             one psum for the global counts) under explicit axes, so it
             exercises the stacked path on any device count.

Two workload regimes, reported separately because the batched win is
regime-dependent: ``small`` is dispatch-bound (the multi-tenant serving
regime — per-draw device work is microseconds, so batching amortizes the
~ms host dispatch and wins ~10x), ``large`` is compute-bound (per-draw
kernel work dominates; batching still wins but saturates toward the
hardware's throughput). The ``small`` rows carry the headline batched
>= 5x-over-loop claim.

This is the trajectory CI's perf-regression gate watches: bench-smoke
feeds its CSV to ``tools/check_bench.py``, which compares against the
committed ``BENCH_throughput.json`` baseline (refresh procedure in
README "Benchmark baselines").
"""
from __future__ import annotations

import sys

import jax

from repro.engine import QueryEngine
from .timing import row, time_fn, tiny
from .workloads import qc_workload

BATCHES = (8, 64, 256)


def _regime(out, name, db, q, batches, shard: bool):
    engine = QueryEngine(db)
    key = jax.random.key(0)
    # Warm the single-draw plan + trace before timing anything.
    jax.block_until_ready(engine.sample(q, key).positions)

    def loop(B):
        return [engine.sample(q, jax.random.fold_in(key, i)) for i in range(B)]

    us_loop = time_fn(lambda: loop(64), reps=3, warmup=1)
    out(row(f"throughput/{name}/loop-B64", us_loop,
            f"draws_per_s={64 / us_loop * 1e6:.0f}"))

    speedup64 = None
    for B in batches:
        keys = jax.random.split(key, B)
        us = time_fn(lambda: engine.sample_batch(q, keys), reps=5)
        derived = f"draws_per_s={B / us * 1e6:.0f}"
        if B == 64:
            speedup64 = us_loop / us
            derived += f";vs_loop={speedup64:.1f}x"
        out(row(f"throughput/{name}/batched-B{B}", us, derived))

    if shard:
        # Explicit axes force the stacked path even on one device (same
        # convention as bench_sharded_engine).
        mesh = jax.make_mesh((len(jax.devices()),), ("data",))
        keys = jax.random.split(key, 64)
        us = time_fn(lambda: engine.sample_batch(q, keys, mesh=mesh,
                                                 axes=("data",)), reps=3)
        out(row(f"throughput/{name}/sharded-batched-B64", us,
                f"draws_per_s={64 / us * 1e6:.0f};"
                f"devices={len(jax.devices())}"))
    return speedup64


def run(out):
    batches = (8, 64) if tiny() else BATCHES

    # Dispatch-bound serving regime: the headline batched-vs-loop claim
    # (>= 5x on CPU, typically 10-18x). Regression enforcement lives in
    # tools/check_bench.py (median over rows, robust to runner noise) —
    # a hard assert here would make a single noisy measurement fail CI.
    db, q = qc_workload(n_persons=200, n_pools=8)
    speedup = _regime(out, "small", db, q, batches, shard=True)
    out(row("throughput/small/speedup-B64", 0.0,
            f"batched/loop={speedup:.1f}x"))
    if speedup < 5.0:
        print(f"# throughput: batched B=64 only {speedup:.2f}x the "
              "single-draw loop (expected >= 5x on CPU)", file=sys.stderr)

    # Compute-bound regime: batching saturates toward kernel throughput.
    db, q = qc_workload(n_persons=400 if tiny() else 3000,
                        n_pools=10 if tiny() else 60)
    _regime(out, "large", db, q, batches, shard=not tiny())
