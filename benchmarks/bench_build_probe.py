"""Paper Table 3 + §6.3 synthetic degree sweep: CSR vs USR probe cost as the
maximum join degree d varies, at fixed output size.

Paper finding (CPU): CSR's linear chain walk beats USR's binary search at
low d (cache-resident chains), loses at high d. TPU adaptation finding
(DESIGN.md §3): the vmapped chain walk serializes lanes at high d while the
vectorized binary search stays flat — the crossover moves to d ~= 1, i.e.
USR is the right default on TPU. This benchmark measures exactly that.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import build_shred, get
from .timing import row, time_fn, tiny
from .workloads import degree_sweep_workload

OUT_SIZE = 1 << 16
DEGREES = (1, 4, 16, 64, 256, 1024)
K = 2048  # probes per GET


def run(out):
    out_size = (1 << 12) if tiny() else OUT_SIZE
    degrees = (1, 16, 256) if tiny() else DEGREES
    for d in degrees:
        db, q = degree_sweep_workload(0, out_size, d)
        shred = build_shred(db, q, rep="both")
        n = int(shred.join_size)
        pos = jax.random.randint(jax.random.key(1), (K,), 0, n).astype(jnp.int64)
        us_u = time_fn(jax.jit(lambda p: get(shred, p, rep="usr")), pos)
        us_c = time_fn(jax.jit(lambda p: get(shred, p, rep="csr")), pos)
        out(row(f"table3/probe-usr/d={d}", us_u, f"k={K};|Q|={n}"))
        out(row(f"table3/probe-csr/d={d}", us_c, f"csr/usr={us_c/us_u:.2f}x"))
        us_bu = time_fn(lambda: build_shred(db, q, rep="usr"), reps=3)
        us_bc = time_fn(lambda: build_shred(db, q, rep="csr"), reps=3)
        out(row(f"table3/build-usr/d={d}", us_bu))
        out(row(f"table3/build-csr/d={d}", us_bc))
