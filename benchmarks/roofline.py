"""§Roofline: aggregate the dry-run JSONs into the per-(arch x shape x mesh)
three-term roofline table (compute / memory / collective seconds, dominant
bottleneck, 6ND model-FLOPs ratio) and emit a markdown table for
EXPERIMENTS.md.

Usage: python -m benchmarks.roofline [--mesh 16x16] [--markdown out.md]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro import config

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load(mesh: str = None):
    recs = []
    for f in sorted(DRYRUN_DIR.glob("*.json")):
        if f.name.startswith("BASELINE_"):
            continue  # pre-§Perf snapshots live beside the finals
        r = json.loads(f.read_text())
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def fraction(r):
    """Roofline fraction: useful model FLOP-time over the dominant term.

    Peak FLOP/s comes from ``config.PEAK_FLOPS`` keyed by the record's
    ``backend`` field; records without one (every pre-§15 dry run) resolve
    to the TPU row — the historical 197e12 constant — so their committed
    ratios are unchanged."""
    if "roofline" not in r or "model_flops_per_device" not in r:
        return None
    dom = max(r["roofline"]["compute_s"], r["roofline"]["memory_s"],
              r["roofline"]["collective_s"])
    t_model = r["model_flops_per_device"] / config.peak_flops(
        r.get("backend", "tpu"))
    return t_model / dom if dom > 0 else None


def advice(r):
    dom = r["roofline"]["dominant"]
    if dom == "memory":
        return "cut HBM traffic: bf16 attention probs / fuse / larger arithmetic intensity per pass"
    if dom == "collective":
        return "cut comms: reduce-scatter grads, overlap TP psum with compute, shard KV differently"
    return "raise MFU: larger per-chip tiles, fewer remat passes"


def table(recs, out):
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':8s} {'comp_s':>9s} {'mem_s':>9s} "
           f"{'coll_s':>9s} {'dom':>10s} {'6ND/HLO':>8s} {'frac':>7s}")
    out(hdr)
    out("-" * len(hdr))
    for r in recs:
        if r.get("skipped"):
            out(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:8s} "
                f"{'SKIP':>9s}  ({r['skipped'][:60]}...)")
            continue
        ro = r["roofline"]
        ur = r.get("useful_flops_ratio") or 0
        fr = fraction(r) or 0
        out(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:8s} "
            f"{ro['compute_s']:9.3f} {ro['memory_s']:9.3f} {ro['collective_s']:9.3f} "
            f"{ro['dominant']:>10s} {ur:8.3f} {fr:7.3f}")


def markdown(recs) -> str:
    lines = ["| arch | shape | mesh | compute (s) | memory (s) | collective (s) "
             "| dominant | 6ND/HLO | roofline frac | next lever |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — "
                         f"| skipped | — | — | {r['skipped'][:70]} |")
            continue
        ro = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {ro['compute_s']:.3f} "
            f"| {ro['memory_s']:.3f} | {ro['collective_s']:.3f} | {ro['dominant']} "
            f"| {(r.get('useful_flops_ratio') or 0):.3f} | {(fraction(r) or 0):.3f} "
            f"| {advice(r)} |")
    return "\n".join(lines)


def run(out):
    recs = load(mesh="16x16")
    if not recs:
        out("roofline: no dry-run records found (run repro.launch.dryrun)")
        return
    table(recs, out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--markdown", default=None)
    args = ap.parse_args()
    recs = load(args.mesh)
    table(recs, print)
    if args.markdown:
        Path(args.markdown).write_text(markdown(recs))
        print(f"wrote {args.markdown}")


if __name__ == "__main__":
    main()
