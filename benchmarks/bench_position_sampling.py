"""Paper Fig. 7: position-sampling efficiency as a function of p.

Reproduced claim: GEO (O(np) work) beats BERN (O(n)) for small p; BERN wins
for large p; BINOM tracks GEO with higher constants; HYBRID takes the best
of both at the p=0.5 threshold. On TPU/JAX the crossover driver is memory
lanes touched, not branch prediction (DESIGN.md §3) — the qualitative
ordering is what transfers.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import sampling
from .timing import row, time_fn, tiny

N = 200_000
PS = (0.0001, 0.001, 0.01, 0.1, 0.3, 0.5, 0.7, 0.9)


def run(out):
    n = 10_000 if tiny() else N
    ps = (0.001, 0.1, 0.9) if tiny() else PS
    for p in ps:
        cap = int(min(max(n * p * 1.3 + 6 * (n * p) ** 0.5 + 256, 512), n + 1))
        fns = {
            "bern": jax.jit(partial(sampling.bern_positions, n=n, cap=cap)),
            "geo": jax.jit(partial(sampling.geo_positions, n=n, cap=cap)),
            "binom": jax.jit(partial(sampling.binom_positions, n=n, cap=cap)),
            "hybrid": jax.jit(partial(sampling.hybrid_positions, n=n, cap=cap)),
        }
        for name, fn in fns.items():
            us = time_fn(lambda k: fn(k, jnp.float64(p)), jax.random.key(0))
            out(row(f"fig7/{name}/p={p}", us, f"n={n};cap={cap}"))
