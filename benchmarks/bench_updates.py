"""Incremental index maintenance vs rebuild-the-world (DESIGN.md §11).

Measures what a ~1% delta costs along each maintenance path:

  reshred-incremental — ``shred.reshred_incremental``: merge the delta into
                        the existing sorted grouping (bit-identical result);
  full-rebuild        — ``build_shred`` on the post-delta snapshot (what the
                        incremental path replaces);
  engine-apply-delta  — the serving path: ``QueryEngine.apply_delta`` with a
                        warm plan cache (incremental reshred + in-place plan
                        upgrade, zero rebuilds);
  engine-rebind       — the pre-§11 alternative: ``rebind`` + recompile,
                        i.e. full invalidation per update.

The speedup row (informational, us <= 0) is the headline: incremental
reshred must beat the full rebuild by >= 5x at |delta|/N <= 1% at the
default sizes.
"""
from __future__ import annotations

import numpy as np
import jax

from repro.core import build_shred
from repro.core.delta import DeltaBatch
from repro.core.shred import reshred_incremental
from repro.engine import QueryEngine

from .timing import row, time_fn, tiny
from .workloads import job_like, stats_like


def _churn_delta(db, relation: str, frac: float, seed: int = 0) -> DeltaBatch:
    """A shape-preserving ~``2*frac`` churn of one relation: ``frac`` of its
    rows deleted, as many re-inserted (values resampled from the relation
    itself, so join keys stay in-distribution)."""
    rng = np.random.default_rng(seed)
    n = db.relations[relation].num_rows
    k = max(1, int(frac * n))
    cols = {c: np.asarray(v)[rng.integers(0, n, k)]
            for c, v in db.relations[relation].columns.items()}
    return DeltaBatch.of(**{relation: {
        "insert": cols, "delete": rng.choice(n, k, replace=False)}})


def run(out):
    s1, s2 = (120, 150) if tiny() else (8000, 10000)
    for name, (db, q) in (("job_like", job_like(scale=s1)),
                          ("stats_like", stats_like(scale=s2))):
        # 0.5% of one child relation each way: |delta|/N well under 1%.
        child = [r for r in db.relations][1]
        delta = _churn_delta(db, child, 0.005)
        base = build_shred(db, q)
        db_next = db.apply(delta)

        us_inc = time_fn(
            lambda: jax.tree.leaves(reshred_incremental(base, db, q, delta)),
            reps=5)
        us_full = time_fn(
            lambda: jax.tree.leaves(build_shred(db_next, q)), reps=3)
        out(row(f"updates/{name}/reshred-incremental", us_inc,
                f"delta={delta.size()};N={db.size()}"))
        out(row(f"updates/{name}/full-rebuild", us_full))
        out(row(f"updates/{name}/speedup", 0.0,
                f"incremental_vs_rebuild={us_full/us_inc:.1f}x"))

        # Serving path: warm engine absorbing one delta per call. The same
        # churn delta stays valid across applies (row counts preserved).
        engine = QueryEngine(db)
        key = jax.random.key(0)
        engine.sample(q, key)  # warm the plan cache

        def apply_and_draw():
            engine.apply_delta(delta)
            return engine.sample(q, key).positions

        us_apply = time_fn(apply_and_draw, reps=5)
        st = engine.stats
        out(row(f"updates/{name}/engine-apply-delta", us_apply,
                f"upgrades={st.shred_upgrades};builds={st.shred_builds}"))

        def rebind_and_draw():
            engine.rebind(engine.db.apply(delta))
            return engine.sample(q, key).positions

        us_rebind = time_fn(rebind_and_draw, reps=3)
        out(row(f"updates/{name}/engine-rebind-rebuild", us_rebind,
                f"apply_vs_rebind={us_rebind/us_apply:.1f}x"))