"""Paper Table 6 / Supplementary "Caching": effect of the bulk-probe caching
optimization (Fig. 11) on chained-index probing.

Paper finding (CPU): caching consistently helps CSR (linked lists live at
non-contiguous addresses; resuming skips re-walks) and slightly hurts USR.
TPU-adaptation finding: the cached walk is *sequential by construction* (a
scan carrying the resume state), so on lockstep hardware it loses to the
data-parallel vmapped walk except at extreme degree — quantified here; this
is the measured basis for DESIGN.md §3's claim that bulk vectorization
subsumes the caching optimization.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import build_shred
from repro.core.probe import csr_get_rows, csr_get_rows_cached, usr_get_rows
from .timing import row, time_fn, tiny
from .workloads import degree_sweep_workload

OUT_SIZE = 1 << 14
K = 1024


def run(out):
    out_size = (1 << 11) if tiny() else OUT_SIZE
    k = 128 if tiny() else K
    for d in ((4, 64) if tiny() else (4, 64, 512)):
        db, q = degree_sweep_workload(0, out_size, d)
        shred = build_shred(db, q, rep="both")
        n = int(shred.join_size)
        pos = jnp.sort(jax.random.randint(jax.random.key(1), (k,), 0, n)
                       .astype(jnp.int64))
        us_plain = time_fn(jax.jit(lambda p: csr_get_rows(shred, p)), pos, reps=3)
        us_cache = time_fn(jax.jit(lambda p: csr_get_rows_cached(shred, p)), pos, reps=3)
        us_usr = time_fn(jax.jit(lambda p: usr_get_rows(shred, p)), pos, reps=3)
        out(row(f"table6/csr-vmap/d={d}", us_plain, f"k={k}"))
        out(row(f"table6/csr-cached/d={d}", us_cache,
                f"cached/vmap={us_cache/us_plain:.2f}x"))
        out(row(f"table6/usr/d={d}", us_usr))
