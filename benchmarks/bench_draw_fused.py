"""One-launch fused draw vs the multi-launch per-node path (DESIGN.md §14).

Feeds the ``probe`` suite (BENCH_probe.json) alongside the fused-GET rows.
Three regimes over the STATS-like chain:

* **dispatch-bound single draw** (``draw-eager`` rows) — the serving
  regime the tentpole targets and the rows the acceptance gate reads: the
  multi-launch path dispatches the whole EXPRACE ladder op by op (uniform
  gaps, cumsum, prefix search, dedupe, compaction, then a per-tree-node
  probe walk), while the fused path is ONE kernel launch from PRNG key to
  per-node rows plus the column gather. Gated individually in
  BENCH_probe.json (``gate_rows``) so the >=2x dispatch-floor win cannot
  regress behind a healthy suite median.
* **warm jitted plan** (``draw-jit`` rows) — both routes fully traced into
  one dispatch via ``CompiledPlan.sample``; informational on the CPU
  interpret leg, where emulated Pallas loses to native jnp once dispatch
  overhead is gone (same story as the ``probe/jit-*`` rows).
* **small batch** (``draw-batched`` rows) — the vmapped multi-draw
  executor (DESIGN.md §10) over a power-of-two key bucket.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import config
from repro.core import build_shred, probe, sampling
from repro.engine import QueryEngine

from .timing import row, time_fn, tiny
from .workloads import stats_like

SCALE = 3000
BATCH = 8


def run(out):
    scale = 300 if tiny() else SCALE
    batch = 4 if tiny() else BATCH

    db, q = stats_like(0, scale)
    eng = QueryEngine(db)
    plan_f = eng.compile(q, kernels="fused")
    plan_p = eng.compile(q, kernels="pernode")
    n = plan_f.join_size
    cap = plan_f.default_capacity()
    acap = plan_f.arrival_capacity()
    key = jax.random.key(7)
    keys = jax.random.split(key, batch)

    # -- dispatch-bound: eager single draw (the gated rows) -----------------
    shred = build_shred(db, q, rep="both")
    root = shred.root
    w, p, prefE = root.weight, root.data.column("p"), shred.root_prefE
    dparams = sampling.fused_draw_params(w, p, prefE)
    assert dparams is not None, "workload must be fused-capable"

    def eager_pernode():
        ps = sampling.exprace_positions(key, w, p, prefE, cap,
                                        arrival_cap=acap)
        pos = jnp.minimum(ps.positions, jnp.maximum(prefE[-1] - 1, 0))
        return probe.get(shred, pos, rep="usr"), ps

    def eager_fused():
        rows, ps = probe.draw_fused(shred, dparams, key, method="exprace",
                                    cap=cap, acap=acap)
        return probe.gather_columns(shred, rows), ps

    us_p_e = time_fn(lambda: jax.block_until_ready(eager_pernode()))
    us_f_e = time_fn(lambda: jax.block_until_ready(eager_fused()))
    out(row("probe/draw-eager-pernode/1", us_p_e, f"|Q|={n};cap={cap}"))
    out(row("probe/draw-eager-fused/1", us_f_e,
            f"pernode/fused={us_p_e / us_f_e:.2f}x"))

    # -- dispatch-bound, paged regime (DESIGN.md §15): the same draw with
    # the index rebuilt one word over the VMEM budget, so the walk streams
    # pages (sample launch + paged probe). Gated individually (gate_rows):
    # losing the paged rung means falling back to the multi-launch
    # per-node ladder and this row regressing toward draw-eager-pernode.
    size = shred.packed.layout.size
    pol = dataclasses.replace(config.current_policy(), vmem_limit=size - 1)
    with config.override(pol):
        shred_pg = build_shred(db, q, rep="both")
        assert shred_pg.paged is not None, "workload must land in the paged regime"

        def eager_paged():
            rows, ps = probe.draw_paged(shred_pg, dparams, key,
                                        method="exprace", cap=cap, acap=acap)
            return probe.gather_columns(shred_pg, rows), ps

        us_g_e = time_fn(lambda: jax.block_until_ready(eager_paged()))
    out(row("probe/draw-eager-paged/1", us_g_e,
            f"pernode/paged={us_p_e / us_g_e:.2f}x"))

    # -- warm jitted plan: single draw --------------------------------------
    us_p_j = time_fn(lambda: plan_p.sample(key))
    us_f_j = time_fn(lambda: plan_f.sample(key))
    out(row("probe/draw-jit-pernode/1", us_p_j))
    out(row("probe/draw-jit-fused/1", us_f_j,
            f"pernode/fused={us_p_j / us_f_j:.2f}x"))

    # -- small batch: the vmapped multi-draw executor -----------------------
    us_p_b = time_fn(lambda: plan_p.sample_batch(keys))
    us_f_b = time_fn(lambda: plan_f.sample_batch(keys))
    out(row(f"probe/draw-batched-pernode/B={batch}", us_p_b))
    out(row(f"probe/draw-batched-fused/B={batch}", us_f_b,
            f"pernode/fused={us_p_b / us_f_b:.2f}x"))
