"""Replicated-fleet serving latency (DESIGN.md §12).

End-to-end request latency through the fleet — admission at the router,
fingerprint-affine dispatch, micro-batched execution on a replica, and
the response hop back — measured with the real clock so the p50/p99 rows
are wall-clock SLOs, not sim-time fictions. The stream mixes three query
shapes (so affinity spreads work across replicas) with periodic deltas
(so version barriers are on the serving path, not just in tests).

Rows:
  serve/R1/p50|p99      — single-replica fleet: the router+transport
                          overhead on top of the bare micro-batcher;
  serve/R4/p50|p99      — the 4-replica fleet on the same stream;
  serve/R4/rejected-rate, serve/R4/retries — informational (us <= 0).

The p99 rows are listed in ``BENCH_serve.json``'s ``gate_rows``: CI's
bench-smoke gates each of them individually (tools/check_bench.py), so a
tail-latency regression cannot hide behind a healthy suite median.
"""
from __future__ import annotations

import numpy as np

from repro.core import Atom, Database, JoinQuery
from repro.core.delta import DeltaBatch
from repro.launch.fleet import Fleet, JoinSampleRequest, UpdateRequest
from repro.launch.metrics import percentile
from .timing import row, tiny


def _workload(seed=0, nr=400, ns=700, nt=300):
    rng = np.random.default_rng(seed)
    db = Database.from_columns({
        "R": {"x": rng.integers(0, 40, nr), "p": rng.random(nr) * 0.4},
        "S": {"x": rng.integers(0, 40, ns), "y": rng.integers(0, 30, ns)},
        "T": {"y": rng.integers(0, 30, nt), "z": np.arange(nt)},
    })
    shapes = (
        JoinQuery((Atom.of("R", "x", "p"),), prob_var="p"),
        JoinQuery((Atom.of("R", "x", "p"), Atom.of("S", "x", "y")),
                  prob_var="p"),
        JoinQuery((Atom.of("R", "x", "p"), Atom.of("S", "x", "y"),
                   Atom.of("T", "y", "z")), prob_var="p"),
    )
    return db, shapes


def _delta(i):
    # Shape-preserving (2 in, 2 out): replicas upgrade warm caches in
    # place at the barrier (DESIGN.md §11) — the rows below measure
    # steady-state serving, not recompiles.
    return DeltaBatch.of(S={"insert": {"x": [i % 40, (i + 7) % 40],
                                       "y": [i % 30, (i + 3) % 30]},
                            "delete": [0, 1]})


def _serve(db, shapes, n, replicas, max_batch):
    import jax

    fleet = Fleet(db, replicas=replicas, max_batch=max_batch,
                  max_wait_ms=2.0, max_inflight=1 << 16,
                  retry_timeout_s=60.0, clock="real")
    # Warm compile-time one-offs out of the latency rows (bench_throughput
    # convention): every (shape, batch-bucket) plan on every replica —
    # barrier flushes produce partial batches, so the whole bucket ladder
    # is on the serving path — plus the incremental delta-apply kernels.
    warm_key = jax.random.key(0)
    for rep in fleet.replicas:
        for q in shapes:
            b = 1
            while b <= max_batch:
                jax.block_until_ready(
                    rep.engine.sample_batch(q, jax.random.split(warm_key, b))
                    .positions)
                b *= 2
    from repro.engine import QueryEngine
    throwaway = QueryEngine(db)
    for q in shapes:
        jax.block_until_ready(throwaway.sample(q, warm_key).positions)
    throwaway.apply_delta(_delta(0))
    jax.block_until_ready(throwaway.sample(q, warm_key).positions)
    return fleet


def _pass(fleet, shapes, n, max_batch):
    """One measured stream: batch-aligned blocks (each block fills exactly
    one micro-batch on the shape's home replica — ``sample_batch`` traces
    per batch size, so ragged flushes would measure compiles, not
    serving), shapes rotating per block, a delta between blocks."""
    n_blocks = n // max_batch
    reqs = [JoinSampleRequest(query=shapes[i // max_batch % len(shapes)],
                              seed=i) for i in range(n_blocks * max_batch)]
    update_blocks = max(1, n_blocks // 4)
    for b in range(n_blocks):
        if b and b % update_blocks == 0:
            fleet.submit(UpdateRequest(_delta(b)))
        for r in reqs[b * max_batch:(b + 1) * max_batch]:
            fleet.submit(r)
    fleet.take_completed()
    lats = [r.latency_s for r in reqs if r.latency_s is not None]
    assert len(lats) == len(reqs), "fleet lost a request"
    return lats


def run(out):
    n = 128 if tiny() else 320
    reps = 5
    max_batch = 8
    db, shapes = _workload(nr=200 if tiny() else 400,
                           ns=350 if tiny() else 700,
                           nt=150 if tiny() else 300)
    for replicas in (1, 4):
        fleet = _serve(db, shapes, n, replicas, max_batch)
        # The tail is dominated by barrier-adjacent flushes, so a single
        # pass's p99 is noisy (it is nearly a max). time_fn convention at
        # the pass level: one discarded warm pass (absorbs each replica's
        # first-barrier one-offs), then the median percentile over reps.
        _pass(fleet, shapes, n, max_batch)
        p50s, p99s, maxes = [], [], []
        for _ in range(reps):
            lats = _pass(fleet, shapes, n, max_batch)
            p50s.append(percentile(lats, 0.5))
            p99s.append(percentile(lats, 0.99))
            maxes.append(max(lats))
        tag = f"serve/R{replicas}"
        out(row(f"{tag}/p50", percentile(p50s, 0.5) * 1e6,
                f"n={n};reps={reps};replicas={replicas};"
                f"max_batch={max_batch}"))
        out(row(f"{tag}/p99", percentile(p99s, 0.5) * 1e6,
                f"max={max(maxes) * 1e6:.0f}us"))
        if replicas > 1:
            rt = fleet.router
            total = rt.accepted + rt.rejected
            out(row(f"{tag}/rejected-rate", 0.0,
                    f"rate={rt.rejected / total:.4f};"
                    f"accepted={rt.accepted};rejected={rt.rejected}"))
            out(row(f"{tag}/retries", 0.0,
                    f"retries={rt.retries};duplicates={rt.duplicates};"
                    f"log_head={fleet.log.head}"))
        fleet.drain()
