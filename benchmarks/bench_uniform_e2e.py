"""Paper Fig. 8 / Table 5: end-to-end uniform sampling — Index-and-Probe
(CSR / USR x GEO / BERN) vs Materialize-and-Scan (M-CSYA / M-USYA / M-BJ).

Reproduced claims: (a) I&P beats M&S for small/moderate p and the gap grows
with join size (STATS-like >> JOB-like); (b) at p -> 1 M&S catches up
(flatten is sequential-friendly); (c) on the TPU-adapted implementation USR
probing is the vectorized fast path (the CPU-paper's CSR advantage inverts —
DESIGN.md §3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import build_shred, yannakakis
from repro.engine import QueryEngine
from .timing import row, time_fn, tiny
from .workloads import job_like, stats_like

PS = (0.0001, 0.01, 0.1, 0.5, 0.9)


def _ps():
    return (0.01, 0.5) if tiny() else PS


def _bench_suite(name, db, q, out):
    engine = QueryEngine(db)
    sampler_u = engine.compile(q, rep="usr")
    sampler_c = engine.compile(q, rep="csr")
    n = sampler_u.join_size

    # index build (amortized per Monte-Carlo loop, reported separately)
    us = time_fn(lambda: build_shred(db, q, rep="usr"), reps=3)
    out(row(f"fig8/{name}/build/usr", us, f"|Q(db)|={n}"))
    us = time_fn(lambda: build_shred(db, q, rep="csr"), reps=3)
    out(row(f"fig8/{name}/build/csr", us, f"|Q(db)|={n}"))

    for p in _ps():
        method = "geo" if p <= 0.5 else "bern"
        cap = int(min(max(n * p * 1.3 + 6 * (n * p) ** 0.5 + 256, 512), n + 1))
        for repname, s in (("usr", sampler_u), ("csr", sampler_c)):
            us = time_fn(lambda k: s.uniform_sample(k, p, cap=cap, method=method),
                         jax.random.key(1), reps=3)
            out(row(f"fig8/{name}/I&P-{repname}-{method}/p={p}", us))
        # M&S baseline: flatten everything + one Bernoulli per join tuple
        us = time_fn(lambda k: yannakakis.materialize_and_scan(k, db, q, uniform_p=p),
                     jax.random.key(1), reps=3)
        out(row(f"fig8/{name}/M-SYA/p={p}", us))


def run(out):
    s1, s2 = (150, 200) if tiny() else (1500, 2000)
    db, q = job_like(scale=s1)
    _bench_suite("job_like", db, q, out)
    db, q = stats_like(scale=s2)
    _bench_suite("stats_like", db, q, out)
