"""Paper Table 4: full-join processing — shredded Yannakakis (CSR/USR flatten)
vs materializing binary joins (M-BJ).

Reproduced claim: SYA is instance-optimal and robust; the binary-join plan
pays for materialized intermediates (on skewed STATS-like inputs the gap is
large — the paper reports up to ~46s vs ~5s worst case). "One engine basis
without regret": the same index used for sampling computes full joins
competitively.
"""
from __future__ import annotations

from .timing import row, time_fn
from .workloads import job_like, stats_like
from repro.core import yannakakis


def run(out):
    for name, (db, q) in (("job_like", job_like(scale=1200)),
                          ("stats_like", stats_like(scale=1500))):
        us_u = time_fn(lambda: yannakakis.full_join(db, q, rep="usr"), reps=3)
        us_c = time_fn(lambda: yannakakis.full_join(db, q, rep="csr"), reps=3)
        us_b = time_fn(lambda: yannakakis.binary_join(db, q), reps=3)
        out(row(f"table4/{name}/SYA-usr", us_u))
        out(row(f"table4/{name}/SYA-csr", us_c))
        out(row(f"table4/{name}/binary-join", us_b,
                f"bj/sya={us_b/min(us_u, us_c):.2f}x"))
