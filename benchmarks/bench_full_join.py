"""Paper Table 4: full-join processing — shredded Yannakakis (CSR/USR flatten)
vs materializing binary joins (M-BJ), routed through the query engine.

Reproduced claim: SYA is instance-optimal and robust; the binary-join plan
pays for materialized intermediates (on skewed STATS-like inputs the gap is
large — the paper reports up to ~46s vs ~5s worst case). "One engine basis
without regret": the same index used for sampling computes full joins
competitively.

The table4/ rows keep their historical end-to-end semantics (plan + index
build + flatten, a fresh engine per call, directly comparable to M-BJ);
the extra SYA-*-warm rows time the flatten alone from the engine's cached
index — the serving-path cost once the plan cache is hot (DESIGN.md §7).
"""
from __future__ import annotations

from .timing import row, time_fn, tiny
from .workloads import job_like, stats_like
from repro.core import yannakakis
from repro.engine import QueryEngine


def run(out):
    s1, s2 = (120, 150) if tiny() else (1200, 1500)
    for name, (db, q) in (("job_like", job_like(scale=s1)),
                          ("stats_like", stats_like(scale=s2))):
        us_u = time_fn(lambda: QueryEngine(db, rep="usr").full_join(q), reps=3)
        us_c = time_fn(lambda: QueryEngine(db, rep="csr").full_join(q), reps=3)
        us_b = time_fn(lambda: yannakakis.binary_join(db, q), reps=3)
        out(row(f"table4/{name}/SYA-usr", us_u))
        out(row(f"table4/{name}/SYA-csr", us_c))
        out(row(f"table4/{name}/binary-join", us_b,
                f"bj/sya={us_b/min(us_u, us_c):.2f}x"))
        warm = QueryEngine(db, rep="usr")
        warm.compile(q)  # index built outside the timed region
        us_w = time_fn(lambda: warm.full_join(q), reps=3)
        out(row(f"table4/{name}/SYA-usr-warm", us_w,
                f"cold/warm={us_u/us_w:.2f}x"))
