"""Timing helpers: jit, warm up, block_until_ready, report microseconds."""
from __future__ import annotations

import time
from typing import Callable

import jax

from repro import config


def tiny() -> bool:
    """True in bench-smoke mode (``benchmarks.run --tiny``): suites shrink
    their workloads so CI exercises every path in seconds."""
    return config.bench_tiny()


def time_fn(fn: Callable, *args, reps: int = 5, warmup: int = 2, **kw) -> float:
    """Median wall-time of fn(*args) in microseconds (post-warmup)."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def row(name: str, us: float, derived: str = "") -> str:
    return f"{name},{us:.1f},{derived}"
