"""Kernel microbenchmarks: Pallas (interpret) vs jnp reference.

NOTE: interpret-mode timings measure the *simulated* kernel on CPU — they
validate plumbing cost, not TPU speed. TPU performance is assessed
structurally via the dry-run roofline (§Roofline); these rows exist to keep
the harness one-command and to catch pathological regressions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from .timing import row, time_fn, tiny


def run(out):
    # bsearch probe
    npref, nq = (512, 1024) if tiny() else (4096, 8192)
    pref = jnp.cumsum(jax.random.randint(jax.random.key(0), (npref,), 0, 9)).astype(jnp.int32)
    pref = jnp.concatenate([jnp.zeros((1,), jnp.int32), pref])
    q = jax.random.randint(jax.random.key(1), (nq,), 0, int(pref[-1])).astype(jnp.int32)
    out(row("kernels/bsearch/pallas", time_fn(ops.searchsorted_prefix, pref, q)))
    out(row("kernels/bsearch/xla", time_fn(
        jax.jit(lambda p, x: jnp.searchsorted(p, x, side='right') - 1), pref, q)))

    # prefix sum
    x = jax.random.randint(jax.random.key(2), (1 << (12 if tiny() else 16),), 0, 9).astype(jnp.int32)
    out(row("kernels/prefix_sum/pallas", time_fn(ops.prefix_sum, x)))
    out(row("kernels/prefix_sum/xla", time_fn(jax.jit(jnp.cumsum), x)))

    # decode attention
    B, H, S, D = (1, 2, 256, 64) if tiny() else (2, 8, 2048, 64)
    ks = jax.random.split(jax.random.key(3), 3)
    qq = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    kk = jax.random.normal(ks[1], (B, H, S, D), jnp.float32)
    vv = jax.random.normal(ks[2], (B, H, S, D), jnp.float32)
    bias = jnp.zeros((B, S), jnp.float32)
    out(row("kernels/flash_decode/pallas-interpret",
            time_fn(ops.decode_attention, qq, kk, vv, bias, reps=3)))
    out(row("kernels/flash_decode/xla-ref",
            time_fn(jax.jit(ref.flash_decode_ref), qq, kk, vv, bias, reps=3)))

    # prefill (full-sequence causal) attention
    Sq, blk = (256, 128) if tiny() else (1024, 256)
    q4 = jax.random.normal(ks[0], (1, 4, Sq, 64), jnp.float32)
    k4 = jax.random.normal(ks[1], (1, 4, Sq, 64), jnp.float32)
    v4 = jax.random.normal(ks[2], (1, 4, Sq, 64), jnp.float32)
    out(row("kernels/flash_prefill/pallas-interpret",
            time_fn(lambda: ops.prefill_attention(q4, k4, v4, block_q=blk,
                                                  block_k=blk), reps=3)))
    out(row("kernels/flash_prefill/xla-ref",
            time_fn(jax.jit(ref.flash_prefill_ref), q4, k4, v4, reps=3)))
