"""Fused Pallas tree-probe GET vs the per-node USR walk vs the CSR chain
walk (DESIGN.md §4 "Fused GET").

Two regimes, both over a STATS-like 3-deep chain (the shape where the
per-node path's ~3·depth ops hurt most):

* **dispatch-bound** (``eager`` rows) — op-by-op GET on a small probe
  batch, the serving regime where host dispatch overhead dominates: the
  per-node USR path issues one searchsorted plus perm/child_start/child_w
  gathers *per tree node*, while the fused path is ONE kernel launch over
  the packed arena (plus tiling glue). This is the regime the tentpole
  targets and the row the acceptance criterion reads.
* **compute-bound** (``jit`` rows) — the whole GET jitted into one
  dispatch per call; measures pure op cost at a larger probe batch.

A batched ``(B, cap)`` row exercises the vmapped fused kernel the engine's
multi-draw executor uses (DESIGN.md §10). ``--tiny`` shrinks every size
(CI bench-smoke); the committed BENCH_probe.json baseline is gated by
tools/check_bench.py.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import config
from repro.core import build_shred, get
from repro.core.probe import (usr_get_rows, usr_get_rows_fused,
                              usr_get_rows_paged)

from .timing import row, time_fn, tiny
from .workloads import stats_like

SCALE = 3000
K_DISPATCH = 512    # dispatch-bound probe batch
K_COMPUTE = 1 << 14  # compute-bound probe batch
BATCH = 16


def run(out):
    scale = 300 if tiny() else SCALE
    k_d = 128 if tiny() else K_DISPATCH
    k_c = (1 << 10) if tiny() else K_COMPUTE
    batch = 4 if tiny() else BATCH

    db, q = stats_like(0, scale)
    shred = build_shred(db, q, rep="both")
    n = int(shred.join_size)
    assert shred.packed is not None, "workload must narrow to int32"
    depth = len(shred.packed.layout.names)

    def pos_of(k, seed=1):
        return jax.random.randint(jax.random.key(seed), (k,), 0, n
                                  ).astype(jnp.int64)

    # -- dispatch-bound: eager op-by-op GET ---------------------------------
    pos_d = pos_of(k_d)
    us_usr_e = time_fn(lambda: jax.block_until_ready(
        usr_get_rows(shred, pos_d)))
    us_fus_e = time_fn(lambda: jax.block_until_ready(
        usr_get_rows_fused(shred, pos_d)))
    out(row(f"probe/eager-usr/k={k_d}", us_usr_e,
            f"|Q|={n};depth={depth}"))
    out(row(f"probe/eager-fused/k={k_d}", us_fus_e,
            f"usr/fused={us_usr_e / us_fus_e:.2f}x"))

    # -- dispatch-bound, paged regime (DESIGN.md §15): the same workload
    # rebuilt under a VMEM budget one word short of the arena, so the index
    # pages instead of packing a monolith. Gated individually (gate_rows):
    # a regression that drops the paged rung back to the per-node walk
    # shows up as this row converging on eager-usr, not the healthy median.
    size = shred.packed.layout.size
    pol = dataclasses.replace(config.current_policy(), vmem_limit=size - 1)
    with config.override(pol):
        shred_pg = build_shred(db, q, rep="both")
        assert shred_pg.paged is not None, "workload must land in the paged regime"
        us_pag_e = time_fn(lambda: jax.block_until_ready(
            usr_get_rows_paged(shred_pg, pos_d)))
    out(row(f"probe/eager-paged/k={k_d}", us_pag_e,
            f"usr/paged={us_usr_e / us_pag_e:.2f}x;"
            f"pages={len(shred_pg.paged.pages)}"))

    # -- compute-bound: one jitted dispatch per GET -------------------------
    pos_c = pos_of(k_c)
    us_usr = time_fn(jax.jit(lambda p: get(shred, p, rep="usr")), pos_c)
    us_fus = time_fn(jax.jit(lambda p: get(shred, p, rep="usr_fused")), pos_c)
    us_csr = time_fn(jax.jit(lambda p: get(shred, p, rep="csr")), pos_c)
    out(row(f"probe/jit-usr/k={k_c}", us_usr))
    out(row(f"probe/jit-fused/k={k_c}", us_fus,
            f"usr/fused={us_usr / us_fus:.2f}x"))
    out(row(f"probe/jit-csr/k={k_c}", us_csr,
            f"csr/fused={us_csr / us_fus:.2f}x"))

    # -- batched (B, cap): the vmapped shape of the multi-draw executor -----
    pos_b = jnp.stack([pos_of(k_d, s) for s in range(batch)])
    us_usr_b = time_fn(jax.jit(jax.vmap(
        lambda p: get(shred, p, rep="usr"))), pos_b)
    us_fus_b = time_fn(jax.jit(jax.vmap(
        lambda p: get(shred, p, rep="usr_fused"))), pos_b)
    out(row(f"probe/batched-usr/B={batch}", us_usr_b))
    out(row(f"probe/batched-fused/B={batch}", us_fus_b,
            f"usr/fused={us_usr_b / us_fus_b:.2f}x"))
