"""Paper Fig. 9 / Table 2: non-uniform Poisson sampling across low / medium /
high probability distributions, I&P vs M-CSYA, plus the beyond-paper
EXPRACE sampler vs the faithful PT*-style flat-Bernoulli.

Reproduced claims: I&P speedups grow as the probability distribution gets
lighter (low > medium > high), mirroring the paper's (min/avg/max) speedup
ordering; the hybrid/vectorized sampler is never worse than the faithful
PTBERN-flat baseline and wins big at low p.
"""
from __future__ import annotations

import jax

from repro.core import PoissonSampler, yannakakis
from .timing import row, time_fn
from .workloads import PROB_DISTS, job_like, stats_like


def _suite(name, mk, out):
    for dist in ("low", "medium", "high"):
        db, q = mk(dist=dist)
        s_race = PoissonSampler(db, q, rep="usr", method="exprace")
        s_bern = PoissonSampler(db, q, rep="usr", method="ptbern_flat")
        s_csr = PoissonSampler(db, q, rep="csr", method="exprace")
        n = s_race.join_size
        ek = s_race.expected_k()

        us_r = time_fn(lambda k: s_race.sample(k), jax.random.key(0), reps=3)
        out(row(f"fig9/{name}/{dist}/I&P-usr-EXPRACE", us_r,
                f"|Q|={n};E[k]={ek:.0f}"))
        us_c = time_fn(lambda k: s_csr.sample(k), jax.random.key(0), reps=3)
        out(row(f"fig9/{name}/{dist}/I&P-csr-EXPRACE", us_c))
        us_b = time_fn(lambda k: s_bern.sample(k), jax.random.key(0), reps=3)
        out(row(f"fig9/{name}/{dist}/I&P-usr-PTBERNflat", us_b))
        us_ms = time_fn(lambda k: yannakakis.materialize_and_scan(k, db, q),
                        jax.random.key(0), reps=3)
        out(row(f"fig9/{name}/{dist}/M-CSYA", us_ms,
                f"speedup={us_ms/us_r:.2f}x"))


def run(out):
    _suite("job_like", lambda dist: job_like(dist=dist, scale=1200), out)
    _suite("stats_like", lambda dist: stats_like(dist=dist, scale=1500), out)
