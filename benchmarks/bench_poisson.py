"""Paper Fig. 9 / Table 2: non-uniform Poisson sampling across low / medium /
high probability distributions, I&P vs M-CSYA, plus the beyond-paper
EXPRACE sampler vs the faithful PT*-style flat-Bernoulli — all routed
through one ``QueryEngine`` per workload, so the three I&P variants share
the engine's shred cache (usr built once, csr built once).

Reproduced claims: I&P speedups grow as the probability distribution gets
lighter (low > medium > high), mirroring the paper's (min/avg/max) speedup
ordering; the hybrid/vectorized sampler is never worse than the faithful
PTBERN-flat baseline and wins big at low p.
"""
from __future__ import annotations

import jax

from repro.engine import QueryEngine
from .timing import row, time_fn, tiny
from .workloads import PROB_DISTS, job_like, stats_like


def _suite(name, mk, out):
    for dist in (("low", "high") if tiny() else ("low", "medium", "high")):
        db, q = mk(dist=dist)
        engine = QueryEngine(db, rep="usr")
        plan_race = engine.compile(q, rep="usr", method="exprace")
        plan_bern = engine.compile(q, rep="usr", method="ptbern_flat")
        plan_csr = engine.compile(q, rep="csr", method="exprace")
        n = plan_race.join_size
        ek = plan_race.expected_k()

        us_r = time_fn(lambda k: plan_race.sample(k), jax.random.key(0), reps=3)
        out(row(f"fig9/{name}/{dist}/I&P-usr-EXPRACE", us_r,
                f"|Q|={n};E[k]={ek:.0f}"))
        us_c = time_fn(lambda k: plan_csr.sample(k), jax.random.key(0), reps=3)
        out(row(f"fig9/{name}/{dist}/I&P-csr-EXPRACE", us_c))
        us_b = time_fn(lambda k: plan_bern.sample(k), jax.random.key(0), reps=3)
        out(row(f"fig9/{name}/{dist}/I&P-usr-PTBERNflat", us_b))
        us_ms = time_fn(lambda k: engine.materialize_and_scan(k, q),
                        jax.random.key(0), reps=3)
        out(row(f"fig9/{name}/{dist}/M-CSYA", us_ms,
                f"speedup={us_ms/us_r:.2f}x"))


def run(out):
    s1, s2 = (120, 150) if tiny() else (1200, 1500)
    _suite("job_like", lambda dist: job_like(dist=dist, scale=s1), out)
    _suite("stats_like", lambda dist: stats_like(dist=dist, scale=s2), out)
