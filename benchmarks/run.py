"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Usage:
    PYTHONPATH=src python -m benchmarks.run [--only fig7,fig9,...] [--tiny]

``--tiny`` shrinks the workload sizes (CI bench-smoke mode: exercises every
code path, measures nothing meaningful). An ``--only`` filter matching no
suite is an error (exit 2) — a silent empty run would upload a header-only
CSV and pass CI.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated substrings to filter suites")
    ap.add_argument("--tiny", action="store_true",
                    help="tiny workload sizes (CI smoke; sets REPRO_BENCH_TINY)")
    args = ap.parse_args()
    if args.tiny:
        # Before the suite imports: sizes are chosen at module/run scope.
        from repro import config
        config.set_bench_tiny(True)

    from . import (bench_position_sampling, bench_uniform_e2e, bench_poisson,
                   bench_build_probe, bench_probe_fused, bench_draw_fused,
                   bench_full_join, bench_qc, bench_caching,
                   bench_engine_cache, bench_sharded_engine, bench_serve,
                   bench_throughput, bench_updates, bench_pipeline,
                   bench_kernels, roofline)
    suites = [
        ("fig7_position_sampling", bench_position_sampling.run),
        ("fig8_uniform_e2e", bench_uniform_e2e.run),
        ("fig9_poisson", bench_poisson.run),
        ("table3_build_probe", bench_build_probe.run),
        # Both feed the "probe" suite / BENCH_probe.json: fused GET rows,
        # then the one-launch fused-draw rows (DESIGN.md §14).
        ("probe", bench_probe_fused.run),
        ("probe", bench_draw_fused.run),
        ("table4_full_join", bench_full_join.run),
        ("fig10_qc", bench_qc.run),
        ("table6_caching", bench_caching.run),
        ("engine_cache", bench_engine_cache.run),
        ("sharded_engine", bench_sharded_engine.run),
        ("serve", bench_serve.run),
        ("throughput", bench_throughput.run),
        ("updates", bench_updates.run),
        ("pipeline", bench_pipeline.run),
        ("kernels", bench_kernels.run),
        ("roofline", roofline.run),
    ]
    if args.only:
        keys = [k for k in args.only.split(",") if k]
        selected = [(n, f) for n, f in suites if any(k in n for k in keys)]
        if not selected:
            names = ", ".join(n for n, _ in suites)
            print(f"benchmarks.run: --only {args.only!r} matched no suites "
                  f"(available: {names})", file=sys.stderr)
            sys.exit(2)
        suites = selected

    print("name,us_per_call,derived")
    failures = []
    for name, fn in suites:
        print(f"# --- {name} ---")
        try:
            fn(print)
        except Exception:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"# FAILED suites: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
