"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Usage:
    PYTHONPATH=src python -m benchmarks.run [--only fig7,fig9,...]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated substrings to filter suites")
    args = ap.parse_args()

    from . import (bench_position_sampling, bench_uniform_e2e, bench_poisson,
                   bench_build_probe, bench_full_join, bench_qc,
                   bench_caching, bench_engine_cache, bench_kernels, roofline)
    suites = [
        ("fig7_position_sampling", bench_position_sampling.run),
        ("fig8_uniform_e2e", bench_uniform_e2e.run),
        ("fig9_poisson", bench_poisson.run),
        ("table3_build_probe", bench_build_probe.run),
        ("table4_full_join", bench_full_join.run),
        ("fig10_qc", bench_qc.run),
        ("table6_caching", bench_caching.run),
        ("engine_cache", bench_engine_cache.run),
        ("kernels", bench_kernels.run),
        ("roofline", roofline.run),
    ]
    if args.only:
        keys = args.only.split(",")
        suites = [(n, f) for n, f in suites if any(k in n for k in keys)]

    print("name,us_per_call,derived")
    failures = []
    for name, fn in suites:
        print(f"# --- {name} ---")
        try:
            fn(print)
        except Exception:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"# FAILED suites: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
