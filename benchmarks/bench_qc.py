"""Paper Fig. 10: the EpiQL contact query Q_c across population sizes.

Reproduced claims: I&P scales with the sample size (E[k] ~= 2.4% x |Q|)
while M&S scales with the full join size; M-BJ materializes the largest
intermediates and falls over first. Population sizes are scaled to CPU;
the join-size : sample-size ratio (~40x) matches the paper's regime
(1.3e10 join, ~1e8 samples at p~=2.4%).
"""
from __future__ import annotations

import jax

from repro.core import yannakakis
from repro.engine import QueryEngine
from .timing import row, time_fn, tiny
from .workloads import qc_workload

POPS = (500, 1000, 2000, 4000)


def run(out):
    for pop in ((200, 400) if tiny() else POPS):
        db, q = qc_workload(n_persons=pop, n_pools=max(pop // 40, 4))
        s = QueryEngine(db, rep="usr").compile(q, method="exprace")
        n, ek = s.join_size, s.expected_k()
        us_ip = time_fn(lambda k: s.sample(k), jax.random.key(0), reps=3)
        out(row(f"fig10/qc/pop={pop}/I&P", us_ip, f"|Q|={n};E[k]={ek:.0f}"))
        if n <= 4_000_000:
            us_ms = time_fn(lambda k: yannakakis.materialize_and_scan(k, db, q),
                            jax.random.key(0), reps=3)
            out(row(f"fig10/qc/pop={pop}/M-CSYA", us_ms,
                    f"speedup={us_ms/us_ip:.2f}x"))
        # Monte-Carlo loop amortization: 5 independent sampling steps reuse
        # the index (the EpiQL simulation pattern)
        def five(k):
            outs = []
            for i in range(5):
                outs.append(s.sample(jax.random.fold_in(k, i)))
            return outs
        us5 = time_fn(five, jax.random.key(7), reps=3)
        out(row(f"fig10/qc/pop={pop}/I&P-5steps", us5, "index reuse"))
