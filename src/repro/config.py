"""Process-wide kernel + platform configuration (DESIGN.md §14).

Before this module, kernel selection leaked through four environment
variables read at call time from four different places —
``REPRO_PALLAS_DISABLE``/``REPRO_PALLAS_INTERPRET``/``REPRO_PALLAS_PREFER``
in ``kernels/ops.py``, the fused-GET VMEM budget in ``core/probe.py``, the
bench-smoke flag in ``benchmarks/timing.py``, and the host-device count in
``launch/mesh.py``. ``KernelPolicy`` is the one value object for all of it:

  * **frozen + hashable** — a policy can be compared, cached against, and
    baked into plan identity without aliasing surprises;
  * **env vars are the default constructor only** — ``policy_from_env()``
    parses the three ``REPRO_PALLAS_*`` variables with their historical
    semantics (below) and nothing else ever reads them; the grep lint
    ``tools/check_env.py`` fails CI on raw ``REPRO_*`` reads outside this
    module;
  * **scoped override** — ``with override(KernelPolicy(...)):`` installs a
    policy for the dynamic extent (contextvar, so async/thread safe), and
    every ``policy=`` keyword threaded through ``kernels/ops.py`` /
    ``core/probe.py`` takes a per-call override on top.

Resolution order (first hit wins — DESIGN.md §14):

    per-call ``policy=``  >  ``override(...)`` context  >  environment

Exact env semantics (kept bit-for-bit from the pre-consolidation readers;
the CI matrix relies on ``REPRO_PALLAS_INTERPRET=''`` meaning *interpret*):

    enabled   = REPRO_PALLAS_DISABLE  in ("", "0")   (default "0")
    interpret = REPRO_PALLAS_INTERPRET != "0"        (default "1")
    prefer    = REPRO_PALLAS_PREFER   not in ("","0") (default "0")

Platform setup (``xla_force_host_platform_device_count``) lives here too so
launch scripts have one import that owns every process-level knob.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import os

__all__ = [
    "KernelPolicy", "policy_from_env", "current_policy", "override",
    "DEFAULT_VMEM_LIMIT", "force_host_devices", "bench_tiny",
    "set_bench_tiny",
]

# int32 elements kept fully VMEM-resident (bsearch prefix tables, the
# fused-GET arena, and the fused-draw scratch share this budget — see
# DESIGN.md §9; ``kernels/ops.py`` re-exports it as VMEM_PREF_LIMIT).
DEFAULT_VMEM_LIMIT = 1 << 21


@dataclasses.dataclass(frozen=True)
class KernelPolicy:
    """How Pallas kernels are selected, everywhere (DESIGN.md §14).

    enabled     master switch: False routes every wrapper through its
                pure-XLA/jnp fallback (the operator escape hatch for a
                kernel bug; historical ``REPRO_PALLAS_DISABLE=1``).
    interpret   run kernels in Pallas interpret mode (the validated mode
                on this CPU container; False = compiled mode on real TPU).
    prefer      prefer kernels over their XLA twins inside jitted hot
                paths even in interpret mode (the CI interpret leg pins
                this so the whole tier-1 suite exercises the kernels).
    vmem_limit  int32-element budget for VMEM-resident tables (prefix
                vectors, the packed index arena, fused-draw scratch).
    fused_draw  allow the one-launch fused draw route (kernels/fused_draw)
                when capability gates pass; False pins the multi-launch
                per-node path without touching GET kernel selection.
    """

    enabled: bool = True
    interpret: bool = True
    prefer: bool = False
    vmem_limit: int = DEFAULT_VMEM_LIMIT
    fused_draw: bool = True

    @property
    def preferred(self) -> bool:
        """Should jitted hot paths *prefer* Pallas kernels over their XLA
        twins when both are available? True in compiled mode (real TPU —
        the kernels are the point); in interpret mode the interpreter's
        per-access overhead loses to XLA inside an already-jitted
        executor, so hot paths default to XLA unless ``prefer`` pins the
        kernel path. Capability gates (``enabled``, dtype/VMEM fallbacks)
        still apply on top."""
        return self.enabled and (self.prefer or not self.interpret)


def policy_from_env() -> KernelPolicy:
    """The default policy, parsed from the environment *at call time* (so
    tests and CI legs can flip a var without re-importing anything). The
    parse of each variable is exactly the historical reader's — in
    particular ``REPRO_PALLAS_INTERPRET=''`` still means interpret=True
    (the CI matrix sets the empty string on non-interpret legs)."""
    env = os.environ.get
    return KernelPolicy(
        enabled=env("REPRO_PALLAS_DISABLE", "0") in ("", "0"),
        interpret=env("REPRO_PALLAS_INTERPRET", "1") != "0",
        prefer=env("REPRO_PALLAS_PREFER", "0") not in ("", "0"),
    )


_override: "contextvars.ContextVar[KernelPolicy]" = contextvars.ContextVar(
    "repro_kernel_policy", default=None)


def current_policy(policy: KernelPolicy = None) -> KernelPolicy:
    """Resolve the active policy: per-call ``policy=`` > ``override(...)``
    context > environment defaults (DESIGN.md §14)."""
    if policy is not None:
        return policy
    installed = _override.get()
    return installed if installed is not None else policy_from_env()


@contextlib.contextmanager
def override(policy: KernelPolicy):
    """Install ``policy`` for the dynamic extent of the ``with`` block::

        with repro.config.override(KernelPolicy(prefer=True)):
            plan = engine.compile(query)   # binds the fused routes

    Contextvar-scoped: concurrent threads/tasks see their own override.
    Note plans capture routing verdicts at *bind* time — a policy change
    after ``compile()`` does not rewire an existing plan (recompile, or
    let ``DrawSpec.kernels`` pin the route as plan identity)."""
    token = _override.set(policy)
    try:
        yield policy
    finally:
        _override.reset(token)


# ---------------------------------------------------------------------------
# Platform setup (process-level, owned here so launch scripts import one
# module for every knob; launch/mesh.py delegates).
# ---------------------------------------------------------------------------

def force_host_devices(n: int) -> int:
    """Ask XLA for ``n`` virtual host (CPU) devices; returns the count
    actually available. Only effective before the backend initializes —
    appends ``--xla_force_host_platform_device_count`` to ``XLA_FLAGS``
    and reports (rather than raises) when the backend beat us to it, so
    callers degrade to the real device count."""
    import sys

    import jax

    if n > 1:
        flags = os.environ.get("XLA_FLAGS", "")
        flag = f"--xla_force_host_platform_device_count={n}"
        if "--xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = f"{flags} {flag}".strip()
    got = len(jax.devices())
    if got < n:
        print(f"[mesh] requested {n} host devices, backend has {got} "
              f"(already initialized, or XLA_FLAGS pre-set); using {got}",
              file=sys.stderr)
    return got


# ---------------------------------------------------------------------------
# Bench-smoke flag (the only other REPRO_* variable; centralizing the read
# and the write here keeps the check_env lint trivially green).
# ---------------------------------------------------------------------------

def bench_tiny() -> bool:
    """True in bench-smoke mode (``benchmarks.run --tiny``): suites shrink
    their workloads so CI exercises every path in seconds."""
    return os.environ.get("REPRO_BENCH_TINY", "") not in ("", "0")


def set_bench_tiny(on: bool = True) -> None:
    """Flip bench-smoke mode for this process (and subprocesses). Set via
    env because suites size their workloads at module/run scope, possibly
    in spawned workers that inherit the environment."""
    if on:
        os.environ["REPRO_BENCH_TINY"] = "1"
    else:
        os.environ.pop("REPRO_BENCH_TINY", None)
