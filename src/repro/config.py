"""Process-wide kernel + platform configuration (DESIGN.md §14).

Before this module, kernel selection leaked through four environment
variables read at call time from four different places —
``REPRO_PALLAS_DISABLE``/``REPRO_PALLAS_INTERPRET``/``REPRO_PALLAS_PREFER``
in ``kernels/ops.py``, the fused-GET VMEM budget in ``core/probe.py``, the
bench-smoke flag in ``benchmarks/timing.py``, and the host-device count in
``launch/mesh.py``. ``KernelPolicy`` is the one value object for all of it:

  * **frozen + hashable** — a policy can be compared, cached against, and
    baked into plan identity without aliasing surprises;
  * **env vars are the default constructor only** — ``policy_from_env()``
    parses the three ``REPRO_PALLAS_*`` variables with their historical
    semantics (below) and nothing else ever reads them; the grep lint
    ``tools/check_env.py`` fails CI on raw ``REPRO_*`` reads outside this
    module;
  * **scoped override** — ``with override(KernelPolicy(...)):`` installs a
    policy for the dynamic extent (contextvar, so async/thread safe), and
    every ``policy=`` keyword threaded through ``kernels/ops.py`` /
    ``core/probe.py`` takes a per-call override on top.

Resolution order (first hit wins — DESIGN.md §14):

    per-call ``policy=``  >  ``override(...)`` context  >  environment

Exact env semantics (kept bit-for-bit from the pre-consolidation readers;
the CI matrix relies on ``REPRO_PALLAS_INTERPRET=''`` meaning *interpret*):

    enabled   = REPRO_PALLAS_DISABLE  in ("", "0")   (default "0")
    interpret = REPRO_PALLAS_INTERPRET != "0"        (default "1")
    prefer    = REPRO_PALLAS_PREFER   not in ("","0") (default "0")

Platform setup (``xla_force_host_platform_device_count``) lives here too so
launch scripts have one import that owns every process-level knob.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import os

__all__ = [
    "KernelPolicy", "policy_from_env", "current_policy", "override",
    "DEFAULT_VMEM_LIMIT", "PAGED_PACK_LIMIT", "force_host_devices",
    "bench_tiny", "set_bench_tiny", "backend", "device_kind", "backend_key",
    "PEAK_FLOPS", "peak_flops",
]

# int32 elements kept fully VMEM-resident (bsearch prefix tables, the
# fused-GET arena, and the fused-draw scratch share this budget — see
# DESIGN.md §9; ``kernels/ops.py`` re-exports it as VMEM_PREF_LIMIT).
DEFAULT_VMEM_LIMIT = 1 << 21

# Ceiling on the *total* size of a paged index arena (int32 elements,
# DESIGN.md §15): an arena bigger than the VMEM budget is still packed —
# page-sliced and streamed through VMEM by the paged kernels — up to this
# cap, past which the int32 copy stops paying for itself and the per-node
# int64 path stands (a 2^25-element arena is 128 MiB of extra HBM).
PAGED_PACK_LIMIT = 1 << 25


@dataclasses.dataclass(frozen=True)
class KernelPolicy:
    """How Pallas kernels are selected, everywhere (DESIGN.md §14).

    enabled     master switch: False routes every wrapper through its
                pure-XLA/jnp fallback (the operator escape hatch for a
                kernel bug; historical ``REPRO_PALLAS_DISABLE=1``).
    interpret   run kernels in Pallas interpret mode (the validated mode
                on this CPU container; False = compiled mode on real TPU).
    prefer      prefer kernels over their XLA twins inside jitted hot
                paths even in interpret mode (the CI interpret leg pins
                this so the whole tier-1 suite exercises the kernels).
    vmem_limit  int32-element budget for VMEM-resident tables (prefix
                vectors, the packed index arena, fused-draw scratch).
                Arenas above it no longer drop to the per-node path:
                they run the *paged* rung (DESIGN.md §15) as long as
                every page fits this budget.
    fused_draw  allow the one-launch fused draw route (kernels/fused_draw)
                when capability gates pass; False pins the multi-launch
                per-node path without touching GET kernel selection.
    tuned       resolve kernel tile shapes through the committed
                ``kernels/TUNE_TABLE.json`` (per backend + problem-size
                bucket, DESIGN.md §15); False pins every kernel's builtin
                default tile (the pre-autotuner behavior).
    tile_overrides
                per-kernel tile pins that win over the tuning table: a
                tuple of ``(kernel_name, value)`` pairs (tuple-of-pairs —
                not a dict — so the policy stays hashable), e.g.
                ``(("tree_probe", 16), ("flash_prefill", (128, 256)))``.
    """

    enabled: bool = True
    interpret: bool = True
    prefer: bool = False
    vmem_limit: int = DEFAULT_VMEM_LIMIT
    fused_draw: bool = True
    tuned: bool = True
    tile_overrides: tuple = ()

    @property
    def preferred(self) -> bool:
        """Should jitted hot paths *prefer* Pallas kernels over their XLA
        twins when both are available? True in compiled mode (real TPU —
        the kernels are the point); in interpret mode the interpreter's
        per-access overhead loses to XLA inside an already-jitted
        executor, so hot paths default to XLA unless ``prefer`` pins the
        kernel path. Capability gates (``enabled``, dtype/VMEM fallbacks)
        still apply on top."""
        return self.enabled and (self.prefer or not self.interpret)

    def tile_override(self, kernel: str):
        """The pinned tile for ``kernel`` from ``tile_overrides``, or
        ``None`` — the first (highest-precedence) rung of the tile
        resolution ladder in ``kernels/autotune.tile_for``."""
        for name, value in self.tile_overrides:
            if name == kernel:
                return value
        return None


def policy_from_env() -> KernelPolicy:
    """The default policy, parsed from the environment *at call time* (so
    tests and CI legs can flip a var without re-importing anything). The
    parse of each variable is exactly the historical reader's — in
    particular ``REPRO_PALLAS_INTERPRET=''`` still means interpret=True
    (the CI matrix sets the empty string on non-interpret legs)."""
    env = os.environ.get
    return KernelPolicy(
        enabled=env("REPRO_PALLAS_DISABLE", "0") in ("", "0"),
        interpret=env("REPRO_PALLAS_INTERPRET", "1") != "0",
        prefer=env("REPRO_PALLAS_PREFER", "0") not in ("", "0"),
    )


_override: "contextvars.ContextVar[KernelPolicy]" = contextvars.ContextVar(
    "repro_kernel_policy", default=None)


def current_policy(policy: KernelPolicy = None) -> KernelPolicy:
    """Resolve the active policy: per-call ``policy=`` > ``override(...)``
    context > environment defaults (DESIGN.md §14)."""
    if policy is not None:
        return policy
    installed = _override.get()
    return installed if installed is not None else policy_from_env()


@contextlib.contextmanager
def override(policy: KernelPolicy):
    """Install ``policy`` for the dynamic extent of the ``with`` block::

        with repro.config.override(KernelPolicy(prefer=True)):
            plan = engine.compile(query)   # binds the fused routes

    Contextvar-scoped: concurrent threads/tasks see their own override.
    Note plans capture routing verdicts at *bind* time — a policy change
    after ``compile()`` does not rewire an existing plan (recompile, or
    let ``DrawSpec.kernels`` pin the route as plan identity)."""
    token = _override.set(policy)
    try:
        yield policy
    finally:
        _override.reset(token)


# ---------------------------------------------------------------------------
# Platform setup (process-level, owned here so launch scripts import one
# module for every knob; launch/mesh.py delegates).
# ---------------------------------------------------------------------------

def force_host_devices(n: int) -> int:
    """Ask XLA for ``n`` virtual host (CPU) devices; returns the count
    actually available. Only effective before the backend initializes —
    appends ``--xla_force_host_platform_device_count`` to ``XLA_FLAGS``
    and reports (rather than raises) when the backend beat us to it, so
    callers degrade to the real device count."""
    import sys

    import jax

    if n > 1:
        flags = os.environ.get("XLA_FLAGS", "")
        flag = f"--xla_force_host_platform_device_count={n}"
        if "--xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = f"{flags} {flag}".strip()
    got = len(jax.devices())
    if got < n:
        print(f"[mesh] requested {n} host devices, backend has {got} "
              f"(already initialized, or XLA_FLAGS pre-set); using {got}",
              file=sys.stderr)
    return got


# ---------------------------------------------------------------------------
# Backend detection (DESIGN.md §15). Centralized here so every kernel-
# selection seam (paged-probe DMA variant, tuning-table lookup, roofline
# peaks) asks the same question the same way; jax is imported lazily so
# stdlib-only tools (benchmarks/roofline.py aggregation) can import this
# module without pulling the runtime in.
# ---------------------------------------------------------------------------

def backend() -> str:
    """The active execution substrate: ``'tpu'`` | ``'gpu'`` | ``'cpu'``
    (``jax.default_backend()``). The paged tree-probe picks its streaming
    strategy off this (TPU: in-kernel double-buffered DMA; GPU/CPU: the
    portable per-page launch path — no ``pltpu``-only primitives), and the
    tuning table keys its entries off ``backend_key()``."""
    import jax

    return jax.default_backend()


def device_kind() -> str:
    """Normalized device-kind slug of device 0 (e.g. ``'tpu-v5e'``,
    ``'nvidia-h100'``, ``'cpu'``) — the second half of ``backend_key()``,
    so tuning entries distinguish device generations within a backend."""
    import jax

    kind = jax.devices()[0].device_kind
    return "-".join(str(kind).lower().split())


def backend_key() -> str:
    """``'<backend>/<device-kind>'`` — the tuning-table entry key for this
    process (DESIGN.md §15), e.g. ``'cpu/cpu'`` or ``'tpu/tpu-v5e'``."""
    return f"{backend()}/{device_kind()}"


# Peak dense-math FLOP/s per backend (bf16-class units), the denominator of
# the roofline fraction (benchmarks/roofline.py). 197e12 is the documented
# TPU default this repo has always modeled (v5e-class bf16); the GPU and
# CPU rows are representative single-device figures (A100-class bf16
# tensor-core peak; a ~32-core AVX-512 host), good for bottleneck
# *classification*, not for absolute MFU claims.
PEAK_FLOPS = {
    "tpu": 197e12,
    "gpu": 312e12,
    "cpu": 2e12,
}


def peak_flops(backend_name: str = None) -> float:
    """Peak FLOP/s for ``backend_name`` (default: the detected backend).
    Unknown names fall back to the TPU row — the historical constant, so
    pre-existing dry-run records keep their ratios."""
    if backend_name is None:
        backend_name = backend()
    return PEAK_FLOPS.get(backend_name, PEAK_FLOPS["tpu"])


# ---------------------------------------------------------------------------
# Bench-smoke flag (the only other REPRO_* variable; centralizing the read
# and the write here keeps the check_env lint trivially green).
# ---------------------------------------------------------------------------

def bench_tiny() -> bool:
    """True in bench-smoke mode (``benchmarks.run --tiny``): suites shrink
    their workloads so CI exercises every path in seconds."""
    return os.environ.get("REPRO_BENCH_TINY", "") not in ("", "0")


def set_bench_tiny(on: bool = True) -> None:
    """Flip bench-smoke mode for this process (and subprocesses). Set via
    env because suites size their workloads at module/run scope, possibly
    in spawned workers that inherit the environment."""
    if on:
        os.environ["REPRO_BENCH_TINY"] = "1"
    else:
        os.environ.pop("REPRO_BENCH_TINY", None)
