"""Version compatibility shims for the pinned container toolchain.

The repo targets current jax APIs but must also run on the container's
older release (no ``jax.shard_map``, no ``jax.sharding.AxisType``) — the
rule is gate, don't vendor: each shim forwards to the modern API when
present and falls back to the documented equivalent otherwise.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map", "axis_size", "OLDEST_SUPPORTED_JAX"]

# The oldest jax release the shims below are exercised against — the
# pinned container toolchain. CI's test matrix runs one leg on exactly
# this version (and one on latest) so shim drift is caught before users
# hit it; bump this in lockstep with the container image.
OLDEST_SUPPORTED_JAX = "0.4.37"


def axis_size(name):
    """``jax.lax.axis_size`` when available, else the psum(1) identity."""
    import jax.lax

    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` when available, else the experimental spelling
    (whose ``check_rep`` is the old name of ``check_vma``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
