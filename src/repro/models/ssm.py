"""Attention-free sequence mixers: Mamba2 (SSD, chunked) and RWKV6 (Finch).

Both are O(S) in sequence length with O(1)-per-token decode state — which is
exactly why the assignment's long_500k shape runs only for these families
(DESIGN.md §Arch-applicability).

Mamba2: the SSD chunked algorithm (intra-chunk quadratic + inter-chunk state
scan) with scalar-per-head decay A, depthwise causal conv on (x, B, C), and
a gated output — faithful to arXiv 2405.21060's minimal SSD formulation.

RWKV6 "Finch": data-dependent per-channel decay w_t = exp(-exp(...)) via a
low-rank (LoRA) projection of the token-shifted input, matrix-valued state
S_h (hd x hd) per head, bonus u for the current token, plus the squared-ReLU
channel mix. Train path is a lax.scan over time; decode is one state update.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import Initializer, Params, dtype_of, rms_norm

# ---------------------------------------------------------------------------
# Mamba2 (SSD)
# ---------------------------------------------------------------------------

_CONV_K = 4
_SSD_CHUNK = 256


def init_mamba(ini: Initializer, path: str, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    din = cfg.ssm_expand * d
    H = cfg.ssm_heads or max(din // 64, 1)
    n = cfg.ssm_state
    return {
        # in_proj emits [z (din), x (din), B (n), C (n), dt (H)]
        "in_proj": ini.normal(f"{path}/in_proj", (d, 2 * din + 2 * n + H)),
        "conv_w": ini.normal(f"{path}/time_conv_w", (_CONV_K, din + 2 * n), scale=0.5),
        "A_log": ini.zeros(f"{path}/time_A_log", (H,)),
        "D": ini.ones(f"{path}/time_D", (H,)),
        "dt_bias": ini.zeros(f"{path}/time_dt_bias", (H,)),
        "out_proj": ini.normal(f"{path}/out_proj", (din, d)),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, state=None):
    """Depthwise causal conv, kernel K. x: (B,S,C); w: (K,C).
    state: (B, K-1, C) tail of the previous sequence (decode)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i: i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    return out, xp[:, -(K - 1):, :]


def _ssd_chunked(xh, dt, B, C, A, chunk: int):
    """SSD: y_t = C_t^T sum_{s<=t} (prod decay) B_s (dt_s x_s).

    xh: (Bt, S, H, hd); dt: (Bt, S, H); B, C: (Bt, S, n); A: (H,) negative.
    Returns y (Bt, S, H, hd) and final state (Bt, H, hd, n).
    """
    Bt, S, H, hd = xh.shape
    n = B.shape[-1]
    pad = (-S) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    nC = (S + pad) // chunk
    xh = xh.reshape(Bt, nC, chunk, H, hd)
    dt = dt.reshape(Bt, nC, chunk, H)
    B = B.reshape(Bt, nC, chunk, n)
    C = C.reshape(Bt, nC, chunk, n)

    da = dt * A[None, None, None, :]                 # (Bt,nC,c,H) negative
    cum = jnp.cumsum(da, axis=2)                     # within-chunk cumulative

    # intra-chunk (quadratic in chunk): L[i,j] = exp(cum_i - cum_j) (i >= j)
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]        # (Bt,nC,c,c,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(li), 0.0)
    # scores: (C_i . B_j) * L[i,j] * dt_j
    CB = jnp.einsum("bkin,bkjn->bkij", C, B)                  # (Bt,nC,c,c)
    W = CB[..., None] * L * dt[:, :, None, :, :]              # (Bt,nC,i,j,H)
    y_intra = jnp.einsum("bkijh,bkjhd->bkihd", W, xh)

    # inter-chunk: carry state (H, hd, n)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)           # (Bt,nC,c,H)
    chunk_in = jnp.einsum("bkch,bkchd,bkcn->bkhdn",
                          dt * decay_to_end, xh, B)           # state contribution
    chunk_decay = jnp.exp(jnp.sum(da, axis=2))                # (Bt,nC,H)

    def body(state, inp):
        cin, cdec, Cc, cumc = inp   # state: (Bt,H,hd,n)
        y_in = jnp.einsum("bcn,bhdn,bch->bchd", Cc, state, jnp.exp(cumc))
        state = state * cdec[:, :, None, None] + cin
        return state, y_in

    state0 = jnp.zeros((Bt, H, hd, n), jnp.float32)
    state, y_inter = jax.lax.scan(
        body, state0,
        (chunk_in.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         chunk_decay.transpose(1, 0, 2),
         C.transpose(1, 0, 2, 3),
         cum.transpose(1, 0, 2, 3)))
    y = y_intra + y_inter.transpose(1, 0, 2, 3, 4)
    y = y.reshape(Bt, S + pad, H, hd)[:, :S]
    return y, state


def mamba_mixer(p: Params, x, cfg: ModelConfig, decode_cache: Dict = None,
                ) -> Tuple[jnp.ndarray, Dict]:
    """x: (B,S,d). Returns (y, new_cache). Cache: conv tail + ssm state."""
    dt_ = dtype_of(cfg.compute_dtype)
    B_, S, d = x.shape
    din = cfg.ssm_expand * d
    H = cfg.ssm_heads or max(din // 64, 1)
    hd = din // H
    n = cfg.ssm_state

    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["in_proj"].astype(dt_))
    z, xin, Bv, Cv, dt = jnp.split(
        zxbcdt, [din, 2 * din, 2 * din + n, 2 * din + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xin, Bv, Cv], axis=-1)
    conv_state = None if decode_cache is None else decode_cache["conv"]
    conv_out, conv_tail = _causal_conv(conv_in, p["conv_w"].astype(dt_), conv_state)
    conv_out = jax.nn.silu(conv_out)
    xin, Bv, Cv = jnp.split(conv_out, [din, din + n], axis=-1)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    xh = xin.reshape(B_, S, H, hd).astype(jnp.float32)

    if decode_cache is None:
        y, state = _ssd_chunked(xh, dt, Bv.astype(jnp.float32),
                                Cv.astype(jnp.float32), A, _SSD_CHUNK)
    else:
        # one-step recurrence: S' = S * exp(dt*A) + dt * B x^T ; y = C . S'
        state = decode_cache["state"]
        da = jnp.exp(dt[:, 0] * A[None, :])                       # (B,H)
        upd = jnp.einsum("bh,bhd,bn->bhdn", dt[:, 0], xh[:, 0],
                         Bv[:, 0].astype(jnp.float32))
        state = state * da[:, :, None, None] + upd
        y = jnp.einsum("bn,bhdn->bhd", Cv[:, 0].astype(jnp.float32), state)[:, None]

    y = y + xh * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B_, S, din).astype(dt_) * jax.nn.silu(z)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"].astype(dt_))
    return out, {"conv": conv_tail.astype(jnp.float32), "state": state}


def init_mamba_cache(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    din = cfg.ssm_expand * d
    H = cfg.ssm_heads or max(din // 64, 1)
    hd = din // H
    n = cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, _CONV_K - 1, din + 2 * n), jnp.float32),
        "state": jnp.zeros((batch, H, hd, n), jnp.float32),
    }


# ---------------------------------------------------------------------------
# RWKV6 (Finch)
# ---------------------------------------------------------------------------

_LORA = 64


def init_rwkv(ini: Initializer, path: str, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    return {
        "time_mix": ini.normal(f"{path}/time_mix_lerp", (5, d), scale=0.02),
        "time_decay_w0": ini.zeros(f"{path}/time_decay_w0", (d,)),
        "time_decay_a": ini.normal(f"{path}/time_decay_a", (d, _LORA), scale=0.02),
        "time_decay_b": ini.normal(f"{path}/time_decay_b", (_LORA, d), scale=0.02),
        "time_bonus": ini.zeros(f"{path}/time_bonus_u", (d,)),
        "wr": ini.normal(f"{path}/wq", (d, d)),
        "wk": ini.normal(f"{path}/wk", (d, d)),
        "wv": ini.normal(f"{path}/wv", (d, d)),
        "wg": ini.normal(f"{path}/w_gate", (d, d)),
        "wo": ini.normal(f"{path}/wo", (d, d)),
        "chan_mix": ini.normal(f"{path}/chan_mix_lerp", (2, d), scale=0.02),
        "chan_k": ini.normal(f"{path}/w_up", (d, 7 * d // 2)),
        "chan_v": ini.normal(f"{path}/w_down", (7 * d // 2, d)),
    }


def _wkv6_scan(r, k, v, w, u, state0):
    """r,k,v: (B,S,H,hd); w: (B,S,H,hd) decays in (0,1); u: (H,hd).
    state: (B,H,hd,hd)   out_t = (S + u*k_t (x) v_t)^T r_t ; S' = w*S + k (x) v
    """
    def body(state, inp):
        rt, kt, vt, wt = inp  # (B,H,hd)
        kv = kt[..., :, None] * vt[..., None, :]            # (B,H,hd,hd)
        full = state + u[None, :, :, None] * kv
        out = jnp.einsum("bhk,bhkv->bhv", rt, full)
        state = state * wt[..., :, None] + kv
        return state, out

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (r, k, v, w))
    state, out = jax.lax.scan(body, state0, xs)
    return out.transpose(1, 0, 2, 3), state


def rwkv_time_mix(p: Params, x, cfg: ModelConfig, cache: Dict = None):
    dt_ = dtype_of(cfg.compute_dtype)
    B, S, d = x.shape
    hd = cfg.rwkv_head_dim
    H = d // hd
    prev = (jnp.concatenate([jnp.zeros((B, 1, d), x.dtype), x[:, :-1]], axis=1)
            if cache is None else
            jnp.concatenate([cache["shift_t"][:, None].astype(x.dtype), x[:, :-1]], axis=1))
    mix = p["time_mix"].astype(jnp.float32)

    def lerp(i):
        m = mix[i][None, None, :]
        return (x.astype(jnp.float32) * (1 - m) + prev.astype(jnp.float32) * m).astype(dt_)

    r = jnp.einsum("bsd,dk->bsk", lerp(0), p["wr"].astype(dt_)).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,dk->bsk", lerp(1), p["wk"].astype(dt_)).reshape(B, S, H, hd)
    v = jnp.einsum("bsd,dk->bsk", lerp(2), p["wv"].astype(dt_)).reshape(B, S, H, hd)
    g = jnp.einsum("bsd,dk->bsk", lerp(3), p["wg"].astype(dt_))
    # data-dependent decay (Finch): w = exp(-exp(w0 + lora(x)))
    dd = jnp.einsum("bsd,dl,le->bse", lerp(4).astype(jnp.float32),
                    p["time_decay_a"].astype(jnp.float32),
                    p["time_decay_b"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(p["time_decay_w0"].astype(jnp.float32)[None, None] + dd))
    w = w.reshape(B, S, H, hd)
    u = p["time_bonus"].astype(jnp.float32).reshape(H, hd)

    state0 = (jnp.zeros((B, H, hd, hd), jnp.float32) if cache is None
              else cache["state"])
    out, state = _wkv6_scan(r.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32), w, u, state0)
    out = (out.reshape(B, S, d) * jax.nn.silu(g.astype(jnp.float32))).astype(dt_)
    y = jnp.einsum("bsd,dk->bsk", out, p["wo"].astype(dt_))
    new_cache = {"shift_t": x[:, -1].astype(jnp.float32), "state": state}
    return y, new_cache


def rwkv_channel_mix(p: Params, x, cfg: ModelConfig, cache: Dict = None):
    dt_ = dtype_of(cfg.compute_dtype)
    B, S, d = x.shape
    prev = (jnp.concatenate([jnp.zeros((B, 1, d), x.dtype), x[:, :-1]], axis=1)
            if cache is None else
            jnp.concatenate([cache["shift_c"][:, None].astype(x.dtype), x[:, :-1]], axis=1))
    mix = p["chan_mix"].astype(jnp.float32)

    def lerp(i):
        m = mix[i][None, None, :]
        return (x.astype(jnp.float32) * (1 - m) + prev.astype(jnp.float32) * m).astype(dt_)

    k = jnp.einsum("bsd,df->bsf", lerp(0), p["chan_k"].astype(dt_))
    k = jnp.square(jax.nn.relu(k))
    y = jnp.einsum("bsf,fd->bsd", k, p["chan_v"].astype(dt_))
    return y, {"shift_c": x[:, -1].astype(jnp.float32)}


def init_rwkv_cache(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    H = d // hd
    return {
        "shift_t": jnp.zeros((batch, d), jnp.float32),
        "shift_c": jnp.zeros((batch, d), jnp.float32),
        "state": jnp.zeros((batch, H, hd, hd), jnp.float32),
    }
