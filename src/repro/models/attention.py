"""Attention blocks: GQA self-attention (full / windowed causal /
bidirectional), cross-attention, and single-token decode.

Train / prefill use *blockwise attention*: a lax.scan over KV chunks with an
online softmax — O(S * chunk) live memory instead of the O(S^2) score
matrix, which is what makes the 32k-prefill shapes compile within HBM and is
the pure-JAX twin of the Pallas flash_decode kernel (kernels/flash_decode.py
is the TPU fast path for the decode case; the XLA path here is what the
dry-run lowers, since interpret-mode Pallas would unroll its grid into HLO).

Decode uses a dense masked einsum over the KV cache: with one query token
the score tensor is (B, H, T) — tiny — so chunking buys nothing.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (Initializer, Params, dtype_of, rope, shard_batch,
                     shard_batch_seq)

NEG_INF = -1e30


def init_attention(ini: Initializer, path: str, cfg: ModelConfig,
                   cross: bool = False) -> Params:
    d, hd = cfg.d_model, cfg.hd
    H, KV = cfg.n_heads, cfg.n_kv_heads
    p = {
        "wq": ini.normal(f"{path}/wq", (d, H * hd)),
        "wk": ini.normal(f"{path}/wk", (d, KV * hd)),
        "wv": ini.normal(f"{path}/wv", (d, KV * hd)),
        "wo": ini.normal(f"{path}/wo", (H * hd, d)),
    }
    if cross:
        p["c_wq"] = ini.normal(f"{path}/c_wq", (d, H * hd))
        p["c_wk"] = ini.normal(f"{path}/c_wk", (d, KV * hd))
        p["c_wv"] = ini.normal(f"{path}/c_wv", (d, KV * hd))
        p["c_wo"] = ini.normal(f"{path}/c_wo", (H * hd, d))
    return p


def _project_qkv(p, x, cfg: ModelConfig, prefix: str = ""):
    dt = dtype_of(cfg.compute_dtype)
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, p[prefix + "wq"].astype(dt)).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,dh->bsh", x, p[prefix + "wk"].astype(dt)).reshape(B, S, KV, hd)
    v = jnp.einsum("bsd,dh->bsh", x, p[prefix + "wv"].astype(dt)).reshape(B, S, KV, hd)
    return q, k, v


def blockwise_attention(
    q: jnp.ndarray,            # (B, S, H, hd)
    k: jnp.ndarray,            # (B, T, KV, hd)
    v: jnp.ndarray,            # (B, T, KV, hd)
    q_pos: jnp.ndarray,        # (S,) absolute positions of queries
    kv_pos: jnp.ndarray,       # (T,)
    *,
    causal: bool,
    window: int = 0,
    chunk: int = 1024,
    seq_shard: bool = False,
    head_shard: bool = False,
    probs_bf16: bool = False,
) -> jnp.ndarray:
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = hd ** -0.5
    acc_dt = jnp.bfloat16 if probs_bf16 else jnp.float32
    if seq_shard:
        # sequence-parallel attention: queries sharded on the model axis,
        # K/V replicated across it — no sharded-contraction psums.
        q = shard_batch_seq(q, 1)
        k = shard_batch(k)
        v = shard_batch(v)
    if head_shard:
        # GQA group-parallel attention (§Perf H1): shard the per-KV-group
        # query-head dim G over "model" (llama3-405b: G=16 == axis size),
        # replicate K/V (tiny: KV heads only). Scores/probs/PV stay local;
        # the only collective left is wo's standard row-parallel psum.
        # Heads are interpreted g-MAJOR so the TP projection's contiguous
        # column shards coincide exactly with G blocks — the constraint is
        # then a no-op relabeling, not a reshard (this exact mismatch cost
        # 5.6TB of involuntary all-gathers in H1 attempt 2; see §Perf).
        qg = (q.reshape(B, S, G, KV, hd).transpose(0, 1, 3, 2, 4)
              .astype(acc_dt) * scale)
        from .layers import _BATCH_AXES, _SEQ_AXIS
        if _BATCH_AXES and _SEQ_AXIS:
            from jax.sharding import PartitionSpec as P
            qg = jax.lax.with_sharding_constraint(
                qg, P(_BATCH_AXES, None, None, _SEQ_AXIS, None))
            k = shard_batch(k)
            v = shard_batch(v)
    else:
        qg = q.reshape(B, S, KV, G, hd).astype(acc_dt) * scale

    chunk = min(chunk, T)
    pad = (-T) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, pad), constant_values=-10**9)
    nC = (T + pad) // chunk
    ks = k.reshape(B, nC, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nC, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    ps = kv_pos.reshape(nC, chunk)

    # NOTE the inner checkpoint: without it the chunk scan saves the (S x
    # chunk) probability tensors of EVERY chunk for backward — O(S*T) live
    # memory, the exact blow-up blockwise attention exists to avoid. With it
    # the backward recomputes each chunk's probs from (q, k-chunk) — the
    # flash-attention recompute schedule.
    @jax.checkpoint
    def body(carry, inp):
        m, l, acc = carry
        kc, vc, pc = inp
        s = jnp.einsum("bskgh,bckh->bskgc", qg, kc.astype(acc_dt)
                       ).astype(jnp.float32)
        valid = pc[None, :] >= 0 if not causal else pc[None, :] <= q_pos[:, None]
        valid = jnp.logical_and(valid, pc[None, :] >= 0)
        if window > 0:
            valid = jnp.logical_and(valid, pc[None, :] > q_pos[:, None] - window)
        s = jnp.where(valid[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        prob = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(prob, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bskgc,bckh->bskgh", prob.astype(acc_dt), vc.astype(acc_dt)
            ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, S, KV, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, S, KV, G), jnp.float32)
    a0 = jnp.zeros((B, S, KV, G, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (ks, vs, ps))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    if head_shard:  # back to the g-major flattened layout wo expects
        out = out.transpose(0, 1, 3, 2, 4)  # (B,S,G,KV,hd)
    return out.reshape(B, S, H, hd).astype(q.dtype)


def self_attention(
    p: Params, x, cfg: ModelConfig, positions, *, causal: bool = True,
    window: int = 0,
) -> jnp.ndarray:
    """Full-sequence self attention (train / prefill)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg)
    q = rope(q, positions[None, :], cfg.rope_theta)
    k = rope(k, positions[None, :], cfg.rope_theta)
    out = blockwise_attention(q, k, v, positions, positions, causal=causal,
                              window=window, chunk=cfg.attn_chunk,
                              seq_shard=cfg.attn_seq_shard,
                              head_shard=cfg.attn_head_shard,
                              probs_bf16=cfg.attn_probs_bf16)
    dt = dtype_of(cfg.compute_dtype)
    out = out.reshape(B, S, -1)
    if cfg.attn_seq_shard:
        out = shard_batch(out)  # gather S back before the row-parallel wo
    return jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(dt)), (k, v)


def cross_attention(p: Params, x, memory_kv, cfg: ModelConfig) -> jnp.ndarray:
    """x attends to a precomputed (k, v) of the encoder memory."""
    dt = dtype_of(cfg.compute_dtype)
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    mk, mv = memory_kv  # (B, M, KV, hd)
    M = mk.shape[1]
    q = jnp.einsum("bsd,dh->bsh", x, p["c_wq"].astype(dt)).reshape(B, S, H, hd)
    pos_q = jnp.arange(S)
    pos_m = jnp.arange(M)
    out = blockwise_attention(q, mk, mv, pos_q, pos_m, causal=False,
                              chunk=cfg.attn_chunk)
    return jnp.einsum("bsh,hd->bsd", out.reshape(B, S, -1), p["c_wo"].astype(dt))


def memory_kv(p: Params, memory, cfg: ModelConfig):
    """Project encoder memory once (prefill) for later cross attention."""
    dt = dtype_of(cfg.compute_dtype)
    B, M, _ = memory.shape
    KV, hd = cfg.n_kv_heads, cfg.hd
    mk = jnp.einsum("bmd,dh->bmh", memory, p["c_wk"].astype(dt)).reshape(B, M, KV, hd)
    mv = jnp.einsum("bmd,dh->bmh", memory, p["c_wv"].astype(dt)).reshape(B, M, KV, hd)
    return mk, mv


def decode_self_attention(
    p: Params, x, cfg: ModelConfig, cache: Dict[str, jnp.ndarray], cur: jnp.ndarray,
    *, window: int = 0,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One-token decode with KV cache update.

    cache: {"k","v"} of shape (B, T, KV, hd); cur = current length (scalar).
    Dense masked einsum — (B, H, T) scores; see module docstring.
    """
    dt = dtype_of(cfg.compute_dtype)
    B, S, _ = x.shape
    assert S == 1
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    G = H // KV
    q, k, v = _project_qkv(p, x, cfg)
    pos = jnp.full((1,), cur, jnp.int32)
    q = rope(q, pos[None, :], cfg.rope_theta)
    k = rope(k, pos[None, :], cfg.rope_theta)
    zero = jnp.zeros((), jnp.int32)
    cur32 = jnp.asarray(cur, jnp.int32)
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (zero, cur32, zero, zero))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (zero, cur32, zero, zero))
    T = ck.shape[1]
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bkgh,btkh->bkgt", qg, ck.astype(jnp.float32))
    tpos = jnp.arange(T)
    valid = tpos <= cur
    if window > 0:
        valid = jnp.logical_and(valid, tpos > cur - window)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkh->bkgh", w, cv.astype(jnp.float32))
    out = out.reshape(B, 1, H * hd).astype(x.dtype)
    y = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(dt))
    return y, {"k": ck, "v": cv}


def decode_cross_attention(p: Params, x, cfg: ModelConfig, cache) -> jnp.ndarray:
    """Decode-time cross attention against cached memory KV."""
    dt = dtype_of(cfg.compute_dtype)
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    G = H // KV
    q = jnp.einsum("bsd,dh->bsh", x, p["c_wq"].astype(dt)).reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,bmkh->bkgm", q.astype(jnp.float32) * hd ** -0.5,
                   cache["ck"].astype(jnp.float32))
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgm,bmkh->bkgh", w, cache["cv"].astype(jnp.float32))
    out = out.reshape(B, 1, H * hd).astype(x.dtype)
    return jnp.einsum("bsh,hd->bsd", out, p["c_wo"].astype(dt))
