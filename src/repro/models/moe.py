"""Mixture-of-Experts FFN (llama4-scout 16e top-1 + shared expert;
olmoe 64e top-8).

Default layout is **TP-MoE**: every expert's d_ff is sharded over the
"model" axis (weights (E, d, ff) -> P(None, None, "model")), so the expert
GEMMs are column/row-parallel like a dense FFN and no all-to-all is needed;
tokens stay sharded on batch. Dispatch uses sort + jax.lax.ragged_dot —
tokens grouped per expert by ONE argsort, then a grouped GEMM; no (N, E, C)
one-hot dispatch tensors.

The **EP-MoE** variant (experts partitioned over "model", dense per-shard
compute + psum combine) is exposed via ``ep=True`` for the §Perf collective
study: it trades the TP all-reduces for expert-local compute with a combine
psum; the dry-run measures both schedules.

An auxiliary load-balancing loss (Switch-style) is returned alongside.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import Initializer, Params, dtype_of


def init_moe(ini: Initializer, path: str, cfg: ModelConfig) -> Params:
    d, ff, E = cfg.d_model, cfg.moe_dff, cfg.n_experts
    p = {
        "router": ini.normal(f"{path}/router", (d, E), scale=0.02),
        "experts_gate": ini.normal(f"{path}/experts_gate", (E, d, ff)),
        "experts_up": ini.normal(f"{path}/experts_up", (E, d, ff)),
        "experts_down": ini.normal(f"{path}/experts_down", (E, ff, d)),
    }
    if cfg.shared_expert_dff:
        sf = cfg.shared_expert_dff
        p["shared_gate"] = ini.normal(f"{path}/w_gate", (d, sf))
        p["shared_up"] = ini.normal(f"{path}/w_up", (d, sf))
        p["shared_down"] = ini.normal(f"{path}/w_down", (sf, d))
    return p


def moe_ffn(p: Params, x: jnp.ndarray, cfg: ModelConfig,
            ep: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out, aux_loss)."""
    dt = dtype_of(cfg.compute_dtype)
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.topk
    N = B * S
    xt = x.reshape(N, d)

    logits = jnp.einsum("nd,de->ne", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)        # (N, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # Switch aux loss: E * sum_e f_e * P_e
    density = jnp.mean(
        jax.nn.one_hot(expert_idx, E, dtype=jnp.float32).sum(axis=1), axis=0)
    aux = E * jnp.sum(density * jnp.mean(probs, axis=0))

    # --- sort + capacity-bucketed batched GEMM dispatch ---------------------
    # (was jax.lax.ragged_dot: under GSPMD it lowered to dense 8.4M-row dots
    # plus 22TB of copies on olmoe train_4k — §Perf H3. Bucketing into
    # (E, C, d) and running ONE batched einsum per projection is the
    # partitioner-friendly schedule; over-capacity tokens drop, standard
    # "dropped MoE" semantics with capacity factor 1.25.)
    C = int(-(-N * K * 125 // (E * 100)) // 1)              # ceil(1.25*N*K/E)
    C = max(((C + 127) // 128) * 128, 128)
    flat_expert = expert_idx.reshape(-1)                    # (N*K,)
    order = jnp.argsort(flat_expert)                        # stable enough
    sorted_e = jnp.take(flat_expert, order)
    counts = jnp.bincount(flat_expert, length=E)            # (E,)
    start = jnp.searchsorted(sorted_e, jnp.arange(E))       # group starts
    slot = start[:, None] + jnp.arange(C)[None, :]          # (E, C)
    in_cap = jnp.arange(C)[None, :] < jnp.minimum(counts, C)[:, None]
    slot = jnp.clip(slot, 0, N * K - 1)
    src = jnp.take(order, slot)                             # flat assignment id
    token_of = src // K                                     # (E, C) source token
    xs = jnp.take(xt, token_of.reshape(-1), axis=0).astype(dt)
    xs = jnp.where(in_cap.reshape(-1, 1), xs, 0).reshape(E, C, d)

    g = jnp.einsum("ecd,edf->ecf", xs, p["experts_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", xs, p["experts_up"].astype(dt))
    h = jax.nn.silu(g) * u
    y = jnp.einsum("ecf,efd->ecd", h, p["experts_down"].astype(dt))

    gates_bucket = jnp.where(in_cap, jnp.take(gate_vals.reshape(-1), src), 0.0)
    contrib = y.astype(jnp.float32) * gates_bucket[..., None]
    out = jnp.zeros((N, d), jnp.float32).at[token_of.reshape(-1)].add(
        contrib.reshape(-1, d), mode="drop")

    if cfg.shared_expert_dff:
        sg = jnp.einsum("nd,df->nf", xt, p["shared_gate"].astype(dt))
        su = jnp.einsum("nd,df->nf", xt, p["shared_up"].astype(dt))
        out = out + jnp.einsum("nf,fd->nd", jax.nn.silu(sg) * su,
                               p["shared_down"].astype(dt)).astype(jnp.float32)

    return out.reshape(B, S, d).astype(x.dtype), aux
