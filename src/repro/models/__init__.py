"""repro.models — the 10 assigned architectures as one pattern-driven stack.

Public API:
    ModelConfig                       (config.py)
    init_model, forward, loss_fn,
    init_cache, decode_step, prefill, encode   (transformer.py)
    param_specs, shardings_for        (layers.py — sharding rules)
"""
from .config import ModelConfig  # noqa: F401
from .layers import param_specs, shardings_for  # noqa: F401
from .transformer import (  # noqa: F401
    decode_step, encode, forward, init_cache, init_model, loss_fn, prefill,
)
