"""Core neural building blocks (pure functions over dict params) and the
logical-axis sharding rules.

Params are nested dicts of jnp arrays. Sharding is assigned by pattern
matching on parameter *path names* (Megatron/MaxText-style logical rules):

    vocab axis      -> "model"   (embed / unembed tables)
    heads / d_ff    -> "model"   (column-parallel in, row-parallel out)
    experts' d_ff   -> "model"   (TP-MoE default; EP variant in moe.py)
    batch           -> ("pod", "data")
    everything else -> replicated

so tensor parallelism emerges from pjit constraint propagation: column-
parallel matmul -> activation sharded on features -> row-parallel matmul ->
psum, with no hand-written collectives in the model code.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .config import ModelConfig

Params = Dict[str, Any]


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

class Initializer:
    """Deterministic param init: each leaf gets a key folded from its path."""

    def __init__(self, key, param_dtype):
        self.key = key
        self.dtype = param_dtype

    def _k(self, path: str):
        h = np.uint32(abs(hash(path)) % (2 ** 31))
        return jax.random.fold_in(self.key, h)

    def normal(self, path: str, shape, scale: float = None):
        scale = scale if scale is not None else (1.0 / np.sqrt(shape[-2] if len(shape) > 1 else shape[-1]))
        return (jax.random.normal(self._k(path), shape, jnp.float32) * scale).astype(self.dtype)

    def zeros(self, path: str, shape):
        return jnp.zeros(shape, self.dtype)

    def ones(self, path: str, shape):
        return jnp.ones(shape, self.dtype)


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------

_MOE_EP = False


def set_moe_ep(flag: bool) -> None:
    global _MOE_EP
    _MOE_EP = bool(flag)


# (regex on param path, spec builder given ndim). "E" marks the stacked
# repeat axis added by the pattern-stack (always unsharded, leading).
# FSDP x TP: the tensor-parallel dim goes on "model"; the complementary dim
# is sharded over "data" (ZeRO-3/FSDP — weights, grads and moments are all
# fully sharded; XLA all-gathers each layer's weights per scan iteration).
_RULES = [
    (r"embed$",          lambda nd: ("model", "data")),
    (r"unembed$",        lambda nd: ("data", "model")),
    (r"(wq|wk|wv|wr|wg)$", lambda nd: ("data", "model")),
    (r"wo$",             lambda nd: ("model", "data")),
    (r"(w_up|w_gate)$",  lambda nd: ("data", "model")),
    (r"w_down$",         lambda nd: ("model", "data")),
    # TP-MoE default: expert ffn dim on "model". EP variant (_MOE_EP):
    # expert dim itself on "model" — set via set_moe_ep() before param_specs.
    (r"experts_(up|gate)$",
     lambda nd: ("model", "data", None) if _MOE_EP else (None, "data", "model")),
    (r"experts_down$",
     lambda nd: ("model", None, "data") if _MOE_EP else (None, "model", "data")),
    (r"router$",         lambda nd: (None, None)),
    (r"(in_proj|x_proj)$", lambda nd: ("data", "model")),
    (r"(out_proj)$",     lambda nd: ("model", "data")),
    (r"chan_k$",         lambda nd: ("data", "model")),
    (r"chan_v$",         lambda nd: ("model", "data")),
    (r"(time_decay_[ab])$", lambda nd: (None, None)),
    (r"(time_|chan_)\w*$", lambda nd: tuple(None for _ in range(nd))),
]


def spec_for_path(path: str, ndim: int, stacked: bool) -> P:
    body_nd = ndim - (1 if stacked else 0)
    for pat, fn in _RULES:
        if re.search(pat, path):
            spec = list(fn(body_nd))
            spec = spec[:body_nd] + [None] * (body_nd - len(spec))
            if stacked:
                spec = [None] + spec
            return P(*spec)
    return P(*([None] * ndim))


def param_specs(params: Params, prefix: str = "", stacked_keys=("blocks",)) -> Params:
    """Mirror the params tree with PartitionSpecs via the path rules."""

    def rec(node, path, stacked):
        if isinstance(node, dict):
            return {k: rec(v, f"{path}/{k}", stacked or k in stacked_keys)
                    for k, v in node.items()}
        return spec_for_path(path, node.ndim, stacked)

    return rec(params, prefix, False)


def shardings_for(params: Params, mesh) -> Params:
    from jax.sharding import NamedSharding
    return jax.tree.map(lambda s: NamedSharding(mesh, s), param_specs(params),
                        is_leaf=lambda x: isinstance(x, P))


def sanitize_pspecs(pspecs: Params, shapes: Params, mesh) -> Params:
    """Drop sharding on any dim not divisible by its mesh axes (e.g. whisper's
    51865 vocab on a 16-way axis) — rule-generated specs stay valid for every
    architecture."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fix(spec, shape_struct):
        dims = list(spec) + [None] * (shape_struct.ndim - len(spec))
        out = []
        for d, size in zip(dims, shape_struct.shape):
            axes = (d,) if isinstance(d, str) else tuple(d or ())
            prod = 1
            for a in axes:
                prod *= sizes.get(a, 1)
            out.append(d if (prod > 0 and size % prod == 0) else None)
        return P(*out)

    return jax.tree.map(fix, pspecs, shapes,
                        is_leaf=lambda x: isinstance(x, P))


# --- activation sharding constraints ---------------------------------------
# XLA's unconstrained propagation can replicate the batch through attention
# when weights are FSDP-sharded over "data" (measured: 16x redundant compute
# on smollm train_4k). Launchers register the mesh's batch axes here and the
# model pins every major activation to batch-sharded layout, exactly like
# MaxText's logical-axis constraints. No-op when unset (tests, CPU).
_BATCH_AXES: tuple = ()
_SEQ_AXIS: str = ""


def set_batch_axes(axes, seq_axis: str = "model") -> None:
    global _BATCH_AXES, _SEQ_AXIS
    _BATCH_AXES = tuple(axes)
    _SEQ_AXIS = seq_axis if axes else ""


def shard_batch(x, batch_dim: int = 0):
    if not _BATCH_AXES or x.ndim == 0 or x.shape[batch_dim] == 1:
        return x
    dims = [None] * x.ndim
    dims[batch_dim] = _BATCH_AXES
    return jax.lax.with_sharding_constraint(x, P(*dims))


def shard_batch_seq(x, seq_dim: int = 1):
    """Batch on (pod, data) AND sequence on the model axis — the
    sequence-parallel attention layout (queries partition freely; no
    TP contraction of head_dim => no per-chunk score psums)."""
    if not _BATCH_AXES or not _SEQ_AXIS or x.ndim < 2:
        return x
    dims = [None] * x.ndim
    if x.shape[0] > 1:
        dims[0] = _BATCH_AXES
    dims[seq_dim] = _SEQ_AXIS
    return jax.lax.with_sharding_constraint(x, P(*dims))


def shard_replicated_model(x, batch_dim: int = 0):
    """Batch-sharded, explicitly replicated elsewhere (e.g. KV tensors in
    sequence-parallel attention)."""
    return shard_batch(x, batch_dim)


# ---------------------------------------------------------------------------
# Primitive layers
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def gated_mlp(p: Params, x, cfg: ModelConfig):
    dt = dtype_of(cfg.compute_dtype)
    u = jnp.einsum("...d,df->...f", x, p["w_up"].astype(dt))
    if "w_gate" in p:  # SwiGLU (llama family)
        h = jnp.einsum("...d,df->...f", x, p["w_gate"].astype(dt))
        u = jax.nn.silu(h) * u
    else:              # plain GELU (starcoder2, whisper)
        u = jax.nn.gelu(u)
    return jnp.einsum("...f,fd->...d", u, p["w_down"].astype(dt))


def init_mlp(ini: Initializer, path: str, d: int, ff: int, gated: bool = True) -> Params:
    p = {
        "w_up": ini.normal(f"{path}/w_up", (d, ff)),
        "w_down": ini.normal(f"{path}/w_down", (ff, d)),
    }
    if gated:
        p["w_gate"] = ini.normal(f"{path}/w_gate", (d, ff))
    return p


def init_norm(ini: Initializer, path: str, d: int) -> Params:
    return {"scale": ini.zeros(f"{path}/scale", (d,))}


def cross_entropy_loss(logits, targets, mask=None, z_loss: float = 1e-4):
    """Causal-LM loss, fp32, with optional z-loss; logits may be sharded on
    vocab (the log-softmax reduction stays einsum-friendly)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * lse ** 2
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
