"""Model assembly: pattern-stacked decoder (all 10 families) + optional
encoder (whisper), with train forward, prefill, and one-token decode.

The layer stack is ``lax.scan`` over the repeat axis of the block pattern —
one trace of the pattern regardless of depth (llama3-405b's 126 layers
compile as a 126-iteration loop over one 1-layer body), which keeps HLO and
compile time flat across architectures. Shared (tied) blocks — zamba2's
shared attention — live outside the scanned pytree and close over the body.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .config import ModelConfig
from .layers import (Initializer, Params, cross_entropy_loss, dtype_of,
                     gated_mlp, init_mlp, init_norm, rms_norm, shard_batch,
                     shard_batch_seq)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

class _Stacked(Initializer):
    """Adds a leading repeats axis to every parameter (for lax.scan)."""

    def __init__(self, base: Initializer, repeats: int):
        self.base, self.R = base, repeats
        self.dtype = base.dtype

    def normal(self, path, shape, scale=None):
        outs = [self.base.normal(f"{path}~{r}", shape, scale) for r in range(self.R)]
        return jnp.stack(outs)

    def zeros(self, path, shape):
        return jnp.zeros((self.R,) + tuple(shape), self.dtype)

    def ones(self, path, shape):
        return jnp.ones((self.R,) + tuple(shape), self.dtype)


def _init_block(ini, path: str, btype: str, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    p: Params = {"norm1": init_norm(ini, f"{path}/norm1", d)}
    if btype in ("dense", "local", "enc", "moe"):
        p["attn"] = attn.init_attention(ini, f"{path}/attn", cfg)
        p["norm2"] = init_norm(ini, f"{path}/norm2", d)
        if btype == "moe":
            p["moe"] = moe_mod.init_moe(ini, f"{path}/moe", cfg)
        else:
            p["mlp"] = init_mlp(ini, f"{path}/mlp", d, cfg.d_ff, cfg.mlp_gated)
    elif btype == "cross":
        p["attn"] = attn.init_attention(ini, f"{path}/attn", cfg, cross=True)
        p["norm_c"] = init_norm(ini, f"{path}/norm_c", d)
        p["norm2"] = init_norm(ini, f"{path}/norm2", d)
        p["mlp"] = init_mlp(ini, f"{path}/mlp", d, cfg.d_ff, cfg.mlp_gated)
    elif btype == "rwkv":
        p["rwkv_t"] = ssm_mod.init_rwkv(ini, f"{path}/rwkv", cfg)
        p["norm2"] = init_norm(ini, f"{path}/norm2", d)
    elif btype == "mamba":
        p["mamba"] = ssm_mod.init_mamba(ini, f"{path}/mamba", cfg)
        if cfg.mamba_mlp:
            p["norm2"] = init_norm(ini, f"{path}/norm2", d)
            p["mlp"] = init_mlp(ini, f"{path}/mlp", d, cfg.d_ff, cfg.mlp_gated)
    elif btype == "shared_attn":
        pass  # tied params live at params["shared"]
    else:
        raise ValueError(btype)
    return p


def init_model(cfg: ModelConfig, key) -> Params:
    ini = Initializer(key, dtype_of(cfg.param_dtype))
    stacked = _Stacked(ini, cfg.repeats)
    params: Params = {
        "embed": ini.normal("embed", (cfg.vocab, cfg.d_model), scale=0.02),
        "final_norm": init_norm(ini, "final_norm", cfg.d_model),
        "blocks": {
            f"p{i}": _init_block(stacked, f"blocks/p{i}", bt, cfg)
            for i, bt in enumerate(cfg.pattern)
        },
    }
    if not cfg.tie_embeddings:
        params["unembed"] = ini.normal("unembed", (cfg.d_model, cfg.vocab), scale=0.02)
    if "shared_attn" in cfg.pattern:
        params["shared"] = {
            "norm1": init_norm(ini, "shared/norm1", cfg.d_model),
            "attn": attn.init_attention(ini, "shared/attn", cfg),
            "norm2": init_norm(ini, "shared/norm2", cfg.d_model),
            "mlp": init_mlp(ini, "shared/mlp", cfg.d_model, cfg.d_ff, cfg.mlp_gated),
        }
    if cfg.has_encoder:
        assert cfg.enc_d_model == cfg.d_model, "bridge projection unsupported"
        enc_stack = _Stacked(ini, cfg.enc_layers)
        params["encoder"] = {
            "blocks": {"p0": _init_block(enc_stack, "enc/p0", "enc", cfg)},
            "final_norm": init_norm(ini, "enc/final_norm", cfg.d_model),
        }
    return params


# ---------------------------------------------------------------------------
# Train / prefill forward
# ---------------------------------------------------------------------------

def _apply_block(btype: str, bp: Params, h, cfg: ModelConfig, positions,
                 memory, shared: Optional[Params], aux: Dict[str, Any],
                 causal: bool = True):
    eps = cfg.norm_eps
    if btype == "shared_attn":
        bp, btype_eff = shared, "dense"
    else:
        btype_eff = btype

    if btype_eff in ("dense", "local", "enc", "moe"):
        window = cfg.window if btype == "local" else 0
        y, _kv = attn.self_attention(bp["attn"], rms_norm(h, bp["norm1"]["scale"], eps),
                                     cfg, positions, causal=btype_eff != "enc",
                                     window=window)
        h = h + y
        if btype_eff == "moe":
            y, a = moe_mod.moe_ffn(bp["moe"], rms_norm(h, bp["norm2"]["scale"], eps), cfg)
            aux["moe_aux"] = aux.get("moe_aux", 0.0) + a
        else:
            y = gated_mlp(bp["mlp"], rms_norm(h, bp["norm2"]["scale"], eps), cfg)
        return h + y
    if btype_eff == "cross":
        y, _ = attn.self_attention(bp["attn"], rms_norm(h, bp["norm1"]["scale"], eps),
                                   cfg, positions, causal=True)
        h = h + y
        mkv = attn.memory_kv(bp["attn"], memory, cfg)
        h = h + attn.cross_attention(bp["attn"],
                                     rms_norm(h, bp["norm_c"]["scale"], eps), mkv, cfg)
        return h + gated_mlp(bp["mlp"], rms_norm(h, bp["norm2"]["scale"], eps), cfg)
    if btype_eff == "rwkv":
        y, _ = ssm_mod.rwkv_time_mix(bp["rwkv_t"], rms_norm(h, bp["norm1"]["scale"], eps), cfg)
        h = h + y
        y, _ = ssm_mod.rwkv_channel_mix(bp["rwkv_t"], rms_norm(h, bp["norm2"]["scale"], eps), cfg)
        return h + y
    if btype_eff == "mamba":
        y, _ = ssm_mod.mamba_mixer(bp["mamba"], rms_norm(h, bp["norm1"]["scale"], eps), cfg)
        h = h + y
        if cfg.mamba_mlp:
            h = h + gated_mlp(bp["mlp"], rms_norm(h, bp["norm2"]["scale"], eps), cfg)
        return h
    raise ValueError(btype)


def _segment_factor(r: int, hint: int) -> int:
    """Inner segment length for two-level remat: a divisor of r near
    sqrt(r) (or the config hint if it divides r)."""
    if hint and r % hint == 0:
        return hint
    target = max(int(r ** 0.5), 1)
    for delta in range(r):
        for cand in (target + delta, target - delta):
            if 1 <= cand <= r and r % cand == 0:
                return cand
    return 1


def _stack_forward(blocks: Params, h, cfg: ModelConfig, positions, memory,
                   shared, aux_out: Dict[str, Any], pattern=None, causal=True):
    pattern = pattern or cfg.pattern

    pin = shard_batch_seq if cfg.residual_seq_shard else shard_batch
    # residual_seq_shard: the residual stream is sequence-sharded over the
    # model axis between blocks (Megatron sequence parallelism) — XLA then
    # lowers every row-parallel psum as reduce-scatter + all-gather, halving
    # TP ring traffic and sharding all norms/residual math (§Perf H1).

    def body(carry, rep_params):
        hh, aux_acc = carry
        aux: Dict[str, Any] = {}
        for i, bt in enumerate(pattern):
            hh = pin(hh)
            hh = _apply_block(bt, rep_params[f"p{i}"], hh, cfg, positions,
                              memory, shared, aux, causal=causal)
        aux_acc = aux_acc + aux.get("moe_aux", 0.0)
        return (pin(hh), aux_acc), None

    carry0 = (h, jnp.zeros((), jnp.float32))
    if cfg.remat == "segments":
        # Two-level (sqrt-L) checkpointing: only R/seg carries are saved
        # across the outer scan; each segment's inner carries are recomputed
        # during backward. O(sqrt(L)) live activations instead of O(L) —
        # what lets llama3-405b train_4k fit a v5e pod.
        R = jax.tree.leaves(blocks)[0].shape[0]
        seg = _segment_factor(R, cfg.remat_segment)
        seg_blocks = jax.tree.map(
            lambda x: x.reshape((R // seg, seg) + x.shape[1:]), blocks)

        @jax.checkpoint
        def seg_body(carry, seg_params):
            c, _ = jax.lax.scan(body, carry, seg_params)
            return c, None

        (h, moe_aux), _ = jax.lax.scan(seg_body, carry0, seg_blocks)
    else:
        if cfg.remat == "full":
            body = jax.checkpoint(body, prevent_cse=False)
        (h, moe_aux), _ = jax.lax.scan(body, carry0, blocks)
    aux_out["moe_aux"] = moe_aux
    return h


def encode(params: Params, cfg: ModelConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """Whisper-style encoder over (stub) precomputed frame embeddings."""
    enc = params["encoder"]
    S = frames.shape[1]
    aux: Dict[str, Any] = {}
    h = _stack_forward(enc["blocks"], frames.astype(dtype_of(cfg.compute_dtype)),
                       cfg, jnp.arange(S), None, None, aux,
                       pattern=("enc",), causal=False)
    return rms_norm(h, enc["final_norm"]["scale"], cfg.norm_eps)


def forward(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            memory: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray, Dict]:
    """Full-sequence forward -> (logits, aux). memory: stub modality tokens
    (B, M, d) for VLM cross-attn, or encoder output for whisper."""
    dt = dtype_of(cfg.compute_dtype)
    B, S = tokens.shape
    h = shard_batch(jnp.take(params["embed"], tokens, axis=0).astype(dt))
    positions = jnp.arange(S)
    aux: Dict[str, Any] = {}
    if memory is not None:
        memory = memory.astype(dt)
    h = _stack_forward(params["blocks"], h, cfg, positions, memory,
                       params.get("shared"), aux)
    h = shard_batch(rms_norm(h, params["final_norm"]["scale"], cfg.norm_eps))
    unembed = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    logits = shard_batch(jnp.einsum("bsd,dv->bsv", h, unembed.astype(dt)))
    return logits, aux


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]):
    """batch: tokens (B,S) int32, targets (B,S) int32, optional mask (B,S),
    optional memory/frames for VLM & whisper."""
    memory = batch.get("memory")
    if cfg.has_encoder and "frames" in batch:
        memory = encode(params, cfg, batch["frames"])
    logits, aux = forward(params, cfg, batch["tokens"], memory)
    loss = cross_entropy_loss(logits, batch["targets"], batch.get("mask"))
    if cfg.is_moe:
        loss = loss + 0.01 * aux.get("moe_aux", 0.0) / max(cfg.repeats, 1)
    return loss, {"loss": loss}


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               n_memory: int = 0) -> Params:
    """Decode cache, stacked (repeats, ...) per pattern position."""
    KV, hd = cfg.n_kv_heads, cfg.hd
    cdt = dtype_of(cfg.compute_dtype)

    def one(btype):
        if btype in ("dense", "local", "moe", "shared_attn"):
            return {"k": jnp.zeros((batch, max_len, KV, hd), cdt),
                    "v": jnp.zeros((batch, max_len, KV, hd), cdt)}
        if btype == "cross":
            return {"k": jnp.zeros((batch, max_len, KV, hd), cdt),
                    "v": jnp.zeros((batch, max_len, KV, hd), cdt),
                    "ck": jnp.zeros((batch, max(n_memory, 1), KV, hd), cdt),
                    "cv": jnp.zeros((batch, max(n_memory, 1), KV, hd), cdt)}
        if btype == "rwkv":
            return ssm_mod.init_rwkv_cache(cfg, batch)
        if btype == "mamba":
            return ssm_mod.init_mamba_cache(cfg, batch)
        raise ValueError(btype)

    return {
        f"p{i}": jax.tree.map(lambda x: jnp.broadcast_to(x, (cfg.repeats,) + x.shape),
                              one(bt))
        for i, bt in enumerate(cfg.pattern)
    }


def _decode_block(btype: str, bp, h, cfg, cache, cur, shared):
    eps = cfg.norm_eps
    if btype == "shared_attn":
        bp, btype = shared, "dense"
    new_cache = dict(cache)
    if btype in ("dense", "local", "moe"):
        window = cfg.window if btype == "local" else 0
        y, kv = attn.decode_self_attention(
            bp["attn"], rms_norm(h, bp["norm1"]["scale"], eps), cfg, cache, cur,
            window=window)
        new_cache.update(kv)
        h = h + y
        if btype == "moe":
            y, _ = moe_mod.moe_ffn(bp["moe"], rms_norm(h, bp["norm2"]["scale"], eps), cfg)
        else:
            y = gated_mlp(bp["mlp"], rms_norm(h, bp["norm2"]["scale"], eps), cfg)
        return h + y, new_cache
    if btype == "cross":
        y, kv = attn.decode_self_attention(
            bp["attn"], rms_norm(h, bp["norm1"]["scale"], eps), cfg, cache, cur)
        new_cache.update(kv)
        h = h + y
        h = h + attn.decode_cross_attention(
            bp["attn"], rms_norm(h, bp["norm_c"]["scale"], eps), cfg, cache)
        return h + gated_mlp(bp["mlp"], rms_norm(h, bp["norm2"]["scale"], eps), cfg), new_cache
    if btype == "rwkv":
        y, c1 = ssm_mod.rwkv_time_mix(bp["rwkv_t"], rms_norm(h, bp["norm1"]["scale"], eps),
                                      cfg, cache)
        h = h + y
        y, c2 = ssm_mod.rwkv_channel_mix(bp["rwkv_t"], rms_norm(h, bp["norm2"]["scale"], eps),
                                         cfg, cache)
        new_cache.update(c1)
        new_cache.update(c2)
        return h + y, new_cache
    if btype == "mamba":
        y, c1 = ssm_mod.mamba_mixer(bp["mamba"], rms_norm(h, bp["norm1"]["scale"], eps),
                                    cfg, cache)
        new_cache.update(c1)
        h = h + y
        if cfg.mamba_mlp:
            h = h + gated_mlp(bp["mlp"], rms_norm(h, bp["norm2"]["scale"], eps), cfg)
        return h, new_cache
    raise ValueError(btype)


def decode_step(params: Params, cfg: ModelConfig, cache: Params,
                tokens: jnp.ndarray, cur) -> Tuple[jnp.ndarray, Params]:
    """One decode step. tokens: (B, 1); cur: scalar current length."""
    dt = dtype_of(cfg.compute_dtype)
    h = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    shared = params.get("shared")

    def body(hh, inp):
        rep_params, rep_cache = inp
        new_caches = {}
        for i, bt in enumerate(cfg.pattern):
            hh, nc = _decode_block(bt, rep_params[f"p{i}"], hh, cfg,
                                   rep_cache[f"p{i}"], cur, shared)
            new_caches[f"p{i}"] = nc
        return hh, new_caches

    h, new_cache = jax.lax.scan(body, h, (params["blocks"], cache))
    h = rms_norm(h, params["final_norm"]["scale"], cfg.norm_eps)
    unembed = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    logits = jnp.einsum("bsd,dv->bsv", h, unembed.astype(dt)).astype(jnp.float32)
    return logits, new_cache


def prefill(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            max_len: int, memory: Optional[jnp.ndarray] = None):
    """Prefill via repeated decode for correctness tests (slow path), or use
    forward() when only logits are needed. Returns (logits_last, cache)."""
    B, S = tokens.shape
    n_mem = 0 if memory is None else memory.shape[1]
    cache = init_cache(cfg, B, max_len, n_mem)
    if memory is not None and any(b == "cross" for b in cfg.pattern):
        mdt = dtype_of(cfg.compute_dtype)
        # pre-project cross KV once per cross-block instance
        blocks = params["blocks"]
        for i, bt in enumerate(cfg.pattern):
            if bt != "cross":
                continue
            bp = blocks[f"p{i}"]
            mk = jnp.einsum("bmd,rdh->rbmh", memory.astype(mdt), bp["attn"]["c_wk"].astype(mdt))
            mv = jnp.einsum("bmd,rdh->rbmh", memory.astype(mdt), bp["attn"]["c_wv"].astype(mdt))
            R = mk.shape[0]
            M = memory.shape[1]
            cache[f"p{i}"]["ck"] = mk.reshape(R, B, M, cfg.n_kv_heads, cfg.hd)
            cache[f"p{i}"]["cv"] = mv.reshape(R, B, M, cfg.n_kv_heads, cfg.hd)

    def step(carry, t):
        cache, _ = carry
        logits, cache = decode_step(params, cfg, cache, tokens[:, t][:, None], t)
        return (cache, logits), None

    (cache, logits), _ = jax.lax.scan(step, (cache, jnp.zeros((B, 1, cfg.vocab),
                                                              jnp.float32)),
                                      jnp.arange(S))
    return logits, cache
