"""Model configuration for the 10 assigned architecture families.

One config drives all families through a per-layer *block pattern*: the
layer stack is ``repeats x pattern`` where each pattern entry names a block
type. Families map as:

    dense GQA          ("dense",)
    gemma3 local:global("local",)*5 + ("dense",)
    MoE                ("moe",)           (llama4 adds a shared expert)
    VLM cross-attn     ("dense",)*4 + ("cross",)
    whisper            encoder ("enc",)*L + decoder ("cross",)*L
    rwkv6              ("rwkv",)
    mamba2 hybrid      ("mamba",)*k + ("shared_attn",)  [zamba2: tied attn]

Block types:
  dense       causal GQA attention + gated MLP
  local       windowed causal attention + gated MLP
  cross       self attention + cross attention (encoder memory) + MLP
  enc         bidirectional attention + MLP (encoder only)
  moe         causal GQA attention + mixture-of-experts FFN
  rwkv        RWKV6 time mix + channel mix (attention-free)
  mamba       Mamba2 SSD mixer + gated MLP
  shared_attn like dense but parameters are TIED across repeats (zamba2)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

ATTN_BLOCKS = ("dense", "local", "cross", "enc", "moe", "shared_attn")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    pattern: Tuple[str, ...] = ("dense",)
    head_dim: Optional[int] = None          # default d_model // n_heads
    # attention
    rope_theta: float = 10_000.0
    window: int = 0                          # local attention window (tokens)
    # MoE
    n_experts: int = 0
    topk: int = 0
    moe_dff: int = 0
    shared_expert_dff: int = 0               # llama4 shared expert
    moe_ep: bool = False                     # EP: experts over model axis
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_expand: int = 2
    # RWKV
    rwkv_head_dim: int = 64
    # encoder (whisper) / modality stubs
    enc_layers: int = 0
    enc_d_model: int = 0
    enc_heads: int = 0
    enc_d_ff: int = 0
    n_memory_tokens: int = 0                 # stub vision/audio tokens
    mlp_gated: bool = True                   # False: 2-matrix GELU MLP
    mamba_mlp: bool = True                   # False: mamba blocks are pure mixers
    # numerics / misc
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    logical_batch_axes: Tuple[str, ...] = ("pod", "data")
    remat: str = "full"                      # "none" | "full" | "segments"
    remat_segment: int = 0                   # inner segment length (0 = ~sqrt)
    grad_accum: int = 1                      # microbatch accumulation factor
    opt_factored: bool = False               # Adafactor-style second moment
    attn_chunk: int = 1024                   # blockwise-attention KV chunk
    attn_seq_shard: bool = False             # sequence-parallel attention
    attn_head_shard: bool = False            # GQA group-parallel attention
    residual_seq_shard: bool = False         # SP residual stream (RS+AG TP)
    attn_probs_bf16: bool = False            # bf16 probability tensors
    # sub-quadratic capability flag (long_500k eligibility; see DESIGN.md)
    subquadratic: bool = False

    def __post_init__(self):
        assert self.n_layers % len(self.pattern) == 0, \
            f"{self.name}: {self.n_layers} layers not divisible by pattern {len(self.pattern)}"
        if self.n_heads:
            assert self.n_heads % max(self.n_kv_heads, 1) == 0

    @property
    def repeats(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def has_encoder(self) -> bool:
        return self.enc_layers > 0

    @property
    def is_moe(self) -> bool:
        return any(b == "moe" for b in self.pattern)

    def param_count(self) -> int:
        """Approximate parameter count N (for 6*N*D model FLOPs)."""
        d, hd = self.d_model, self.hd
        n = self.vocab * d * (1 if self.tie_embeddings else 2)
        per = {}
        nm = 3 if self.mlp_gated else 2
        per["dense"] = per["enc"] = (
            d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            + nm * d * self.d_ff)
        per["local"] = per["shared_attn"] = per["dense"]
        per["cross"] = per["dense"] + d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads \
            + self.n_heads * hd * d
        per["moe"] = (d * hd * (self.n_heads + 2 * self.n_kv_heads)
                      + self.n_heads * hd * d
                      + 3 * d * self.moe_dff * self.n_experts
                      + d * self.n_experts
                      + (3 * d * self.shared_expert_dff))
        din = d * self.ssm_expand
        per["mamba"] = (d * din * 2 + din * d + din * (2 * self.ssm_state)
                        + (nm * d * self.d_ff if self.mamba_mlp else 0))
        per["rwkv"] = 4 * d * d + d * d + 2 * d * (7 * d // 2)  # time mix + channel mix
        for b in self.pattern:
            n += per[b] * self.repeats
        if self.has_encoder:
            ed = self.enc_d_model
            n += self.enc_layers * (4 * ed * ed + 2 * ed * self.enc_d_ff)
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts) for 6*N_active*D."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        inactive = 3 * d * self.moe_dff * (self.n_experts - self.topk)
        return full - inactive * self.repeats * sum(b == "moe" for b in self.pattern)
