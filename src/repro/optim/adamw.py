"""AdamW (+ optional factored second moment), pure-pytree implementation."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"        # "bfloat16" halves optimizer HBM
    factored: bool = False               # Adafactor-style v for matrices


def _mdt(cfg: AdamWConfig):
    return jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32


def adamw_init(cfg: AdamWConfig, params):
    mdt = _mdt(cfg)

    def init_v(p):
        if cfg.factored and p.ndim >= 2:
            return {"vr": jnp.zeros(p.shape[:-1], mdt),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], mdt)}
        return jnp.zeros_like(p, mdt)

    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(lambda p: jnp.zeros_like(p, mdt), params),
        "v": jax.tree.map(init_v, params),
    }


def clip_by_global_norm(grads, max_norm: float):
    g2 = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(g2)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(cfg: AdamWConfig, params, grads, state, lr_scale=1.0):
    """One AdamW step. Returns (params, state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale
    mdt = _mdt(cfg)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * cfg.b1 + g32 * (1 - cfg.b1)
        if isinstance(v, dict):  # factored second moment
            vr = v["vr"].astype(jnp.float32) * cfg.b2 + jnp.mean(g32 * g32, axis=-1) * (1 - cfg.b2)
            vc = v["vc"].astype(jnp.float32) * cfg.b2 + jnp.mean(g32 * g32, axis=-2) * (1 - cfg.b2)
            vhat = (vr[..., None] * vc[..., None, :]) / jnp.maximum(
                jnp.mean(vr, axis=-1)[..., None, None], 1e-30)
            new_v = {"vr": vr.astype(mdt), "vc": vc.astype(mdt)}
        else:
            vhat = v.astype(jnp.float32) * cfg.b2 + g32 * g32 * (1 - cfg.b2)
            new_v = vhat.astype(mdt)
            vhat_b = vhat / bc2
            upd_ = (m32 / bc1) / (jnp.sqrt(vhat_b) + cfg.eps)
            newp = p.astype(jnp.float32) - lr * (upd_ + cfg.weight_decay * p.astype(jnp.float32))
            return newp.astype(p.dtype), m32.astype(mdt), new_v
        vhat_b = vhat / bc2
        upd_ = (m32 / bc1) / (jnp.sqrt(vhat_b) + cfg.eps)
        newp = p.astype(jnp.float32) - lr * (upd_ + cfg.weight_decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), m32.astype(mdt), new_v

    flat_p, tree = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    # v may contain dicts (factored); flatten at param granularity
    v_tree = state["v"]
    flat_v = tree.flatten_up_to(v_tree)

    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = tree.unflatten([o[0] for o in out])
    new_m = tree.unflatten([o[1] for o in out])
    new_v = tree.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_params, {"step": step, "m": new_m, "v": new_v}, metrics
