"""Optimizers + schedules (self-contained — no optax dependency).

AdamW with decoupled weight decay, global-norm clipping, bias correction,
configurable moment dtype (bf16 moments let llama3-405b train_4k fit one
v5e pod — DESIGN.md §5), and an Adafactor-style factored second moment
option for further memory pressure relief.

Under pjit the optimizer state pytree inherits each parameter's sharding
(ZeRO-3-equivalent: params, grads and moments are all fully sharded; there
is no separate replicated optimizer copy).
"""
from .adamw import AdamWConfig, adamw_init, adamw_update, clip_by_global_norm  # noqa: F401
from .schedule import warmup_cosine  # noqa: F401
