"""Distributed Poisson sampling: root partitioning and stacked-index build.

Why Poisson sampling scales embarrassingly well (and fixed-size sampling
does not): the join result is the disjoint union of the joins produced by
any partition of the ROOT relation's rows, and Poisson trials are
independent per tuple. So block-partitioning the root across devices and
sampling each block independently (with a device-folded PRNG key) is
*distributionally identical* to sampling globally — no coordination, no
rejection, one psum to report the global count. A fixed-k sampler would
instead need a global multivariate-hypergeometric split of k across shards.

This module is the *library* layer the engine's sharded path consumes
(DESIGN.md §8):

  * ``semijoin_filter``     — top-down pre-filter bounding the replicated
                              child relations by the root's join keys;
  * ``partition_root``      — block-partition the root with padding
                              (pad rows are weight-neutralized downstream);
  * ``build_stacked_shred`` — per-shard shredded indexes, all identical
                              shapes, stacked into one pytree with a
                              leading shard axis;
  * ``fold_shard_key``      — the device-folded PRNG key scheme.

``ShardedPoissonSampler`` is kept as a thin facade over
``repro.engine.sharding.ShardedPlan`` (the shard_map executors live there),
mirroring how ``core.PoissonSampler`` facades the single-device engine.
``launch/dryrun.py`` uses it for the paper's architecture on the
production meshes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from .database import Database
from .jointree import JoinQuery, JoinTreeNode
from .relations import Relation, dense_keys
from .shred import Shred, build_plan, build_shred, pack_index
from repro.compat import axis_size

__all__ = [
    "RootPartition", "StackedShred", "ShardedPoissonSampler",
    "partition_root", "semijoin_filter", "build_stacked_shred",
    "build_stacked", "reshard_incremental", "fold_shard_key",
]

I64 = jnp.int64


def fold_shard_key(key, axes: Tuple[str, ...]):
    """Device-distinct PRNG key inside shard_map: fold the linearized shard
    coordinate into ``key``. Shard ``s`` of the stacked index lands on the
    device with linearized coordinate ``s`` (P(axes) block layout), so a
    host-side loop over ``fold_in(key, s)`` reproduces the per-device keys
    bit-for-bit — the reproducibility contract tests and the engine's
    sharded path both rely on."""
    idx = jnp.zeros((), jnp.int32)
    for a in axes:
        idx = idx * axis_size(a) + jax.lax.axis_index(a)
    return jax.random.fold_in(key, idx)


def semijoin_filter(db: Database, query: JoinQuery) -> Database:
    """Top-down semijoin pre-filter: drop child rows that cannot join.

    Walks the (rerooted) join tree from the root, keeping in each child
    relation only the rows whose join key occurs in the parent's (already
    filtered) instance. A relation referenced by several atoms (self joins)
    keeps the union of the rows any alias needs. The root relation is never
    filtered — it is the partitioned side.

    Only dangling rows are removed, and the shred build retains dangling
    tuples with weight 0 anyway, so the join result *and every flat
    position* are unchanged (DESIGN.md §8); the filter just bounds the
    replicated child relations by the root's keys before the per-shard
    index builds.
    """
    plan = build_plan(query)
    keep: Dict[str, np.ndarray] = {}

    def visit(tnode: JoinTreeNode, parent_inst: Optional[Relation]) -> None:
        inst = db.instance_for(tnode.atom)
        if parent_inst is not None:
            shared = sorted(set(parent_inst.attrs) & set(inst.attrs))
            if shared and inst.num_rows and parent_inst.num_rows:
                kp, kc = dense_keys([parent_inst.column(v) for v in shared],
                                    [inst.column(v) for v in shared])
                mask = np.asarray(jnp.isin(kc, kp))
            else:  # cross product (or empty side): nothing to prune
                mask = np.ones((inst.num_rows,), bool)
            name = tnode.atom.relation
            keep[name] = mask if name not in keep else (keep[name] | mask)
            inst = inst.take(jnp.asarray(np.flatnonzero(mask)))
        for c in tnode.children:
            visit(c, inst)

    visit(plan, None)
    keep.pop(plan.atom.relation, None)  # the root is partitioned, not filtered
    rels = dict(db.relations)
    for name, mask in keep.items():
        rels[name] = db.relations[name].take(jnp.asarray(np.flatnonzero(mask)))
    return Database(rels, db.schemas)


@dataclasses.dataclass(frozen=True)
class RootPartition:
    """A block partition of the root relation into equal-sized shard dbs.

    ``shards[s]`` holds rows [s*rows_per_shard, (s+1)*rows_per_shard) of the
    root (short tail shards padded by repeating the last row); children are
    shared across shards. ``valid[s]`` counts the unpadded rows — the
    stacked build weight-neutralizes everything beyond it.
    """

    shards: Tuple[Database, ...]
    root_name: str
    rows_per_shard: int
    valid: Tuple[int, ...]


def partition_root(
    db: Database, query: JoinQuery, num_shards: int
) -> RootPartition:
    """Split the database into ``num_shards`` copies whose root-relation rows
    block-partition the original. Pad rows repeat the last row and get a
    zero probability when the query has a ``prob_var``; the stacked build
    additionally zeroes their weights, so pads contribute to neither
    samples nor full joins."""
    plan = build_plan(query)
    root_atom = plan.atom
    root_rel = db.relations[root_atom.relation]
    n = root_rel.num_rows
    per = -(-n // num_shards)  # 0 rows -> every shard empty (nothing to pad)
    prob_col = None
    if query.prob_var is not None:
        schema = db.schemas[root_atom.relation]
        for c, v in zip(schema, root_atom.variables):
            if v == query.prob_var:
                prob_col = c
    shards, valid = [], []
    for s in range(num_shards):
        lo, hi = min(s * per, n), min((s + 1) * per, n)
        idx = np.arange(lo, hi)
        if hi - lo < per:  # pad with last row, neutralized via p = 0 + w = 0
            idx = np.concatenate([idx, np.full(per - (hi - lo), max(n - 1, 0))])
        cols = {}
        for c, v in root_rel.columns.items():
            col = jnp.take(v, jnp.asarray(idx), axis=0)
            if c == prob_col and hi - lo < per:
                col = col.at[hi - lo:].set(0)
            cols[c] = col
        rels = dict(db.relations)
        rels[root_atom.relation] = Relation(cols)
        shards.append(Database(rels, db.schemas))
        valid.append(hi - lo)
    return RootPartition(tuple(shards), root_atom.relation, per, tuple(valid))


@dataclasses.dataclass
class StackedShred:
    """Per-shard shred indexes stacked into one pytree (leading dim S).

    This is what the engine's shred cache holds for a sharded plan, keyed
    by (query fingerprint, rep, mesh shape, shard count) — DESIGN.md §8.
    Pad rows carry weight 0, so ``prefE[s, -1]`` is the true per-shard join
    size and the shard flattens concatenate to exactly the global flatten.
    """

    shred: Shred                  # every leaf has a leading shard axis
    w: jnp.ndarray                # (S, n_root) int64 root weights, pads zeroed
    p: Optional[jnp.ndarray]      # (S, n_root) root probabilities, or None
    prefE: jnp.ndarray            # (S, n_root + 1) exclusive weight prefixes
    num_shards: int
    root_name: str
    valid: Tuple[int, ...]        # unpadded root rows per shard
    join_sizes: Tuple[int, ...]   # concrete per-shard |Q_s(db)|

    @property
    def join_size(self) -> int:
        """|Q(db)| — the shard join sizes sum to the global size exactly."""
        return int(sum(self.join_sizes))


def _build_one_shard(sdb: Database, query: JoinQuery, rep: str,
                     valid: int) -> Shred:
    """One shard's shred with pad rows weight-neutralized post-build."""
    sh = build_shred(sdb, query, rep=rep)
    n = sh.root.num_rows
    if valid < n:
        w = jnp.where(jnp.arange(n) < valid, sh.root.weight, 0)
        root = dataclasses.replace(sh.root, weight=w)
        prefE = jnp.concatenate([jnp.zeros((1,), I64), jnp.cumsum(w)])
        # Re-pack the fused-GET arena: it embeds root_prefE (DESIGN.md §4).
        packed, paged = pack_index(root, prefE)
        sh = Shred(root=root, root_prefE=prefE, rep=sh.rep,
                   packed=packed, paged=paged)
    return sh


def _stack_shards(built, part: RootPartition, query: JoinQuery,
                  num_shards: int) -> StackedShred:
    """Stack per-shard shreds (identical pytree shapes) into one pytree.

    The fused-GET arena (``Shred.packed``) stacks like any other leaf, but
    only when *every* shard packed one with the same layout — int32
    narrowing is per-shard, and a mixed verdict would be a treedef
    mismatch. Otherwise the stack drops the arenas and the sharded
    executors take the per-node path (the documented fallback ladder,
    DESIGN.md §4/§9)."""
    layouts = {(None if b.packed is None else b.packed.layout,
                None if b.paged is None else b.paged.layout) for b in built}
    if layouts != {(None, None)} and len(layouts) > 1:
        built = [dataclasses.replace(b, packed=None, paged=None)
                 for b in built]
    shred = jax.tree.map(lambda *xs: jnp.stack(xs), *built)
    w = jnp.stack([b.root.weight for b in built])
    pvar = query.prob_var
    p = (jnp.stack([b.root.data.column(pvar) for b in built])
         if pvar is not None else None)
    prefE = jnp.stack([b.root_prefE for b in built])
    return StackedShred(
        shred=shred, w=w, p=p, prefE=prefE, num_shards=num_shards,
        root_name=part.root_name, valid=part.valid,
        join_sizes=tuple(int(b.root_prefE[-1]) for b in built),
    )


def build_stacked(
    db: Database, query: JoinQuery, num_shards: int, rep: str = "usr",
    prefilter: bool = True,
) -> Tuple[StackedShred, Database]:
    """Build ``num_shards`` identical-shape shred indexes and stack them;
    also returns the (semijoin-filtered) base database the shards were cut
    from — the anchor ``reshard_incremental`` diffs against (DESIGN.md §11).

    Children are semijoin-prefiltered once (shared by all shards), the root
    is block-partitioned, and pad rows are weight-zeroed post-build so they
    are invisible to sampling *and* flattening. All shards share one pytree
    structure, so the stack is shard_map-able with in_specs P(axes) on the
    leading dimension.
    """
    base = semijoin_filter(db, query) if prefilter else db
    part = partition_root(base, query, num_shards)
    built = [_build_one_shard(sdb, query, rep, part.valid[s])
             for s, sdb in enumerate(part.shards)]
    return _stack_shards(built, part, query, num_shards), base


def build_stacked_shred(
    db: Database, query: JoinQuery, num_shards: int, rep: str = "usr",
    prefilter: bool = True,
) -> StackedShred:
    """``build_stacked`` without the base handle (API-stable entry point)."""
    return build_stacked(db, query, num_shards, rep=rep,
                         prefilter=prefilter)[0]


def _relations_equal(a, b) -> bool:
    """Value equality of two relations (column names, dtypes, data)."""
    if set(a.columns) != set(b.columns):
        return False
    for c in a.columns:
        x, y = a.columns[c], b.columns[c]
        if x is not y and (
                x.dtype != y.dtype or x.shape != y.shape
                or not bool(jnp.array_equal(x, y))):
            return False
    return True


def reshard_incremental(
    stacked: StackedShred, base: Database, db_new: Database,
    query: JoinQuery, num_shards: int, rep: str = "usr",
) -> Tuple[StackedShred, Database, int, int]:
    """Advance a stacked index to a new snapshot, re-building only shards
    whose inputs changed (DESIGN.md §11).

    ``base`` is the filtered base ``build_stacked`` returned for the old
    snapshot. The new snapshot is re-filtered and re-partitioned (linear
    scans; the expensive part of a shard build is the per-shard sort-based
    grouping, which is what reuse avoids); a shard is reused verbatim when
    every child relation and its slice of the root block are value-equal.
    Deltas that shift the root partition (row-count changes) or touch the
    filtered children rebuild the affected shards — bit-identical to a
    from-scratch ``build_stacked`` either way.

    Returns ``(stacked_new, base_new, shards_reused, shards_rebuilt)``.
    """
    base_new = semijoin_filter(db_new, query)
    part_new = partition_root(base_new, query, num_shards)
    root_atom = build_plan(query).atom
    # Only the query's own child relations feed the per-shard builds: a
    # delta that also touches unrelated relations (other tenants' tables)
    # must not defeat shard reuse.
    child_rels = {a.relation for a in query.atoms} - {stacked.root_name}
    children_same = (num_shards == stacked.num_shards) and all(
        _relations_equal(base.relations[name], base_new.relations[name])
        for name in child_rels)

    built, reused = [], 0
    old_root_data = stacked.shred.root.data  # columns have leading shard dim
    for s, sdb in enumerate(part_new.shards):
        can_reuse = (
            children_same
            and part_new.valid[s] == stacked.valid[s]
            and _relations_equal(
                sdb.instance_for(root_atom),
                Relation({v: col[s]
                          for v, col in old_root_data.columns.items()}))
        )
        if can_reuse:  # slice the full per-shard tree only for actual reuse
            sh = jax.tree.map(lambda x, s=s: x[s], stacked.shred)
            if sh.packed is None and sh.paged is None:
                # The stack may have dropped the arenas (a mixed per-shard
                # narrowing verdict in an earlier epoch); re-pack so a reused
                # shard carries exactly what a from-scratch build would —
                # otherwise packed=None would propagate through every future
                # reuse and the fused/paged path would be lost until a rebind.
                packed, paged = pack_index(sh.root, sh.root_prefE)
                sh = dataclasses.replace(sh, packed=packed, paged=paged)
            built.append(sh)
            reused += 1
        else:
            built.append(_build_one_shard(sdb, query, rep, part_new.valid[s]))
    return (_stack_shards(built, part_new, query, num_shards), base_new,
            reused, num_shards - reused)


class ShardedPoissonSampler:
    """Data-parallel Poisson sampling over a device mesh.

    Facade over the engine's sharded path (``repro.engine.sharding``): one
    stacked index, shard_map'd per-step sampling with device-folded keys.
    Kept for API stability and the dry-run entry; new code should call
    ``QueryEngine.sample(..., mesh=...)`` so indexes are cached across
    queries (DESIGN.md §8).
    """

    def __init__(
        self,
        db: Database,
        query: JoinQuery,
        mesh: Mesh,
        axes: Tuple[str, ...] = ("data",),
        rep: str = "usr",
        method: str = "exprace",
    ):
        # Lazy: repro.engine imports repro.core (same pattern as poisson.py).
        from repro.engine import QueryEngine

        self.mesh = mesh
        self.axes = axes
        self.rep = "usr" if rep == "both" else rep
        self.method = method
        self.engine = QueryEngine(db, rep=rep)
        self._plan = self.engine.compile_sharded(
            query, mesh, axes=axes, rep=rep, method=method)
        self.num_shards = self._plan.num_shards
        self.root_name = self._plan.stacked.root_name
        self.shred = self._plan.stacked.shred
        self.w = self._plan.stacked.w
        self.p = self._plan.stacked.p
        self.prefE = self._plan.stacked.prefE
        self.cap = self._plan.cap
        self.acap = self._plan.acap

    def sample_step(self, key):
        """One independent global Poisson sample. Returns the sharded
        JoinSample (leading dim = shards) and the global count."""
        return self._plan.sample_step(key)

    # -- dry-run support -----------------------------------------------------
    def lower_step(self):
        return self._plan.lower_step()
