"""Multi-pod distributed Poisson sampling (shard_map).

Why Poisson sampling scales embarrassingly well (and fixed-size sampling
does not): the join result is the disjoint union of the joins produced by
any partition of the ROOT relation's rows, and Poisson trials are
independent per tuple. So block-partitioning the root across devices and
sampling each block independently (with a device-folded PRNG key) is
*distributionally identical* to sampling globally — no coordination, no
rejection, one psum to report the global count. A fixed-k sampler would
instead need a global multivariate-hypergeometric split of k across shards.

Layout:
  * root relation rows: block-partitioned over the ("pod", "data") axes
    (pad to a multiple of the shard count with weight-0 rows);
  * child relations: replicated (they are the small dimension tables in the
    paper's workloads; a semijoin pre-filter bounds them by the root's keys);
  * per-shard shredded index built once (stacked pytree, leading shard dim);
  * per-step: shard_map(sample) -> per-shard positions/columns + counts.

The same module also exposes the dry-run entry used by launch/dryrun.py for
the paper's own "architecture" on the production meshes.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import estimate, probe, sampling
from .database import Database
from .jointree import Atom, JoinQuery
from .poisson import JoinSample
from .relations import Relation
from .shred import Shred, build_shred
from repro.compat import axis_size, shard_map

__all__ = ["ShardedPoissonSampler", "partition_root"]

I64 = jnp.int64


def partition_root(
    db: Database, query: JoinQuery, num_shards: int
) -> Tuple[Sequence[Database], str]:
    """Split the database into ``num_shards`` copies whose root-relation rows
    block-partition the original (padded with repeat-last rows that are
    weight-neutralized by a zero probability). Children are replicated."""
    from .shred import build_plan

    plan = build_plan(query)
    root_atom = plan.atom
    root_rel = db.relations[root_atom.relation]
    n = root_rel.num_rows
    per = -(-n // num_shards)
    pad = per * num_shards - n
    prob_col = None
    if query.prob_var is not None:
        schema = db.schemas[root_atom.relation]
        for c, v in zip(schema, root_atom.variables):
            if v == query.prob_var:
                prob_col = c
    shards = []
    for s in range(num_shards):
        lo, hi = s * per, min((s + 1) * per, n)
        idx = np.arange(lo, hi)
        if hi - lo < per:  # pad with last row, neutralized via p = 0
            idx = np.concatenate([idx, np.full(per - (hi - lo), max(n - 1, 0))])
        cols = {}
        for c, v in root_rel.columns.items():
            col = jnp.take(v, jnp.asarray(idx), axis=0)
            if c == prob_col and hi - lo < per:
                col = col.at[hi - lo:].set(0)
            cols[c] = col
        rels = dict(db.relations)
        rels[root_atom.relation] = Relation(cols)
        shards.append(Database(rels, db.schemas))
    return shards, root_atom.relation


class ShardedPoissonSampler:
    """Data-parallel Poisson sampling over a device mesh.

    Builds one shredded index per shard (all identical shapes), stacks them
    into a single pytree with a leading shard axis, and shard_maps the
    per-step sampler over the mesh's data-like axes.
    """

    def __init__(
        self,
        db: Database,
        query: JoinQuery,
        mesh: Mesh,
        axes: Tuple[str, ...] = ("data",),
        rep: str = "usr",
        method: str = "exprace",
    ):
        self.mesh = mesh
        self.axes = axes
        self.rep = "usr" if rep == "both" else rep
        self.method = method
        self.num_shards = int(np.prod([mesh.shape[a] for a in axes]))
        shards, self.root_name = partition_root(db, query, self.num_shards)

        built = [build_shred(sdb, query, rep=rep) for sdb in shards]
        self.shred = jax.tree.map(lambda *xs: jnp.stack(xs), *built)
        root = built[0].root
        pvar = query.prob_var
        self.w = jnp.stack([b.root.weight for b in built])
        self.p = jnp.stack([b.root.data.column(pvar) for b in built])
        self.prefE = jnp.stack([b.root_prefE for b in built])

        mean = float(sum(float(estimate.expected_sample_size(w, p))
                         for w, p in zip(self.w, self.p)) / self.num_shards)
        std = max(float(estimate.sample_std(self.w[0], self.p[0])), 1.0)
        self.cap = estimate.plan_capacity(mean, std)
        mass = float(estimate.exprace_arrival_mass(self.w[0], self.p[0]))
        self.acap = estimate.plan_capacity(mass * 1.1 + 8, mass**0.5)

        spec = P(axes)  # shard the leading (stacked) dim over the data axes
        self._sharded = jax.jit(
            shard_map(
                partial(self._local_sample, cap=self.cap, acap=self.acap,
                        rep=self.rep, method=self.method, axes=self.axes),
                mesh=mesh,
                in_specs=(spec, spec, spec, spec, P()),
                out_specs=(spec, P()),
                check_vma=False,
            )
        )

    @staticmethod
    def _local_sample(shred, w, p, prefE, key, *, cap, acap, rep, method, axes):
        # Fold the shard coordinate into the key: independent trials per shard.
        idx = jnp.zeros((), jnp.int32)
        for a in axes:
            idx = idx * axis_size(a) + jax.lax.axis_index(a)
        key = jax.random.fold_in(key, idx)
        # Drop the leading (stacked) singleton shard dim.
        shred, w, p, prefE = jax.tree.map(lambda x: x[0], (shred, w, p, prefE))
        # Lazy: the executor lives in repro.engine (which imports repro.core).
        from repro.engine.executors import _sample_jit

        s = _sample_jit(shred, w, p, prefE, key, cap=cap, rep=rep,
                        method=method, acap=acap)
        total = jax.lax.psum(s.count, axes)
        # Re-add the shard dim so out_specs can concatenate across shards.
        s = jax.tree.map(lambda x: x[None], s)
        return s, total

    def sample_step(self, key) -> Tuple[JoinSample, jnp.ndarray]:
        """One independent global Poisson sample. Returns the sharded
        JoinSample (leading dim = shards) and the global count."""
        return self._sharded(self.shred, self.w, self.p, self.prefE, key)

    # -- dry-run support -----------------------------------------------------
    def lower_step(self):
        key = jax.ShapeDtypeStruct((), jax.random.key(0).dtype)
        args = jax.eval_shape(lambda: (self.shred, self.w, self.p, self.prefE))
        return self._sharded.lower(*args, key)
