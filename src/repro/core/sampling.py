"""Position sampling (paper §5): BERN / GEO / BINOM / HYBRID and the
non-uniform (Poisson) liftings PTBERN / PTGEO / PTHYBRID, plus EXPRACE —
this repo's beyond-paper, fully-vectorized non-uniform sampler.

Static-shape discipline: every sampler returns a fixed-capacity position
vector plus (count, overflow). Capacity planning lives in estimate.py; on
overflow the caller re-draws with a larger capacity (poisson.py). Positions
use int64 (join sizes reach 1e10 in the paper's EpiQL workload) — core
enables jax x64 on import (see core/__init__.py).

Vmap-safety contract (DESIGN.md §10): ``exprace_positions`` and
``pt_bern_flat_positions`` draw randomness *only* from their PRNG key and
are built entirely from per-lane-deterministic primitives (elementwise
math, sort, cumsum, searchsorted — including the Pallas branchless-descent
searchsorted kernel, which is a fixed unrolled gather sequence and vmaps
by adding a grid dimension — scatter-with-drop) — no host callbacks,
no data-dependent shapes, no cross-lane reductions. ``jax.vmap`` over the
key argument (weights/probabilities/prefixes broadcast) therefore yields,
lane for lane, the *bit-identical* sample a standalone call under that key
produces. The engine's batched multi-draw executor
(``engine/executors.batched_sample_executor``) and the sharded batched
path rely on this; ``tests/test_batched_engine.py`` asserts it for both
methods, both representations, and under a device mesh. Keep new sampler
code inside this envelope (in particular: no ``jax.lax.cond`` whose
branches have key-dependent side conditions on shapes, no host-side
``int(...)``/``float(...)`` of traced values).

EXPRACE (beyond paper, DESIGN.md §3) — exact non-uniform Poisson sampling as
a *thinned Poisson process*, with no sequential per-root loop:

  A Bernoulli(p) trial per unit cell is equivalent to "a Poisson process with
  rate lambda = -ln(1-p) drops >= 1 arrival in the cell" (P[>=1] = 1-e^-lam
  = p; disjoint cells independent). Over all root segments this is ONE
  inhomogeneous Poisson process with piecewise-constant rate, total mass
  Lam = sum_t w_t * lambda_t. Sample it directly:
      M ~ Poisson(Lam); M iid arrival locations via inverse-CDF
      (searchsorted into the cumulative mass); dedupe cells with one sort.
  For p_t > 1/2, sample the *complement* process (failures, rate -ln p_t,
  also <= ln 2 per cell) and invert via the l-th-missing-value formula —
  so the expected arrival count is <= ln2 * E[min(p,1-p) * w] <= 0.70 E[k]
  slots of overhead, for every p in [0, 1] including the exact endpoints.
  All phases are searchsorted / sort / cumsum — O(|N| + C log C) fully
  data-parallel work for capacity C. The paper's PT* methods instead scan
  root tuples sequentially (Fig. 6 loop) — kept below as host oracles.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

__all__ = [
    "PositionSample",
    "bern_positions",
    "geo_positions",
    "binom_positions",
    "hybrid_positions",
    "exprace_positions",
    "pt_bern_flat_positions",
    "fused_draw_params",
    "pt_positions_host",
    "HYBRID_THRESHOLD",
]

I64 = jnp.int64
F64 = jnp.float64
HYBRID_THRESHOLD = 0.5  # paper §6.1: GEO wins for p <= 0.5, BERN above
_TINY = 1e-12


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PositionSample:
    """A fixed-capacity probe sequence. positions[i] for i >= count equals the
    sentinel (the join size n) and must be masked downstream."""

    positions: jnp.ndarray  # (cap,) int64, ascending over valid lanes
    count: jnp.ndarray  # () int64 — number of valid positions (<= cap)
    overflow: jnp.ndarray  # () bool — true sample size exceeded cap

    def tree_flatten(self):
        return (self.positions, self.count, self.overflow), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)

    @property
    def capacity(self) -> int:
        return self.positions.shape[0]


def _finish(positions, valid, n, more_beyond) -> PositionSample:
    positions = jnp.where(valid, positions, n)
    count = jnp.sum(valid).astype(I64)
    return PositionSample(positions.astype(I64), count, more_beyond)


# ---------------------------------------------------------------------------
# Uniform position sampling over [0, n)
# ---------------------------------------------------------------------------

def bern_positions(key, p, n: int, cap: int) -> PositionSample:
    """Paper's BERN: one Bernoulli(p) trial per position. Theta(n) lanes."""
    u = jax.random.uniform(key, (n,), F64)
    mask = u < p
    total = jnp.sum(mask).astype(I64)
    (idx,) = jnp.nonzero(mask, size=cap, fill_value=n)
    valid = jnp.arange(cap) < jnp.minimum(total, cap)
    return _finish(idx.astype(I64), valid, jnp.asarray(n, I64), total > cap)


def geo_positions(key, p, n, cap: int) -> PositionSample:
    """Paper's GEO (Fig. 6), vectorized: draw ``cap`` geometric gaps at once,
    prefix-sum them into positions. O(cap) regardless of n; exact because a
    Bernoulli(p) process's success indices have iid Geometric(p) gaps."""
    n = jnp.asarray(n, I64)
    p = jnp.asarray(p, F64)
    u = jax.random.uniform(key, (cap,), F64, minval=_TINY)
    safe_p = jnp.clip(p, _TINY, 1.0 - _TINY)
    gaps = jnp.floor(jnp.log(u) / jnp.log1p(-safe_p)).astype(F64)
    gaps = jnp.where(p <= 0.0, jnp.asarray(n, F64) + 1.0, gaps)
    gaps = jnp.where(p >= 1.0, 0.0, gaps)
    gaps = jnp.minimum(gaps, 4.0 * jnp.asarray(n, F64) + 2.0)  # avoid inf->int UB
    positions = jnp.cumsum(gaps.astype(I64)) + jnp.arange(cap, dtype=I64)
    valid = positions < n
    # If the last lane is still in range the process hasn't exhausted [0, n):
    more = jnp.logical_and(cap > 0, valid[-1] if cap > 0 else False)
    return _finish(positions, valid, n, more)


def binom_positions(key, p, n: int, cap: int) -> PositionSample:
    """Paper's BINOM: draw k ~ Binomial(n, p), then a uniform k-subset of
    [0, n). The k-subset is drawn exactly via Gumbel-top-k over the n cells
    (the indices of the k smallest of n iid keys form a uniform k-subset).
    Note: Theta(n log n) here vs the O(n min(p,1-p) + np) of [7]/[23] —
    Vitter-style sequential subset draws don't vectorize; the paper discards
    BINOM after its Fig. 7 anyway (DESIGN.md §9)."""
    kk, ku = jax.random.split(key)
    k = jax.random.binomial(kk, n=jnp.asarray(n, F64), p=jnp.asarray(p, F64)).astype(I64)
    k = jnp.minimum(k, n)
    overflow = k > cap
    k_eff = jnp.minimum(k, cap)
    keys = jax.random.uniform(ku, (n,), F64)
    order = jnp.argsort(keys)  # uniform random permutation
    chosen = jnp.sort(jnp.where(jnp.arange(n) < k_eff, order, n)).astype(I64)
    m = min(n, cap)
    positions = jnp.full((cap,), n, I64).at[:m].set(chosen[:m])
    valid = jnp.arange(cap, dtype=I64) < k_eff
    return _finish(positions, valid, jnp.asarray(n, I64), overflow)


def hybrid_positions(key, p, n: int, cap: int) -> PositionSample:
    """Paper's HYBRID: GEO for p <= 0.5, BERN otherwise (threshold from §6.1)."""
    return jax.lax.cond(
        jnp.asarray(p, F64) <= HYBRID_THRESHOLD,
        lambda k: geo_positions(k, p, n, cap),
        lambda k: bern_positions(k, p, n, cap),
        key,
    )


# ---------------------------------------------------------------------------
# Non-uniform (Poisson) position sampling over root groups
# ---------------------------------------------------------------------------

def _locate_prefix(prefE, q, hi, narrow: bool):
    """clip(searchsorted(prefE, q, 'right') - 1, 0, hi) — routed through the
    Pallas branchless-descent kernel (``ops.searchsorted_prefix``) on
    int32-narrowed views when the caller statically guarantees every value
    fits int32 (``narrow=True``: the compiled plan knows join_size < 2^31
    because the shred packed its fused arena — DESIGN.md §4). Bit-identical
    to the XLA expression either way; float prefixes (EXPRACE's mass
    vector) take ``ops``' XLA fallback — dtypes there never permit."""
    if narrow:
        prefE, q = prefE.astype(jnp.int32), q.astype(jnp.int32)
    return jnp.minimum(ops.searchsorted_prefix(prefE, q), hi).astype(I64)


def exprace_positions(
    key, w, p, prefE, cap: int, arrival_cap: int = 0, narrow: bool = False
) -> PositionSample:
    """EXPRACE: exact non-uniform Poisson sample positions via a thinned
    Poisson process (module docstring). Fully vectorized, exact for all
    p in [0, 1]. Vmap-safe over ``key`` (module docstring contract): the
    engine's batched executor maps this function over split keys.

    w:     (R,) int64   flatten weight of each root tuple (0 = dangling)
    p:     (R,) float   sampling probability of each root tuple (t[y])
    prefE: (R+1,) int64 exclusive prefix of w; prefE[-1] = join size n
    cap:        output position capacity
    arrival_cap: scratch capacity for raw Poisson arrivals (default: cap;
        needs >= ln2/min(p,1-p)-adjusted slack — see estimate.plan_capacity)
    narrow: static caller guarantee that every integer prefix value fits
        int32, enabling the Pallas searchsorted kernel (``_locate_prefix``);
        must not change results (it does not — same clip semantics).
    """
    acap = arrival_cap or cap
    R = w.shape[0]
    n = prefE[-1]
    kM, kV = jax.random.split(key)
    p = jnp.clip(p.astype(F64), 0.0, 1.0)
    comp = p > 0.5                      # sample failures instead of successes
    pi = jnp.where(comp, 1.0 - p, p)    # process probability, <= 1/2
    lam = -jnp.log1p(-jnp.minimum(pi, 0.5))  # rate per cell, <= ln 2
    wF = w.astype(F64)

    # --- Poisson arrivals over the piecewise-constant-rate line ------------
    massE = jnp.concatenate([jnp.zeros((1,), F64), jnp.cumsum(wF * lam)])
    Lam = massE[-1]
    M = jax.random.poisson(kM, Lam).astype(I64)
    aM = jnp.minimum(M, acap)
    v = jax.random.uniform(kV, (acap,), F64) * Lam
    avalid = jnp.arange(acap, dtype=I64) < aM
    # Inverse-CDF arrival placement: float mass vector, so the ops wrapper
    # always takes its XLA fallback here (dtypes never permit int32).
    r = _locate_prefix(massE, v, R - 1, False)
    cell = jnp.floor((v - massE[r]) / jnp.maximum(lam[r], _TINY)).astype(I64)
    cell = jnp.clip(cell, 0, jnp.maximum(w[r] - 1, 0))
    gid = jnp.where(avalid, prefE[r] + cell, n)  # global cell id; pads -> n

    # --- dedupe cells (>=1 arrival == one success/failure) -----------------
    gid = jnp.sort(gid)
    uniq = jnp.logical_and(
        gid < n, jnp.concatenate([jnp.ones((1,), jnp.bool_), gid[1:] != gid[:-1]])
    )
    seg = _locate_prefix(prefE, gid, R - 1, narrow)
    hits = jnp.zeros((R,), I64).at[seg].add(uniq.astype(I64))  # per-root count
    k_r = jnp.where(comp, w - hits, hits)  # success count per root (exact)
    outE = jnp.concatenate([jnp.zeros((1,), I64), jnp.cumsum(k_r)])
    K = outE[-1]

    # --- compact the unique cells, in (segment, cell) order ----------------
    urank = jnp.cumsum(uniq.astype(I64)) - 1          # global unique rank
    hitsE = jnp.concatenate([jnp.zeros((1,), I64), jnp.cumsum(hits)])
    local = gid - prefE[seg]                          # cell offset in segment
    BIGPAD = jnp.iinfo(jnp.int64).max
    Fc = jnp.full((acap,), BIGPAD, I64)               # compacted cells
    Gc = jnp.full((acap,), BIGPAD, I64)               # f_i - i + segment offset
    tgt = jnp.where(uniq, urank, acap)                # dups scatter OOB (drop)
    offE = jnp.concatenate([jnp.zeros((1,), I64), jnp.cumsum(w + 1)])
    lrank = urank - hitsE[seg]                        # rank within segment
    g_val = local - lrank + offE[seg]                 # globally nondecreasing
    Fc = Fc.at[tgt].set(jnp.where(uniq, local, BIGPAD), mode="drop")
    Gc = Gc.at[tgt].set(jnp.where(uniq, g_val, BIGPAD), mode="drop")

    # --- emit output slots --------------------------------------------------
    t = jnp.arange(cap, dtype=I64)
    tvalid = t < jnp.minimum(K, cap)
    rO = _locate_prefix(outE, t, R - 1, narrow)
    l = t - outE[rO]
    # direct: l-th unique arrival of segment rO
    direct_pos = Fc[jnp.clip(hitsE[rO] + l, 0, acap - 1)]
    # complement: l-th missing value among the segment's failures
    q = l + offE[rO]
    c = jnp.searchsorted(Gc, q, side="right") - hitsE[rO]
    comp_pos = l + jnp.clip(c, 0, jnp.maximum(w[rO] - 1, 0) - l + 1)
    local_out = jnp.where(comp[rO], comp_pos, direct_pos)
    positions = prefE[rO] + jnp.clip(local_out, 0, jnp.maximum(w[rO] - 1, 0))
    overflow = jnp.logical_or(M > acap, K > cap)
    return _finish(positions, tvalid, n, overflow)


def fused_draw_params(w, p, prefE):
    """Plan-bound operand vectors for the one-launch fused draw
    (kernels/fused_draw.py, DESIGN.md §14) — the EXPRACE thinning tables
    (mass prefix, per-cell rates, complement signs) plus the int32-narrowed
    root prefixes, precomputed once per shred bind so the kernel sees only
    VMEM-ready arrays.

    Called *eagerly* on concrete arrays (engine/plan._bind_shred). The
    float tables are accumulated in f64 and cast to f32 — the fused route
    is a float32 sampler end to end (TPU-native; the F64 multi-launch path
    stays the precision arbiter, module docstring). Returns ``None`` when
    the int32 narrowing cannot be certified (join + R beyond int32, or an
    empty join) — one more rung of the static fallback ladder.
    """
    R = int(w.shape[0])
    n = int(prefE[-1])
    # offE[-1] = n + R must fit the int32 complement offsets.
    if n <= 0 or n + R >= (1 << 31) - 1:
        return None
    p64 = jnp.clip(jnp.asarray(p, F64), 0.0, 1.0)
    comp = p64 > 0.5                     # sample failures instead (EXPRACE)
    pi = jnp.where(comp, 1.0 - p64, p64)
    lam = -jnp.log1p(-jnp.minimum(pi, 0.5))
    wF = jnp.asarray(w, F64)
    zero1 = jnp.zeros((1,), F64)
    massE = jnp.concatenate([zero1, jnp.cumsum(wF * lam)])
    izero1 = jnp.zeros((1,), I64)
    cwE = jnp.concatenate([izero1, jnp.cumsum(jnp.where(comp, w, 0))])
    offE = jnp.concatenate([izero1, jnp.cumsum(w + 1)])
    return {
        "massE": massE.astype(jnp.float32),
        "lam": lam.astype(jnp.float32),
        "sign": jnp.where(comp, -1, 1).astype(jnp.int32),
        "w32": jnp.asarray(w).astype(jnp.int32),
        "prefE32": jnp.asarray(prefE).astype(jnp.int32),
        "cwE": cwE.astype(jnp.int32),
        "offE": offE.astype(jnp.int32),
        "p32": p64.astype(jnp.float32),
    }


def pt_bern_flat_positions(key, root_p, prefE, n: int, cap: int) -> PositionSample:
    """Faithful PTBERN, flattened: one Bernoulli trial per flat position with
    that position's root probability. Theta(n) — only for materializable n.
    Vmap-safe over ``key`` (module docstring contract)."""
    flat = jnp.arange(n, dtype=I64)
    r = jnp.clip(jnp.searchsorted(prefE, flat, side="right") - 1, 0, root_p.shape[0] - 1)
    u = jax.random.uniform(key, (n,), F64)
    mask = u < root_p[r]
    total = jnp.sum(mask).astype(I64)
    (idx,) = jnp.nonzero(mask, size=cap, fill_value=n)
    valid = jnp.arange(cap) < jnp.minimum(total, cap)
    return _finish(idx.astype(I64), valid, jnp.asarray(n, I64), total > cap)


# ---------------------------------------------------------------------------
# Paper-faithful sequential host oracles (numpy; used in tests/benchmarks)
# ---------------------------------------------------------------------------

def pt_positions_host(
    rng: np.random.Generator, w: np.ndarray, p: np.ndarray, method: str = "hybrid"
) -> np.ndarray:
    """The paper's PT* loop (§5 "Non-uniform"): iterate root tuples, run the
    uniform sampler per group, shift by the group's base offset. Sequential
    single-core semantics — the reproduction baseline."""
    w = np.asarray(w, np.int64)
    p = np.asarray(p, np.float64)
    base = np.concatenate([[0], np.cumsum(w)])
    out = []
    for t in range(w.shape[0]):
        wt, pt = int(w[t]), float(p[t])
        if wt == 0 or pt <= 0.0:
            continue
        m = method if method != "hybrid" else ("geo" if pt <= HYBRID_THRESHOLD else "bern")
        if m == "bern":
            idx = np.nonzero(rng.random(wt) < pt)[0]
        elif m == "geo":
            idx = []
            i = int(np.floor(np.log(max(rng.random(), _TINY)) / np.log1p(-min(pt, 1 - 1e-15))))
            while i < wt:
                idx.append(i)
                g = int(np.floor(np.log(max(rng.random(), _TINY)) / np.log1p(-min(pt, 1 - 1e-15))))
                i += 1 + g
            idx = np.asarray(idx, np.int64)
        else:
            raise ValueError(m)
        out.append(idx + base[t])
    if not out:
        return np.zeros((0,), np.int64)
    return np.concatenate(out)
