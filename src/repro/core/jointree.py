"""Join queries, acyclicity (GYO), join trees and rerooting (Prop. 3.1).

A Poisson sampling query is ``Q = beta_y(R1(x1) |><| ... |><| Rl(xl))``
(paper eq. (1)). Queries are data-independent, so everything here is plain
Python executed at trace/plan time.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["Atom", "JoinQuery", "JoinTreeNode", "gyo_join_tree", "is_acyclic", "reroot_for"]


@dataclasses.dataclass(frozen=True)
class Atom:
    """One occurrence of a relation symbol in the query body.

    ``alias`` distinguishes repeated relation symbols (self joins): the alias
    is the key into the database dict *and* the node identity in the tree.
    ``attrs`` maps the relation's physical column names to query variables,
    i.e. attrs[column] = variable. For convenience ``Atom.of`` builds the
    identity mapping.
    """

    relation: str
    variables: Tuple[str, ...]
    alias: Optional[str] = None

    @property
    def name(self) -> str:
        return self.alias or self.relation

    @staticmethod
    def of(relation: str, *variables: str, alias: str = None) -> "Atom":
        return Atom(relation, tuple(variables), alias)

    def var_set(self) -> frozenset:
        return frozenset(self.variables)


@dataclasses.dataclass(frozen=True)
class JoinQuery:
    """A full join query, optionally with a Poisson-probability variable y."""

    atoms: Tuple[Atom, ...]
    prob_var: Optional[str] = None  # the y attribute of beta_y

    def __post_init__(self):
        names = [a.name for a in self.atoms]
        if len(set(names)) != len(names):
            raise ValueError(f"atom aliases must be unique, got {names}")
        if self.prob_var is not None:
            allv = set().union(*[a.var_set() for a in self.atoms])
            if self.prob_var not in allv:
                raise ValueError(f"prob_var {self.prob_var!r} not in query variables")

    @property
    def variables(self) -> frozenset:
        return frozenset().union(*[a.var_set() for a in self.atoms])


@dataclasses.dataclass
class JoinTreeNode:
    atom: Atom
    children: List["JoinTreeNode"] = dataclasses.field(default_factory=list)

    def nodes(self) -> List["JoinTreeNode"]:
        out = [self]
        for c in self.children:
            out.extend(c.nodes())
        return out

    def pretty(self, indent: int = 0) -> str:
        s = "  " * indent + f"{self.atom.name}({', '.join(self.atom.variables)})\n"
        for c in self.children:
            s += c.pretty(indent + 1)
        return s


def _gyo_parents(query: JoinQuery) -> Optional[Dict[str, Optional[str]]]:
    """GYO ear decomposition. Returns atom-name -> parent-name (root: None),
    or None if the query is cyclic.

    Disjoint atoms (variables shared with no remaining atom) are a
    *deliberately supported* degenerate ear: their ``shared`` set is empty,
    so the cover check ``shared <= o.var_set()`` holds vacuously and the
    atom hangs off an arbitrary (first-remaining, hence deterministic)
    parent via a keyless edge — the join tree of a disconnected acyclic
    query connects its components with cross-product edges, which the shred
    build and both GETs execute as single-group (key 0) children (see
    shred._edge_keys). This cannot mask a cyclic component: an empty
    ``shared`` set means the atom shares *no* variable with any remaining
    atom, and a non-empty ``shared`` set only contains variables of the
    atom's own component, so cross-component elimination never removes an
    atom a cyclic component still needs (tests/test_jointree.py).
    """
    remaining: Dict[str, Atom] = {a.name: a for a in query.atoms}
    parent: Dict[str, Optional[str]] = {}
    changed = True
    while len(remaining) > 1 and changed:
        changed = False
        for name, atom in list(remaining.items()):
            others = [a for n, a in remaining.items() if n != name]
            shared = atom.var_set() & frozenset().union(*[o.var_set() for o in others])
            # atom is an ear if some other atom covers all its shared
            # variables (vacuously true for a disjoint atom: keyless edge)
            for o in others:
                if shared <= o.var_set():
                    parent[name] = o.name
                    del remaining[name]
                    changed = True
                    break
            if changed:
                break
    if len(remaining) != 1:
        return None
    root = next(iter(remaining))
    parent[root] = None
    return parent


def is_acyclic(query: JoinQuery) -> bool:
    """True iff GYO reduces the query to one atom. Disconnected queries are
    acyclic iff every connected component is (cross products supported)."""
    return _gyo_parents(query) is not None


def _tree_from_parents(query: JoinQuery, parent: Dict[str, Optional[str]]) -> JoinTreeNode:
    by_name = {a.name: JoinTreeNode(a) for a in query.atoms}
    root = None
    for name, p in parent.items():
        if p is None:
            root = by_name[name]
        else:
            by_name[p].children.append(by_name[name])
    assert root is not None
    return root


def gyo_join_tree(query: JoinQuery) -> JoinTreeNode:
    """Join tree via GYO; raises ValueError on cyclic queries."""
    parent = _gyo_parents(query)
    if parent is None:
        raise ValueError(f"query is cyclic: {[a.name for a in query.atoms]}")
    return _tree_from_parents(query, parent)


def reroot_for(tree: JoinTreeNode, var: str) -> JoinTreeNode:
    """Proposition 3.1: reroot the join tree at a node mentioning ``var``
    so that the probability attribute is flat at the root of the 2NSA
    expression. Connectedness is preserved under rerooting of a join tree."""
    # Build undirected adjacency.
    nodes = tree.nodes()
    adj: Dict[str, List[str]] = {n.atom.name: [] for n in nodes}
    atom_of = {n.atom.name: n.atom for n in nodes}
    for n in nodes:
        for c in n.children:
            adj[n.atom.name].append(c.atom.name)
            adj[c.atom.name].append(n.atom.name)
    target = None
    for n in nodes:
        if var in n.atom.var_set():
            target = n.atom.name
            break
    if target is None:
        raise ValueError(f"no atom mentions variable {var!r}")
    # BFS orient away from the new root.
    new_nodes = {name: JoinTreeNode(atom_of[name]) for name in adj}
    seen = {target}
    frontier = [target]
    while frontier:
        nxt = []
        for u in frontier:
            for v in adj[u]:
                if v not in seen:
                    seen.add(v)
                    new_nodes[u].children.append(new_nodes[v])
                    nxt.append(v)
        frontier = nxt
    return new_nodes[target]
