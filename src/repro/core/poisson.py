"""End-to-end Poisson sampling queries via Index-and-Probe (paper §3).

    sampler = PoissonSampler(db, query)         # (1) build random-access index
    sample  = sampler.sample(key)               # (2) position-sample (3) probe

The index is built once; each .sample() draws a *fresh independent* Poisson
sample — the Monte-Carlo-loop usage pattern of the paper's EpiQL engine and
of this repo's training-data pipeline (data/pipeline.py).

Since the engine refactor (DESIGN.md §7), ``PoissonSampler`` is a thin
facade over ``repro.engine.QueryEngine``: it compiles one plan on a private
engine and delegates every call, so its results are bit-identical to
``engine.poisson_sample`` under the same key. New code that issues more
than one query should construct a ``QueryEngine`` directly to share the
compiled-plan and shred caches across queries.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from .database import Database
from .jointree import JoinQuery

__all__ = ["JoinSample", "PoissonSampler"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class JoinSample:
    """A Poisson sample of the join result. Fixed capacity; lanes >= count
    are padding (mask with .valid()).

    Batched draws (``sample_batch``, DESIGN.md §10) reuse this container
    with a leading batch axis on every leaf: columns/positions ``(B, cap)``,
    count/overflow ``(B,)``. ``capacity``/``valid`` are batch-aware (the
    capacity is always the *last* axis; ``valid()`` broadcasts the per-draw
    counts), so masking code works unchanged on either layout."""

    columns: Dict[str, jnp.ndarray]
    positions: jnp.ndarray  # (cap,) flat offsets into the virtual join
    count: jnp.ndarray  # () int64
    overflow: jnp.ndarray  # () bool

    def tree_flatten(self):
        names = tuple(sorted(self.columns))
        leaves = tuple(self.columns[n] for n in names) + (
            self.positions,
            self.count,
            self.overflow,
        )
        return leaves, names

    @classmethod
    def tree_unflatten(cls, names, leaves):
        cols = dict(zip(names, leaves[: len(names)]))
        return cls(cols, *leaves[len(names):])

    @property
    def capacity(self) -> int:
        return self.positions.shape[-1]

    @property
    def batch(self) -> Optional[int]:
        """Leading batch size for batched samples, else None."""
        return self.positions.shape[0] if self.positions.ndim == 2 else None

    def valid(self) -> jnp.ndarray:
        count = jnp.asarray(self.count)
        if count.ndim:
            count = count[..., None]
        return jnp.arange(self.capacity) < count


class PoissonSampler:
    """Index-and-Probe executor for ``Q = beta_y(R1 |><| ... |><| Rl)``.

    .. deprecated::
        Thin facade over ``repro.engine.QueryEngine`` (one engine, one
        compiled plan), kept so published call sites keep running.
        Construct a ``QueryEngine`` instead — it caches plans across
        queries, batches draws (``sample_batch``), shards over meshes, and
        consumes deltas, none of which this facade exposes (DESIGN.md §13).
    """

    def __init__(
        self,
        db: Database,
        query: JoinQuery,
        rep: str = "usr",
        method: str = "exprace",
        project: Optional[tuple] = None,
    ):
        """``project``: bag-based projection attributes A for queries of the
        paper's form beta_y(pi_A(Q^)) (eq. 2). For bag projection the paper
        notes beta_y(pi_A(Q^)) == pi_A(beta_y(Q^)), so sampling first and
        projecting the sample is exact; we simply restrict GET's output
        columns (y must be in A). Set-based (duplicate-eliminating) free-
        connex projection would need Carmeli et al.'s Q'/D' reduction —
        documented as out of scope in DESIGN.md §9."""
        # Imported lazily: repro.engine imports repro.core, and this module
        # is part of repro.core's own import sequence.
        from repro.engine import QueryEngine

        warnings.warn(
            "core.PoissonSampler is deprecated; use repro.engine.QueryEngine"
            " (engine.sample / engine.sample_batch) — it shares plan caches"
            " across queries and supports batching, sharding, and deltas",
            DeprecationWarning, stacklevel=2)
        if query.prob_var is None:
            raise ValueError("Poisson sampling needs query.prob_var (beta_y)")
        if project is not None and query.prob_var not in project:
            raise ValueError("prob_var (y) must be in the projection A")
        self.engine = QueryEngine(db, rep=rep)
        self._plan = self.engine.compile(
            query, rep=rep, method=method, project=project)
        self.project = self._plan.project
        self.query = query
        self.rep_default = self._plan.rep_default
        self.method = method
        self.shred = self._plan.shred
        self.w = self._plan.w
        self.p = self._plan.p
        self.prefE = self._plan.prefE

    # -- capacity planning ---------------------------------------------------
    @property
    def join_size(self) -> int:
        return self._plan.join_size

    def expected_k(self) -> float:
        return self._plan.expected_k()

    def default_capacity(self) -> int:
        return self._plan.default_capacity()

    def arrival_capacity(self) -> int:
        return self._plan.arrival_capacity()

    # -- sampling -------------------------------------------------------------
    def sample(self, key, cap: Optional[int] = None, rep: Optional[str] = None,
               acap: Optional[int] = None) -> JoinSample:
        return self._plan.sample(key, cap=cap, rep=rep, acap=acap)

    def sample_auto(self, key, max_doublings: int = 8) -> JoinSample:
        """Redraw with doubled capacity until no overflow (host loop)."""
        return self._plan.sample_auto(key, max_doublings=max_doublings)

    def uniform_sample(
        self, key, p: float, cap: Optional[int] = None, method: str = "hybrid"
    ) -> JoinSample:
        """beta_p with a fixed uniform probability (paper §6.1)."""
        return self._plan.uniform_sample(key, p, cap=cap, method=method)
