"""End-to-end Poisson sampling queries via Index-and-Probe (paper §3).

    sampler = PoissonSampler(db, query)         # (1) build random-access index
    sample  = sampler.sample(key)               # (2) position-sample (3) probe

The index is built once; each .sample() draws a *fresh independent* Poisson
sample — the Monte-Carlo-loop usage pattern of the paper's EpiQL engine and
of this repo's training-data pipeline (data/pipeline.py).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from . import estimate, probe, sampling
from .database import Database
from .jointree import JoinQuery
from .shred import Shred, build_shred

__all__ = ["JoinSample", "PoissonSampler"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class JoinSample:
    """A Poisson sample of the join result. Fixed capacity; lanes >= count
    are padding (mask with .valid())."""

    columns: Dict[str, jnp.ndarray]
    positions: jnp.ndarray  # (cap,) flat offsets into the virtual join
    count: jnp.ndarray  # () int64
    overflow: jnp.ndarray  # () bool

    def tree_flatten(self):
        names = tuple(sorted(self.columns))
        leaves = tuple(self.columns[n] for n in names) + (
            self.positions,
            self.count,
            self.overflow,
        )
        return leaves, names

    @classmethod
    def tree_unflatten(cls, names, leaves):
        cols = dict(zip(names, leaves[: len(names)]))
        return cls(cols, *leaves[len(names):])

    @property
    def capacity(self) -> int:
        return self.positions.shape[0]

    def valid(self) -> jnp.ndarray:
        return jnp.arange(self.capacity) < self.count


def _sample_jit(
    shred: Shred, w, p, prefE, key, cap: int, rep: str, method: str, n: int = 0,
    acap: int = 0, project=None,
) -> JoinSample:
    if method == "exprace":
        ps = sampling.exprace_positions(key, w, p, prefE, cap, arrival_cap=acap)
    elif method == "ptbern_flat":  # n is the static, concrete join size
        ps = sampling.pt_bern_flat_positions(key, p, prefE, n, cap)
    else:
        raise ValueError(f"unknown jit sampling method {method!r}")
    pos = jnp.minimum(ps.positions, jnp.maximum(prefE[-1] - 1, 0))  # clamp pads
    cols = probe.get(shred, pos, rep=rep)
    if project is not None:
        cols = {v: c for v, c in cols.items() if v in project}
    return JoinSample(cols, ps.positions, ps.count, ps.overflow)


class PoissonSampler:
    """Index-and-Probe executor for ``Q = beta_y(R1 |><| ... |><| Rl)``."""

    def __init__(
        self,
        db: Database,
        query: JoinQuery,
        rep: str = "usr",
        method: str = "exprace",
        project: Optional[tuple] = None,
    ):
        """``project``: bag-based projection attributes A for queries of the
        paper's form beta_y(pi_A(Q^)) (eq. 2). For bag projection the paper
        notes beta_y(pi_A(Q^)) == pi_A(beta_y(Q^)), so sampling first and
        projecting the sample is exact; we simply restrict GET's output
        columns (y must be in A). Set-based (duplicate-eliminating) free-
        connex projection would need Carmeli et al.'s Q'/D' reduction —
        documented as out of scope in DESIGN.md §8."""
        if query.prob_var is None:
            raise ValueError("Poisson sampling needs query.prob_var (beta_y)")
        if project is not None and query.prob_var not in project:
            raise ValueError("prob_var (y) must be in the projection A")
        self.project = tuple(project) if project else None
        self.query = query
        self.rep_default = "usr" if rep == "both" else rep
        self.method = method
        self.shred = build_shred(db, query, rep=rep)
        root = self.shred.root
        if query.prob_var not in root.variables:
            raise AssertionError("build_plan must reroot prob_var to the root")
        self.w = root.weight
        self.p = root.data.column(query.prob_var)
        self.prefE = self.shred.root_prefE
        self._jit = jax.jit(
            partial(_sample_jit, method=method, project=self.project),
            static_argnames=("cap", "rep", "n", "acap"),
        )

    # -- capacity planning ---------------------------------------------------
    @property
    def join_size(self) -> int:
        return int(self.shred.join_size)

    def expected_k(self) -> float:
        return float(estimate.expected_sample_size(self.w, self.p))

    def default_capacity(self) -> int:
        mean = estimate.expected_sample_size(self.w, self.p)
        std = estimate.sample_std(self.w, self.p)
        return estimate.plan_capacity(float(mean), float(std))

    def arrival_capacity(self) -> int:
        mass = float(estimate.exprace_arrival_mass(self.w, self.p))
        return estimate.plan_capacity(mass, mass**0.5)

    # -- sampling -------------------------------------------------------------
    def _empty(self, cap: int) -> JoinSample:
        cols = {v: jnp.zeros((cap,), node.data.column(v).dtype)
                for node in self.shred.root.nodes() for v in node.owned}
        return JoinSample(cols, jnp.zeros((cap,), jnp.int64),
                          jnp.zeros((), jnp.int64), jnp.zeros((), jnp.bool_))

    def sample(self, key, cap: Optional[int] = None, rep: Optional[str] = None,
               acap: Optional[int] = None) -> JoinSample:
        cap = cap or self.default_capacity()
        if self.join_size == 0:
            return self._empty(cap)
        acap = acap or (self.arrival_capacity() if self.method == "exprace" else 0)
        n = self.join_size if self.method == "ptbern_flat" else 0
        return self._jit(self.shred, self.w, self.p, self.prefE, key, cap=cap,
                         rep=rep or self.rep_default, n=n, acap=acap)

    def sample_auto(self, key, max_doublings: int = 8) -> JoinSample:
        """Redraw with doubled capacity until no overflow (host loop)."""
        cap = self.default_capacity()
        acap = self.arrival_capacity() if self.method == "exprace" else 0
        for _ in range(max_doublings):
            s = self.sample(key, cap=cap, acap=acap)
            if not bool(s.overflow):
                return s
            cap *= 2
            acap *= 2
        raise RuntimeError("sample capacity still overflowing after doublings")

    def uniform_sample(
        self, key, p: float, cap: Optional[int] = None, method: str = "hybrid"
    ) -> JoinSample:
        """beta_p with a fixed uniform probability (paper §6.1)."""
        n = self.join_size
        if cap is None:
            mean = n * p
            cap = estimate.plan_capacity(mean, (mean * max(1 - p, 0.0)) ** 0.5)
        fn = {
            "bern": sampling.bern_positions,
            "geo": sampling.geo_positions,
            "binom": sampling.binom_positions,
            "hybrid": sampling.hybrid_positions,
        }[method]
        ps = fn(key, p, n, cap)
        pos = jnp.minimum(ps.positions, max(n - 1, 0))
        cols = probe.get(self.shred, pos, rep=self.rep_default)
        return JoinSample(cols, ps.positions, ps.count, ps.overflow)
