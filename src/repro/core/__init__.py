"""repro.core — Poisson sampling over acyclic joins (the paper's contribution).

Public API:
    Database, Relation, Atom, JoinQuery       data model / queries
    DeltaBatch, Database.apply                versioned snapshots (DESIGN.md §11)
    build_shred, Shred, get                   random-access index (CSR/USR)
    reshred_incremental                       merge a delta into an index
    PoissonSampler, JoinSample                end-to-end Index-and-Probe
    sampling.*                                position-sampling methods
    yannakakis.*                              full joins + M&S baselines
    distributed.*                             shard_map multi-pod sampling

Engine entry points: ``repro.engine.QueryEngine`` is the unified planner /
compiled-plan cache over these primitives — ``engine.full_join(q)`` and
``engine.poisson_sample(q, key)`` serve both workloads from one cached
shred index (DESIGN.md §7). ``PoissonSampler`` and ``yannakakis.full_join``
are DEPRECATED single-query facades over it (DeprecationWarning since the
DrawSpec consolidation, DESIGN.md §13); new code holds a ``QueryEngine``.

x64 note: join sizes reach 1e10 (paper §1), so offsets/prefix vectors are
int64. JAX only honors int64 with the x64 flag; importing repro.core enables
it process-wide. Model code (repro.models) is dtype-explicit everywhere and
unaffected.
"""
import jax as _jax

_jax.config.update("jax_enable_x64", True)

from .relations import Relation, pack_keys, dense_keys  # noqa: E402
from .database import Database  # noqa: E402
from .delta import DeltaBatch, RelationDelta  # noqa: E402
from .jointree import Atom, JoinQuery, gyo_join_tree, is_acyclic, reroot_for  # noqa: E402
from .shred import (Shred, ShredNode, build_shred, build_plan,  # noqa: E402
                    reshred_incremental, PackedShred, PagedArena,
                    pack_arena, pack_index)
from .probe import (get, get_rows, csr_get_rows, usr_get_rows,  # noqa: E402
                    usr_get_rows_fused, usr_get_rows_paged)
from . import sampling, estimate, yannakakis  # noqa: E402
from .poisson import PoissonSampler, JoinSample  # noqa: E402

__all__ = [
    "Relation", "Database", "DeltaBatch", "RelationDelta", "Atom",
    "JoinQuery", "gyo_join_tree", "is_acyclic",
    "reroot_for", "Shred", "ShredNode", "build_shred", "build_plan",
    "reshred_incremental", "PackedShred", "PagedArena", "pack_arena",
    "pack_index", "get",
    "get_rows", "csr_get_rows", "usr_get_rows", "usr_get_rows_fused",
    "usr_get_rows_paged", "sampling", "estimate",
    "yannakakis", "PoissonSampler", "JoinSample", "pack_keys", "dense_keys",
]
