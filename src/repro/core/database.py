"""A tiny schema-aware database: named relations with ordered columns."""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from .relations import Relation
from .jointree import Atom

__all__ = ["Database"]


@dataclasses.dataclass
class Database:
    """relations: name -> Relation; schemas: name -> ordered column names.

    Atom variables bind positionally to the schema order, which is what makes
    self-joins (one relation, several aliases with different variables) work.
    """

    relations: Dict[str, Relation]
    schemas: Dict[str, Tuple[str, ...]]

    @staticmethod
    def from_columns(tables: Mapping[str, Mapping[str, Sequence]]) -> "Database":
        rels, schemas = {}, {}
        for name, cols in tables.items():
            schemas[name] = tuple(cols.keys())
            rels[name] = Relation({c: jnp.asarray(np.asarray(v)) for c, v in cols.items()})
        return Database(rels, schemas)

    def size(self) -> int:
        """|db| = total number of tuples."""
        return sum(r.num_rows for r in self.relations.values())

    def instance_for(self, atom: Atom) -> Relation:
        """The atom's relation with columns renamed to the atom's variables."""
        rel = self.relations[atom.relation]
        schema = self.schemas[atom.relation]
        if len(schema) != len(atom.variables):
            raise ValueError(
                f"atom {atom.name}: {len(atom.variables)} variables for "
                f"{len(schema)}-column relation {atom.relation}"
            )
        return Relation({v: rel.columns[c] for c, v in zip(schema, atom.variables)})
