"""A tiny schema-aware database: named relations with ordered columns.

Snapshots are immutable and *versioned* (DESIGN.md §11): the only way to
change data is ``Database.apply(delta)``, which returns a NEW snapshot with
``version + 1``. Untouched relations are shared by reference, so a delta
over one relation costs O(|that relation| + |delta|) to apply and nothing
for the rest of the database.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from .relations import Relation
from .jointree import Atom

__all__ = ["Database"]


@dataclasses.dataclass
class Database:
    """relations: name -> Relation; schemas: name -> ordered column names.

    Atom variables bind positionally to the schema order, which is what makes
    self-joins (one relation, several aliases with different variables) work.

    ``version`` increases monotonically along an ``apply`` chain; two
    snapshots with the same version are NOT guaranteed identical (versions
    are per-lineage, not global) — the engine pairs version with object
    identity for cache coherence (DESIGN.md §11).
    """

    relations: Dict[str, Relation]
    schemas: Dict[str, Tuple[str, ...]]
    version: int = 0

    @staticmethod
    def from_columns(tables: Mapping[str, Mapping[str, Sequence]]) -> "Database":
        rels, schemas = {}, {}
        for name, cols in tables.items():
            schemas[name] = tuple(cols.keys())
            rels[name] = Relation({c: jnp.asarray(np.asarray(v)) for c, v in cols.items()})
        return Database(rels, schemas)

    def size(self) -> int:
        """|db| = total number of tuples."""
        return sum(r.num_rows for r in self.relations.values())

    def instance_for(self, atom: Atom) -> Relation:
        """The atom's relation with columns renamed to the atom's variables."""
        rel = self.relations[atom.relation]
        schema = self.schemas[atom.relation]
        if len(schema) != len(atom.variables):
            raise ValueError(
                f"atom {atom.name}: {len(atom.variables)} variables for "
                f"{len(schema)}-column relation {atom.relation}"
            )
        return Relation({v: rel.columns[c] for c, v in zip(schema, atom.variables)})

    def apply(self, delta) -> "Database":
        """The next snapshot: ``delta`` (a ``core.delta.DeltaBatch``) applied
        to this one. Touched relations become "survivors then inserts"
        (``rows[~delete_mask] ++ inserts``); untouched relations are shared
        by reference. Never mutates ``self``.
        """
        from .delta import apply_relation_delta

        unknown = set(delta.relations) - set(self.relations)
        if unknown:
            raise KeyError(f"delta touches unknown relations {sorted(unknown)}")
        delta = delta.resolved({n: r.num_rows for n, r in self.relations.items()})
        rels = dict(self.relations)
        for name, d in delta.relations.items():
            d.validate(name, self.relations[name].num_rows, self.schemas[name])
            rels[name] = Relation(
                apply_relation_delta(self.relations[name].columns, d))
        return Database(rels, self.schemas, self.version + 1)
