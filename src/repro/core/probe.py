"""Random access into the (virtual) flattened join result (paper §4, Figs 4/5/11/12).

Both GETs are *bulk* by construction: the probe vector ``pos`` is processed
as one data-parallel batch. The paper's sequential "caching optimization"
(resume a linked-list walk / binary search from the previous probe) exists to
amortize work across consecutive probes on a single core; on TPU the same
amortization comes from executing all probes in lockstep vectors, so the bulk
APIs here are the faithful analogue (DESIGN.md §3/§4).

USR-GET: one vectorized binary search per tree node — O(log|db|) depth per
probe, fully parallel across probes. The searches over the *global* exclusive
weight-prefix array are confined to the correct join-key run automatically,
because a run's weight interval [cumw_excl[start], cumw_excl[start+len]) is
contiguous in the global prefix (see shred.py).

Fused USR-GET (rep='usr_fused', DESIGN.md §4 "Fused GET"): the whole
per-node walk collapsed into ONE Pallas kernel launch over the shred's
packed int32 index arena (shred.pack_arena) — root locate + mixed-radix
split + per-child binary search + perm resolution in a single pass, the
arena VMEM-resident across tree levels. Bit-identical to usr_get_rows;
falls back to the per-node path down a static ladder (no arena / arena
over the VMEM budget / Pallas disabled).

CSR-GET: faithful linked-list walk (bounded while_loop), vmapped over probes
— O(log|db| + d) per probe with d the max join degree. Kept as the
paper-faithful baseline; pointer chasing does not vectorize on TPU.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro import config
from repro.kernels import ops
from repro.kernels.fused_draw import fused_draw, fused_draw_ref, fused_sample
from repro.kernels.tree_probe import tree_probe, tree_probe_paged

from .sampling import PositionSample
from .shred import PagedArena, Shred, ShredNode

__all__ = ["get", "get_rows", "gather_columns", "csr_get_rows",
           "usr_get_rows", "usr_get_rows_fused", "usr_get_rows_paged",
           "csr_get_rows_cached", "fused_available", "paged_available",
           "paged_view", "select_rep", "draw_fused_available",
           "draw_paged_available", "select_draw", "draw_fused",
           "draw_paged"]

I64 = jnp.int64


def _root_locate(shred: Shred, pos: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Binary search the root prefix vector: pos -> (root row j, local offset i).

    When the shred carries a packed arena (static: every prefix value fits
    int32), the search runs through ``ops.searchsorted_prefix`` — the
    Pallas branchless-descent kernel — on int32-narrowed views; the int64
    local offset is still derived from the original prefix, so results are
    bit-identical to the XLA path (DESIGN.md §4).
    """
    prefE = shred.root_prefE
    n = shred.root.num_rows
    # Either index form (monolithic arena or paged) certifies the int32
    # narrowing; the root prefix itself is always within one page.
    if ((shred.packed is not None or shred.paged is not None)
            and n and ops.pallas_preferred()):
        j = jnp.minimum(
            ops.searchsorted_prefix(prefE.astype(jnp.int32),
                                    pos.astype(jnp.int32)),
            n - 1)
    else:
        j = jnp.clip(jnp.searchsorted(prefE, pos, side="right") - 1, 0,
                     max(n - 1, 0))
    local = pos - prefE[j]
    return j.astype(jnp.int32), local.astype(I64)


# ---------------------------------------------------------------------------
# USR
# ---------------------------------------------------------------------------

def _usr_child_locate(node: ShredNode, ci: int, rows: jnp.ndarray, idx: jnp.ndarray):
    """Locate offset ``idx`` within the child-ci group of parent ``rows``.

    One global searchsorted over the child's exclusive weight prefix.
    """
    child = node.children[ci]
    start = node.child_start[ci][rows]          # (k,) offsets into sorted order
    cumw_excl = child.cumw_excl                 # (n_c + 1,)
    base = cumw_excl[start]
    target = base + idx
    # smallest jj with cumw_incl[jj] > target  <=>  cumw_excl[jj+1] > target
    jj = jnp.clip(
        jnp.searchsorted(cumw_excl, target, side="right") - 1,
        0,
        child.num_rows - 1,
    )
    local = target - cumw_excl[jj]
    child_rows = child.perm[jj]
    return child_rows.astype(jnp.int32), local.astype(I64)


def _usr_sub(node: ShredNode, rows: jnp.ndarray, local: jnp.ndarray, out: Dict[str, jnp.ndarray]):
    out[node.name] = rows
    # Mixed-radix split (paper eq. 6-7): child 0 is least significant.
    for ci, child in enumerate(node.children):
        w = node.child_w[ci][rows]
        w_safe = jnp.maximum(w, 1)
        idx = local % w_safe
        local = local // w_safe
        crows, clocal = _usr_child_locate(node, ci, rows, idx)
        _usr_sub(child, crows, clocal, out)


def usr_get_rows(shred: Shred, pos: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Resolve probe positions to per-node row indices (USR)."""
    assert shred.rep in ("usr", "both"), "index was not built with USR columns"
    rows, local = _root_locate(shred, pos)
    out: Dict[str, jnp.ndarray] = {}
    _usr_sub(shred.root, rows, local, out)
    return out


# ---------------------------------------------------------------------------
# Fused USR (single Pallas pass over the packed arena, DESIGN.md §4)
# ---------------------------------------------------------------------------

def fused_available(shred: Shred, policy=None) -> bool:
    """Static verdict: does this shred take the fused kernel path?
    (arena packed + within the active policy's VMEM budget + kernels not
    disabled). The budget was historically the module constant
    ``FUSED_VMEM_LIMIT``; it now lives on ``config.KernelPolicy`` so tests
    and operators shrink it with ``config.override(...)``."""
    pol = config.current_policy(policy)
    return (shred.packed is not None
            and shred.packed.layout.size <= pol.vmem_limit
            and pol.enabled)


def paged_view(shred: Shred):
    """The shred's ``PagedArena``, or ``None``: the build-time one when
    ``pack_index`` chose paging, else a page-sliced view of the monolithic
    arena (static slice bounds — a call-time policy with a shrunken
    ``vmem_limit`` pages an already-packed index without a rebuild)."""
    if shred.paged is not None:
        return shred.paged
    if shred.packed is not None:
        return PagedArena.from_packed(shred.packed)
    return None


def paged_available(shred: Shred, policy=None) -> bool:
    """Static verdict for the *paged* rung (DESIGN.md §15): the fused
    monolith does not apply, but an int32 index exists whose every page
    fits the VMEM budget (total within ``config.PAGED_PACK_LIMIT``).
    Sits strictly between ``fused`` and the per-node fallback in the
    ladder — ``fused_available`` wins when both hold."""
    pol = config.current_policy(policy)
    if not pol.enabled or fused_available(shred, pol):
        return False
    layout = (shred.paged.layout if shred.paged is not None
              else shred.packed.layout if shred.packed is not None else None)
    if layout is None:
        return False
    return (layout.max_page <= pol.vmem_limit
            and layout.size <= config.PAGED_PACK_LIMIT)


def select_rep(shred: Shred, base: str, policy=None) -> Tuple[str, bool]:
    """The executor policy both plan layers share (DESIGN.md §4): given the
    rep a plan would use (``usr``/``csr``), return ``(rep, narrow)`` —
    upgrade USR down the kernel ladder (``usr_fused``, then ``usr_paged``
    when only the pages fit the VMEM budget) and enable int32-narrowed
    sampler searches iff the shred packed an int32 index (monolithic or
    paged) AND the backend prefers Pallas (compiled mode /
    ``REPRO_PALLAS_PREFER=1``). Single source of truth so single-device
    and sharded plans cannot diverge."""
    pol = config.current_policy(policy)
    prefer = pol.preferred
    narrow = (shred.packed is not None or shred.paged is not None) and prefer
    if base == "usr" and prefer:
        if fused_available(shred, pol):
            return "usr_fused", narrow
        if paged_available(shred, pol):
            return "usr_paged", narrow
    return base, narrow


def usr_get_rows_fused(shred: Shred, pos: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Resolve probe positions to per-node row indices in ONE kernel launch.

    Bit-identical to ``usr_get_rows`` (same rows, every node). The fallback
    ladder is static — decided at trace time from the shred's pytree
    structure, never from traced values:

      1. no packed arena (int32 narrowing refused: join > 2^31, or an
         empty node)                      -> per-node USR (or CSR) path;
      2. arena over the VMEM budget       -> the PAGED rung
         (``usr_get_rows_paged``) when every page fits it, else per-node;
      3. ``REPRO_PALLAS_DISABLE=1``       -> per-node path.

    Positions are narrowed to int32 — exact, because a packed arena
    guarantees join_size < 2^31 and callers clamp pads to n - 1 (GET's
    out-of-range lanes are arbitrary-but-masked either way, §4).
    """
    if not fused_available(shred):
        if paged_available(shred):
            return usr_get_rows_paged(shred, pos)
        rep = "usr" if shred.rep in ("usr", "both") else "csr"
        return get_rows(shred, pos, rep=rep)
    packed = shred.packed
    k = pos.shape[0]
    tiles = ops.to_tiles(pos.astype(jnp.int32))
    out = tree_probe(packed.arena, tiles, layout=packed.layout,
                     block_rows=ops.tile_for("tree_probe", k),
                     interpret=ops.interpret_default())
    flat = out.reshape(out.shape[0], -1)[:, :k]
    return {name: flat[i] for i, name in enumerate(packed.layout.names)}


def usr_get_rows_paged(shred: Shred, pos: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """The paged rung's GET (DESIGN.md §15): the same walk as the fused
    kernel, streamed page by page through VMEM (``tree_probe_paged`` —
    double-buffered DMA on TPU, per-page launches elsewhere). Bit-identical
    to ``usr_get_rows``/``usr_get_rows_fused`` at every position. Callers
    reach it through ``select_rep``/``get_rows`` (rep ``usr_paged``) or the
    fused ladder's fallback; it assumes ``paged_available`` held at
    selection time."""
    pv = paged_view(shred)
    k = pos.shape[0]
    tiles = ops.to_tiles(pos.astype(jnp.int32))
    out = tree_probe_paged(pv.pages, tiles, layout=pv.layout,
                           block_rows=ops.tile_for("tree_probe_paged", k),
                           interpret=ops.interpret_default())
    flat = out.reshape(out.shape[0], -1)[:, :k]
    return {name: flat[i] for i, name in enumerate(pv.layout.names)}


# ---------------------------------------------------------------------------
# Fused one-launch draw (sample + walk in one kernel, DESIGN.md §14)
# ---------------------------------------------------------------------------

def draw_fused_available(shred: Shred, dparams, *, method: str, n: int = 0,
                         policy=None) -> bool:
    """Static *capability* verdict (no preference): can the one-launch
    fused draw — or its pure-jnp reference twin — run this method on this
    shred?  Requires the packed arena within the policy's VMEM budget plus
    the plan-bound parameter vectors (``sampling.fused_draw_params`` —
    ``None`` when int32 narrowing cannot be certified).  ``ptbern_flat``
    additionally materializes n lanes in VMEM, so n shares the budget.
    Deliberately ignores ``policy.enabled``: the reference route runs with
    kernels disabled; ``select_draw`` layers the preference gates on top."""
    pol = config.current_policy(policy)
    if dparams is None or shred.packed is None:
        return False
    if shred.packed.layout.size > pol.vmem_limit:
        return False
    if method == "ptbern_flat":
        return 0 < n <= pol.vmem_limit
    return method == "exprace"


def draw_paged_available(shred: Shred, dparams, *, method: str, n: int = 0,
                         policy=None) -> bool:
    """Static capability verdict for the *paged* draw (DESIGN.md §15): the
    one-launch fused draw cannot apply (arena over the VMEM budget), but
    the sampling half still fits — the root-level parameter vectors ride
    with the root page — and the walk half can stream pages
    (``paged_available``). Same method gates as the fused draw."""
    pol = config.current_policy(policy)
    if dparams is None or not paged_available(shred, pol):
        return False
    if method == "ptbern_flat":
        return 0 < n <= pol.vmem_limit
    return method == "exprace"


def select_draw(shred: Shred, dparams, *, method: str, n: int = 0,
                kernels: str = "auto", policy=None) -> str:
    """Resolve a ``DrawSpec.kernels`` request to the executor draw route —
    ``'fused'`` (one Pallas launch), ``'paged'`` (sample launch + page-
    streamed walk), ``'reference'`` (same math, plain traced jnp) or
    ``'pernode'`` (the F64 multi-launch path).  Decided at plan-bind time,
    like ``select_rep``:

      * ``'auto'``   — fused iff capable AND the policy enables, prefers
                       and hasn't opted out of the fused draw; else paged
                       under the same preference gates when only the pages
                       fit the VMEM budget; else pernode.
      * ``'fused'``  — explicit request: raise unless capable and enabled.
      * ``'paged'``  — explicit request: raise unless the paged rung is
                       capable and enabled (DESIGN.md §15).
      * ``'reference'`` — explicit request: raise unless capable (runs
                       without Pallas — it is the bit-identity oracle).
      * ``'pernode'`` — always honored (the precision arbiter).
    """
    pol = config.current_policy(policy)
    capable = draw_fused_available(shred, dparams, method=method, n=n,
                                   policy=pol)
    paged_capable = draw_paged_available(shred, dparams, method=method, n=n,
                                         policy=pol)
    if kernels == "pernode":
        return "pernode"
    if kernels == "fused":
        if not (capable and pol.enabled):
            raise ValueError(
                "kernels='fused' requested but the fused draw is "
                "unavailable here (needs a packed arena within the VMEM "
                "budget, certified int32 narrowing, an exprace/ptbern_flat "
                "method, and kernels enabled)")
        return "fused"
    if kernels == "paged":
        if not (paged_capable and pol.enabled):
            raise ValueError(
                "kernels='paged' requested but the paged draw is "
                "unavailable here (needs an int32 index whose arena "
                "exceeds the VMEM budget while every page fits it, "
                "certified narrowing, an exprace/ptbern_flat method, and "
                "kernels enabled)")
        return "paged"
    if kernels == "reference":
        if not (capable or paged_capable):
            raise ValueError(
                "kernels='reference' requested but the fused-draw operands "
                "are unavailable here (needs a packed arena within the "
                "VMEM budget and certified int32 narrowing)")
        return "reference"
    if kernels != "auto":
        raise ValueError(f"unknown kernels request {kernels!r}")
    if pol.enabled and pol.fused_draw and pol.preferred:
        if capable:
            return "fused"
        if paged_capable:
            return "paged"
    return "pernode"


def draw_fused(shred: Shred, dparams, key, *, method: str, cap: int,
               acap: int = 0, n: int = 0, reference: bool = False,
               policy=None):
    """Run the one-launch draw (kernels/fused_draw.py): key -> per-node
    rows + PositionSample, ONE dispatch.  ``reference=True`` runs the same
    ``draw_core`` + ``tree_walk`` as plain traced jnp instead — bit-
    identical in interpret mode by construction.

    Returns ``(node_rows, ps)``: node name -> (cap,) int32 rows (lanes
    beyond ``ps.count`` arbitrary-but-masked, the GET contract) and a
    ``PositionSample`` with the usual int64/sentinel-n conventions, so
    downstream compaction/masking is route-agnostic."""
    if shred.packed is not None:
        arena, layout = shred.packed.arena, shred.packed.layout
    else:
        # Paged-only index on the reference route: the pages concatenate
        # back to the monolithic arena exactly (contiguous slices).
        arena = jnp.concatenate(shred.paged.pages)
        layout = shred.paged.layout
    key_data = jax.random.key_data(key).astype(jnp.uint32)
    if reference:
        rows, pos, cnt, ovf = fused_draw_ref(
            arena, key_data, dparams, layout=layout,
            method=method, cap=cap, acap=acap, n=n)
    else:
        rows, pos, cnt, ovf = fused_draw(
            arena, key_data, dparams, layout=layout,
            method=method, cap=cap, acap=acap, n=n,
            interpret=ops.interpret_default(policy))
    node_rows = {name: rows[i]
                 for i, name in enumerate(layout.names)}
    ps = PositionSample(pos.astype(I64), cnt.astype(I64), ovf)
    return node_rows, ps


def draw_paged(shred: Shred, dparams, key, *, method: str, cap: int,
               acap: int = 0, n: int = 0, policy=None):
    """The paged rung's draw (DESIGN.md §15): the sampling half in one
    kernel launch (``fused_sample`` — the exact ``draw_core`` the fused
    draw and its reference run, so positions are bit-identical under the
    same key), then the walk half streamed page by page
    (``tree_probe_paged`` — bit-identical to ``tree_walk``). Same return
    contract as ``draw_fused``."""
    pv = paged_view(shred)
    key_data = jax.random.key_data(key).astype(jnp.uint32)
    pos, cnt, ovf = fused_sample(
        key_data, dparams, method=method, cap=cap, acap=acap, n=n,
        interpret=ops.interpret_default(policy))
    # Clamp sentinels for the walk (arbitrary-but-masked, the GET contract).
    wpos = jnp.minimum(pos, dparams["prefE32"][-1] - 1)
    tiles = ops.to_tiles(wpos)
    rows = tree_probe_paged(pv.pages, tiles, layout=pv.layout,
                            block_rows=ops.tile_for("tree_probe_paged", cap),
                            interpret=ops.interpret_default(policy))
    flat = rows.reshape(rows.shape[0], -1)[:, :cap]
    node_rows = {name: flat[i] for i, name in enumerate(pv.layout.names)}
    ps = PositionSample(pos.astype(I64), cnt.astype(I64), ovf)
    return node_rows, ps


# ---------------------------------------------------------------------------
# CSR
# ---------------------------------------------------------------------------

def _csr_walk(child_weight: jnp.ndarray, nxt: jnp.ndarray, hd: jnp.ndarray, idx: jnp.ndarray):
    """Walk the same-key chain until the cumulative weight covers ``idx``.

    Vectorized over probes via vmap; each lane runs its own bounded
    while_loop (paper Fig. 4 lines 11-15, incl. skipping weight-0 tuples).
    """

    def one(h, i):
        def cond(st):
            row, rem = st
            return jnp.logical_and(row >= 0, rem >= child_weight[row])

        def body(st):
            row, rem = st
            return nxt[row], rem - child_weight[row]

        row, rem = jax.lax.while_loop(cond, body, (h, i))
        return row, rem

    return jax.vmap(one)(hd, idx)


def _csr_sub(node: ShredNode, rows: jnp.ndarray, local: jnp.ndarray, out: Dict[str, jnp.ndarray]):
    out[node.name] = rows
    for ci, child in enumerate(node.children):
        w = node.child_w[ci][rows]
        w_safe = jnp.maximum(w, 1)
        idx = local % w_safe
        local = local // w_safe
        hd = node.child_hd[ci][rows]
        crows, clocal = _csr_walk(child.weight, child.nxt, hd, idx)
        crows = jnp.maximum(crows, 0).astype(jnp.int32)  # clamp sentinel lanes
        _csr_sub(child, crows, clocal.astype(I64), out)


def csr_get_rows(shred: Shred, pos: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Resolve probe positions to per-node row indices (CSR)."""
    assert shred.rep in ("csr", "both"), "index was not built with CSR columns"
    rows, local = _root_locate(shred, pos)
    out: Dict[str, jnp.ndarray] = {}
    _csr_sub(shred.root, rows, local, out)
    return out


# ---------------------------------------------------------------------------
# CSR bulk probe with the paper's caching optimization (Fig. 11)
# ---------------------------------------------------------------------------

def _csr_walk_cached(child_weight, nxt, hd, idx):
    """Faithful Fig.-11 semantics: probes are processed in (ascending-
    position) order and a chain traversal resumes from where the previous
    probe on the SAME list stopped, instead of restarting at the head.

    Realized as one lax.scan over the probe vector carrying
    (prev_head, prev_row, prev_consumed): sequential like the paper's loop —
    this is the *paper-faithful baseline*; the vmapped walk in _csr_walk is
    the data-parallel adaptation benchmarked against it (table6 bench).
    """

    def step(carry, inp):
        prev_head, prev_row, prev_consumed = carry
        h, i = inp
        same = jnp.logical_and(prev_head == h, i >= prev_consumed)
        row0 = jnp.where(same, prev_row, h)
        rem0 = jnp.where(same, i - prev_consumed, i)
        consumed0 = jnp.where(same, prev_consumed, 0)

        def cond(st):
            row, rem, _ = st
            return jnp.logical_and(row >= 0, rem >= child_weight[row])

        def body(st):
            row, rem, cons = st
            w = child_weight[row]
            return nxt[row], rem - w, cons + w

        row, rem, consumed = jax.lax.while_loop(cond, body, (row0, rem0, consumed0))
        return (h, row, consumed), (row, rem)

    init = (jnp.int32(-2), jnp.int32(-1), jnp.zeros((), idx.dtype))
    _, (rows, rems) = jax.lax.scan(step, init, (hd, idx))
    return rows, rems


def csr_get_rows_cached(shred: Shred, pos: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """CSR GET with the caching optimization; expects ascending ``pos``
    (samplers emit sorted positions, the paper's usage)."""
    assert shred.rep in ("csr", "both")
    rows, local = _root_locate(shred, pos)
    out: Dict[str, jnp.ndarray] = {}

    def sub(node: ShredNode, rows, local):
        out[node.name] = rows
        for ci, child in enumerate(node.children):
            w = node.child_w[ci][rows]
            w_safe = jnp.maximum(w, 1)
            idx = local % w_safe
            local_next = local // w_safe
            hd = node.child_hd[ci][rows]
            crows, clocal = _csr_walk_cached(child.weight, child.nxt, hd, idx)
            crows = jnp.maximum(crows, 0).astype(jnp.int32)
            sub(child, crows, clocal.astype(I64))
            local = local_next

    sub(shred.root, rows, local)
    return out


# ---------------------------------------------------------------------------
# public GET
# ---------------------------------------------------------------------------

def get_rows(shred: Shred, pos: jnp.ndarray, rep: str = None) -> Dict[str, jnp.ndarray]:
    rep = rep or ("usr" if shred.rep in ("usr", "both") else "csr")
    if rep == "usr_fused":
        return usr_get_rows_fused(shred, pos)
    if rep == "usr_paged":
        return usr_get_rows_paged(shred, pos)
    if rep == "usr":
        return usr_get_rows(shred, pos)
    return csr_get_rows(shred, pos)


def gather_columns(shred: Shred, node_rows: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    """Per-node row indices -> owned output columns (the gather half of
    GET).  Shared by the positional routes (``get``) and the fused draw,
    whose kernel already resolved the rows in-launch."""
    out: Dict[str, jnp.ndarray] = {}
    for node in shred.root.nodes():
        rows = node_rows[node.name]
        for v in node.owned:
            out[v] = jnp.take(node.data.column(v), rows, axis=0)
    return out


def get(shred: Shred, pos: jnp.ndarray, rep: str = None) -> Dict[str, jnp.ndarray]:
    """idx.GET(pos): the bag of join tuples at the given flat positions.

    Returns variable -> (k,) array. Lanes whose pos is out of range
    (>= join_size, the caller's invalid sentinel) contain arbitrary values and
    must be masked by the caller — this keeps GET shape-static.
    """
    return gather_columns(shred, get_rows(shred, pos, rep))
