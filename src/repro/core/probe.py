"""Random access into the (virtual) flattened join result (paper §4, Figs 4/5/11/12).

Both GETs are *bulk* by construction: the probe vector ``pos`` is processed
as one data-parallel batch. The paper's sequential "caching optimization"
(resume a linked-list walk / binary search from the previous probe) exists to
amortize work across consecutive probes on a single core; on TPU the same
amortization comes from executing all probes in lockstep vectors, so the bulk
APIs here are the faithful analogue (DESIGN.md §3/§4).

USR-GET: one vectorized binary search per tree node — O(log|db|) depth per
probe, fully parallel across probes. The searches over the *global* exclusive
weight-prefix array are confined to the correct join-key run automatically,
because a run's weight interval [cumw_excl[start], cumw_excl[start+len]) is
contiguous in the global prefix (see shred.py).

Fused USR-GET (rep='usr_fused', DESIGN.md §4 "Fused GET"): the whole
per-node walk collapsed into ONE Pallas kernel launch over the shred's
packed int32 index arena (shred.pack_arena) — root locate + mixed-radix
split + per-child binary search + perm resolution in a single pass, the
arena VMEM-resident across tree levels. Bit-identical to usr_get_rows;
falls back to the per-node path down a static ladder (no arena / arena
over the VMEM budget / Pallas disabled).

CSR-GET: faithful linked-list walk (bounded while_loop), vmapped over probes
— O(log|db| + d) per probe with d the max join degree. Kept as the
paper-faithful baseline; pointer chasing does not vectorize on TPU.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.tree_probe import tree_probe

from .shred import Shred, ShredNode

__all__ = ["get", "get_rows", "csr_get_rows", "usr_get_rows",
           "usr_get_rows_fused", "csr_get_rows_cached", "fused_available",
           "select_rep"]

I64 = jnp.int64

# Fused-GET VMEM budget: arenas above this int32-element count fall back to
# the per-node path (the bsearch table limit, shared — DESIGN.md §9).
FUSED_VMEM_LIMIT = ops.VMEM_PREF_LIMIT


def _root_locate(shred: Shred, pos: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Binary search the root prefix vector: pos -> (root row j, local offset i).

    When the shred carries a packed arena (static: every prefix value fits
    int32), the search runs through ``ops.searchsorted_prefix`` — the
    Pallas branchless-descent kernel — on int32-narrowed views; the int64
    local offset is still derived from the original prefix, so results are
    bit-identical to the XLA path (DESIGN.md §4).
    """
    prefE = shred.root_prefE
    n = shred.root.num_rows
    if shred.packed is not None and n and ops.pallas_preferred():
        j = jnp.minimum(
            ops.searchsorted_prefix(prefE.astype(jnp.int32),
                                    pos.astype(jnp.int32)),
            n - 1)
    else:
        j = jnp.clip(jnp.searchsorted(prefE, pos, side="right") - 1, 0,
                     max(n - 1, 0))
    local = pos - prefE[j]
    return j.astype(jnp.int32), local.astype(I64)


# ---------------------------------------------------------------------------
# USR
# ---------------------------------------------------------------------------

def _usr_child_locate(node: ShredNode, ci: int, rows: jnp.ndarray, idx: jnp.ndarray):
    """Locate offset ``idx`` within the child-ci group of parent ``rows``.

    One global searchsorted over the child's exclusive weight prefix.
    """
    child = node.children[ci]
    start = node.child_start[ci][rows]          # (k,) offsets into sorted order
    cumw_excl = child.cumw_excl                 # (n_c + 1,)
    base = cumw_excl[start]
    target = base + idx
    # smallest jj with cumw_incl[jj] > target  <=>  cumw_excl[jj+1] > target
    jj = jnp.clip(
        jnp.searchsorted(cumw_excl, target, side="right") - 1,
        0,
        child.num_rows - 1,
    )
    local = target - cumw_excl[jj]
    child_rows = child.perm[jj]
    return child_rows.astype(jnp.int32), local.astype(I64)


def _usr_sub(node: ShredNode, rows: jnp.ndarray, local: jnp.ndarray, out: Dict[str, jnp.ndarray]):
    out[node.name] = rows
    # Mixed-radix split (paper eq. 6-7): child 0 is least significant.
    for ci, child in enumerate(node.children):
        w = node.child_w[ci][rows]
        w_safe = jnp.maximum(w, 1)
        idx = local % w_safe
        local = local // w_safe
        crows, clocal = _usr_child_locate(node, ci, rows, idx)
        _usr_sub(child, crows, clocal, out)


def usr_get_rows(shred: Shred, pos: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Resolve probe positions to per-node row indices (USR)."""
    assert shred.rep in ("usr", "both"), "index was not built with USR columns"
    rows, local = _root_locate(shred, pos)
    out: Dict[str, jnp.ndarray] = {}
    _usr_sub(shred.root, rows, local, out)
    return out


# ---------------------------------------------------------------------------
# Fused USR (single Pallas pass over the packed arena, DESIGN.md §4)
# ---------------------------------------------------------------------------

def fused_available(shred: Shred) -> bool:
    """Static verdict: does this shred take the fused kernel path?
    (arena packed + within the VMEM budget + Pallas not disabled)."""
    return (shred.packed is not None
            and shred.packed.layout.size <= FUSED_VMEM_LIMIT
            and ops.pallas_enabled())


def select_rep(shred: Shred, base: str) -> Tuple[str, bool]:
    """The executor policy both plan layers share (DESIGN.md §4): given the
    rep a plan would use (``usr``/``csr``), return ``(rep, narrow)`` —
    upgrade USR to the fused kernel and enable int32-narrowed sampler
    searches iff the shred packed an arena AND the backend prefers Pallas
    (compiled mode / ``REPRO_PALLAS_PREFER=1``). Single source of truth so
    single-device and sharded plans cannot diverge."""
    prefer = ops.pallas_preferred()
    narrow = shred.packed is not None and prefer
    if base == "usr" and prefer and fused_available(shred):
        return "usr_fused", narrow
    return base, narrow


def usr_get_rows_fused(shred: Shred, pos: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Resolve probe positions to per-node row indices in ONE kernel launch.

    Bit-identical to ``usr_get_rows`` (same rows, every node). The fallback
    ladder is static — decided at trace time from the shred's pytree
    structure, never from traced values:

      1. no packed arena (int32 narrowing refused: join > 2^31, or an
         empty node)                      -> per-node USR (or CSR) path;
      2. arena over the VMEM budget       -> per-node path;
      3. ``REPRO_PALLAS_DISABLE=1``       -> per-node path.

    Positions are narrowed to int32 — exact, because a packed arena
    guarantees join_size < 2^31 and callers clamp pads to n - 1 (GET's
    out-of-range lanes are arbitrary-but-masked either way, §4).
    """
    if not fused_available(shred):
        rep = "usr" if shred.rep in ("usr", "both") else "csr"
        return get_rows(shred, pos, rep=rep)
    packed = shred.packed
    k = pos.shape[0]
    tiles = ops.to_tiles(pos.astype(jnp.int32))
    out = tree_probe(packed.arena, tiles, layout=packed.layout,
                     interpret=ops.interpret_default())
    flat = out.reshape(out.shape[0], -1)[:, :k]
    return {name: flat[i] for i, name in enumerate(packed.layout.names)}


# ---------------------------------------------------------------------------
# CSR
# ---------------------------------------------------------------------------

def _csr_walk(child_weight: jnp.ndarray, nxt: jnp.ndarray, hd: jnp.ndarray, idx: jnp.ndarray):
    """Walk the same-key chain until the cumulative weight covers ``idx``.

    Vectorized over probes via vmap; each lane runs its own bounded
    while_loop (paper Fig. 4 lines 11-15, incl. skipping weight-0 tuples).
    """

    def one(h, i):
        def cond(st):
            row, rem = st
            return jnp.logical_and(row >= 0, rem >= child_weight[row])

        def body(st):
            row, rem = st
            return nxt[row], rem - child_weight[row]

        row, rem = jax.lax.while_loop(cond, body, (h, i))
        return row, rem

    return jax.vmap(one)(hd, idx)


def _csr_sub(node: ShredNode, rows: jnp.ndarray, local: jnp.ndarray, out: Dict[str, jnp.ndarray]):
    out[node.name] = rows
    for ci, child in enumerate(node.children):
        w = node.child_w[ci][rows]
        w_safe = jnp.maximum(w, 1)
        idx = local % w_safe
        local = local // w_safe
        hd = node.child_hd[ci][rows]
        crows, clocal = _csr_walk(child.weight, child.nxt, hd, idx)
        crows = jnp.maximum(crows, 0).astype(jnp.int32)  # clamp sentinel lanes
        _csr_sub(child, crows, clocal.astype(I64), out)


def csr_get_rows(shred: Shred, pos: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Resolve probe positions to per-node row indices (CSR)."""
    assert shred.rep in ("csr", "both"), "index was not built with CSR columns"
    rows, local = _root_locate(shred, pos)
    out: Dict[str, jnp.ndarray] = {}
    _csr_sub(shred.root, rows, local, out)
    return out


# ---------------------------------------------------------------------------
# CSR bulk probe with the paper's caching optimization (Fig. 11)
# ---------------------------------------------------------------------------

def _csr_walk_cached(child_weight, nxt, hd, idx):
    """Faithful Fig.-11 semantics: probes are processed in (ascending-
    position) order and a chain traversal resumes from where the previous
    probe on the SAME list stopped, instead of restarting at the head.

    Realized as one lax.scan over the probe vector carrying
    (prev_head, prev_row, prev_consumed): sequential like the paper's loop —
    this is the *paper-faithful baseline*; the vmapped walk in _csr_walk is
    the data-parallel adaptation benchmarked against it (table6 bench).
    """

    def step(carry, inp):
        prev_head, prev_row, prev_consumed = carry
        h, i = inp
        same = jnp.logical_and(prev_head == h, i >= prev_consumed)
        row0 = jnp.where(same, prev_row, h)
        rem0 = jnp.where(same, i - prev_consumed, i)
        consumed0 = jnp.where(same, prev_consumed, 0)

        def cond(st):
            row, rem, _ = st
            return jnp.logical_and(row >= 0, rem >= child_weight[row])

        def body(st):
            row, rem, cons = st
            w = child_weight[row]
            return nxt[row], rem - w, cons + w

        row, rem, consumed = jax.lax.while_loop(cond, body, (row0, rem0, consumed0))
        return (h, row, consumed), (row, rem)

    init = (jnp.int32(-2), jnp.int32(-1), jnp.zeros((), idx.dtype))
    _, (rows, rems) = jax.lax.scan(step, init, (hd, idx))
    return rows, rems


def csr_get_rows_cached(shred: Shred, pos: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """CSR GET with the caching optimization; expects ascending ``pos``
    (samplers emit sorted positions, the paper's usage)."""
    assert shred.rep in ("csr", "both")
    rows, local = _root_locate(shred, pos)
    out: Dict[str, jnp.ndarray] = {}

    def sub(node: ShredNode, rows, local):
        out[node.name] = rows
        for ci, child in enumerate(node.children):
            w = node.child_w[ci][rows]
            w_safe = jnp.maximum(w, 1)
            idx = local % w_safe
            local_next = local // w_safe
            hd = node.child_hd[ci][rows]
            crows, clocal = _csr_walk_cached(child.weight, child.nxt, hd, idx)
            crows = jnp.maximum(crows, 0).astype(jnp.int32)
            sub(child, crows, clocal.astype(I64))
            local = local_next

    sub(shred.root, rows, local)
    return out


# ---------------------------------------------------------------------------
# public GET
# ---------------------------------------------------------------------------

def get_rows(shred: Shred, pos: jnp.ndarray, rep: str = None) -> Dict[str, jnp.ndarray]:
    rep = rep or ("usr" if shred.rep in ("usr", "both") else "csr")
    if rep == "usr_fused":
        return usr_get_rows_fused(shred, pos)
    if rep == "usr":
        return usr_get_rows(shred, pos)
    return csr_get_rows(shred, pos)


def get(shred: Shred, pos: jnp.ndarray, rep: str = None) -> Dict[str, jnp.ndarray]:
    """idx.GET(pos): the bag of join tuples at the given flat positions.

    Returns variable -> (k,) array. Lanes whose pos is out of range
    (>= join_size, the caller's invalid sentinel) contain arbitrary values and
    must be masked by the caller — this keeps GET shape-static.
    """
    node_rows = get_rows(shred, pos, rep)
    out: Dict[str, jnp.ndarray] = {}
    for node in shred.root.nodes():
        rows = node_rows[node.name]
        for v in node.owned:
            out[v] = jnp.take(node.data.column(v), rows, axis=0)
    return out
