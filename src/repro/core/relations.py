"""Column-store relations (struct-of-arrays) with static shapes.

The paper targets a main-memory column store; the JAX-native equivalent is a
struct-of-arrays: a relation is a mapping ``attribute -> 1-D array``, all of
equal length. Tuples are addressed positionally (offset i), exactly like the
paper's ``R[i](ybar)`` notation.

Key design point for XLA: relations are immutable pytrees so they can cross
``jit`` boundaries, and every derived structure (shreds, indexes, samples)
keeps *static* shapes — dangling tuples are retained with weight zero rather
than compacted (see DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Relation", "pack_keys", "dense_keys"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Relation:
    """An immutable column-store relation.

    columns: mapping attribute name -> array of shape (n,).
    """

    columns: Dict[str, jnp.ndarray]

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        names = tuple(sorted(self.columns))
        return tuple(self.columns[n] for n in names), names

    @classmethod
    def tree_unflatten(cls, names, leaves):
        return cls(dict(zip(names, leaves)))

    # -- basic accessors ----------------------------------------------------
    @property
    def attrs(self) -> Tuple[str, ...]:
        return tuple(sorted(self.columns))

    @property
    def num_rows(self) -> int:
        if not self.columns:
            return 0
        return next(iter(self.columns.values())).shape[0]

    def __len__(self) -> int:  # pragma: no cover - convenience
        return self.num_rows

    def column(self, name: str) -> jnp.ndarray:
        return self.columns[name]

    def project(self, attrs: Sequence[str]) -> "Relation":
        return Relation({a: self.columns[a] for a in attrs})

    def rename(self, mapping: Mapping[str, str]) -> "Relation":
        return Relation({mapping.get(a, a): v for a, v in self.columns.items()})

    def take(self, rows: jnp.ndarray) -> "Relation":
        """Gather rows (positional); rows may repeat (bag semantics)."""
        return Relation({a: jnp.take(v, rows, axis=0) for a, v in self.columns.items()})

    def concat(self, other: "Relation") -> "Relation":
        assert set(self.columns) == set(other.columns)
        return Relation(
            {a: jnp.concatenate([self.columns[a], other.columns[a]]) for a in self.columns}
        )

    def to_numpy(self) -> Dict[str, np.ndarray]:
        return {a: np.asarray(v) for a, v in self.columns.items()}

    @staticmethod
    def from_numpy(cols: Mapping[str, np.ndarray]) -> "Relation":
        return Relation({a: jnp.asarray(v) for a, v in cols.items()})

    def validate(self) -> None:
        lens = {v.shape[0] for v in self.columns.values()}
        if len(lens) > 1:
            raise ValueError(f"ragged columns: { {a: v.shape for a, v in self.columns.items()} }")


def pack_keys(cols: Sequence[jnp.ndarray], radices: Sequence[int]) -> jnp.ndarray:
    """Pack multi-attribute integer keys into one int64 via mixed radix.

    ``radices[i]`` must strictly exceed every value of ``cols[i]``.
    """
    assert len(cols) == len(radices) and cols
    key = cols[0].astype(jnp.int64)
    for c, r in zip(cols[1:], radices[1:]):
        key = key * jnp.int64(r) + c.astype(jnp.int64)
    return key


def dense_keys(
    left: Sequence[jnp.ndarray], right: Sequence[jnp.ndarray]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Map multi-column join keys of two relations to one dense int64 id.

    The same attribute tuple receives the same id on both sides, so the ids
    are directly comparable / sortable / ``searchsorted``-able. Implemented by
    a single lexsort over the concatenation of both key sets — the TPU-native
    replacement for the paper's hash-table key grouping (DESIGN.md §3).
    Fully jittable (static shapes).
    """
    assert len(left) == len(right) and left
    m = left[0].shape[0]
    cols = [jnp.concatenate([l.astype(jnp.int64), r.astype(jnp.int64)]) for l, r in zip(left, right)]
    # lexsort uses the LAST key as primary; order doesn't matter for grouping.
    order = jnp.lexsort(tuple(cols))
    sorted_cols = [c[order] for c in cols]
    diff = jnp.zeros(sorted_cols[0].shape, dtype=jnp.bool_)
    for c in sorted_cols:
        diff = diff | jnp.concatenate([jnp.ones((1,), jnp.bool_), c[1:] != c[:-1]])
    gid_sorted = jnp.cumsum(diff.astype(jnp.int64)) - 1
    gid = jnp.zeros_like(gid_sorted).at[order].set(gid_sorted)
    return gid[:m], gid[m:]
