"""Sample-size estimation and capacity planning.

XLA needs static output shapes, so samplers draw into a fixed-capacity
buffer. The expected Poisson sample size and its variance are exactly
computable from the index in O(|N|):
    E[k] = sum_t w_t * p_t,     Var[k] = sum_t w_t * p_t * (1 - p_t)
(independent Bernoulli trials). Capacity = E + sigmas * sqrt(Var) + slack
covers overflow with probability ~1 - 1e-9 at sigmas=6; poisson.py re-draws
with doubled capacity on the (measurable) overflow event.
"""
from __future__ import annotations

import math

import jax.numpy as jnp

__all__ = ["expected_sample_size", "sample_std", "plan_capacity", "round_up"]


def expected_sample_size(w, p) -> jnp.ndarray:
    return jnp.sum(w.astype(jnp.float64) * p.astype(jnp.float64))


def sample_std(w, p) -> jnp.ndarray:
    p = p.astype(jnp.float64)
    return jnp.sqrt(jnp.sum(w.astype(jnp.float64) * p * (1.0 - p)))


def exprace_arrival_mass(w, p) -> jnp.ndarray:
    """Expected raw Poisson-arrival count of the EXPRACE sampler:
    Lam = sum_t w_t * (-ln(1 - min(p_t, 1-p_t))), always <= ln2 * sum w_t/2."""
    p = jnp.clip(p.astype(jnp.float64), 0.0, 1.0)
    pi = jnp.minimum(p, 1.0 - p)
    return jnp.sum(w.astype(jnp.float64) * (-jnp.log1p(-jnp.minimum(pi, 0.5))))


def round_up(x: int, multiple: int = 128) -> int:
    return int(-(-x // multiple)) * multiple


def plan_capacity(mean: float, std: float, sigmas: float = 6.0, slack: int = 64,
                  multiple: int = 128) -> int:
    """Static capacity for a sampler invocation (multiple of 128 for TPU lanes)."""
    cap = int(math.ceil(float(mean) + sigmas * float(std))) + slack
    return round_up(max(cap, multiple), multiple)
