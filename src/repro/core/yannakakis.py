"""Full acyclic join processing and the Materialize-and-Scan baselines.

The same shredded index that backs Poisson sampling computes full joins
(flatten mu*) — the paper's "single engine basis, no regret" point (§6.3).

Baselines (paper §6 "Baseline"):
  M-CSYA / M-USYA : build the CSR/USR index, flatten, per-tuple Bernoulli.
  M-BJ            : pairwise materializing joins (sort-merge here — XLA has
                    no hash tables; retains the defining property of
                    materializing every intermediate), then Bernoulli scan.
"""
from __future__ import annotations

import warnings
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import probe
from .database import Database
from .jointree import JoinQuery, JoinTreeNode, gyo_join_tree
from .relations import Relation, dense_keys
from .shred import Shred, build_shred

__all__ = ["flatten", "full_join", "materialize_and_scan", "binary_join"]

I64 = jnp.int64


def flatten(shred: Shred, rep: Optional[str] = None) -> Dict[str, jnp.ndarray]:
    """mu*(N): materialize the full join result from the index by probing
    every position. (The paper's sequential flatten is an O(n) pointer walk;
    the bulk-probe flatten is the order-identical data-parallel analogue.)"""
    n = int(shred.join_size)
    if n == 0 or shred.root.num_rows == 0:
        return {v: node.data.column(v)[:0]
                for node in shred.root.nodes() for v in node.owned}
    pos = jnp.arange(n, dtype=I64)
    return probe.get(shred, pos, rep=rep)


def full_join(db: Database, query: JoinQuery, rep: str = "usr") -> Dict[str, jnp.ndarray]:
    """Yannakakis via shredded semijoins + flatten (SYA; Prop 4.4/4.5).

    .. deprecated::
        Facade over ``repro.engine.QueryEngine.full_join`` (one throwaway
        engine — the shred index is rebuilt every call). Hold a
        ``QueryEngine`` instead so the index is cached across calls
        (DESIGN.md §7, §13)."""
    from repro.engine import QueryEngine  # lazy: engine imports repro.core

    warnings.warn(
        "core.yannakakis.full_join is deprecated; use "
        "repro.engine.QueryEngine.full_join — it caches the shred index "
        "across calls instead of rebuilding it per query",
        DeprecationWarning, stacklevel=2)
    return QueryEngine(db, rep=rep).full_join(query)


def materialize_and_scan(
    key,
    db: Database,
    query: JoinQuery,
    uniform_p: Optional[float] = None,
    rep: str = "usr",
) -> Tuple[Dict[str, jnp.ndarray], jnp.ndarray]:
    """The naive M&S algorithm: materialize |Q^(db)| tuples, Bernoulli each.

    Returns (full join columns, keep mask); the sample is cols[mask]. Kept
    un-compacted so callers can compare against I&P samples exactly.
    """
    shred = build_shred(db, query, rep=rep)
    cols = flatten(shred, rep="usr" if rep == "both" else rep)
    n = int(shred.join_size)
    if uniform_p is not None:
        pflat = jnp.full((n,), uniform_p, jnp.float64)
    else:
        assert query.prob_var is not None
        pflat = cols[query.prob_var].astype(jnp.float64)
    keep = jax.random.uniform(key, (max(n, 1),), jnp.float64)[:n] < pflat
    return cols, keep


# ---------------------------------------------------------------------------
# M-BJ: pairwise materializing binary joins
# ---------------------------------------------------------------------------

def _pairwise_join(left: Relation, right: Relation) -> Relation:
    """Materializing sort-merge equi-join on the shared variables.

    Executed eagerly (output cardinality is data-dependent) — exactly why the
    paper replaces this plan shape with the index.
    """
    shared = sorted(set(left.attrs) & set(right.attrs))
    m, n = left.num_rows, right.num_rows
    if shared:
        kl, kr = dense_keys([left.column(v) for v in shared],
                            [right.column(v) for v in shared])
    else:
        kl, kr = jnp.zeros((m,), I64), jnp.zeros((n,), I64)
    order = jnp.argsort(kr, stable=True)
    kr_sorted = kr[order]
    s = jnp.searchsorted(kr_sorted, kl, side="left")
    e = jnp.searchsorted(kr_sorted, kl, side="right")
    counts = np.asarray(e - s)
    total = int(counts.sum())
    # Expand: output row t pairs left row lrow[t] with the (t - base)-th
    # element of its run in the sorted right side.
    lrow = np.repeat(np.arange(m), counts)
    base = np.repeat(np.cumsum(counts) - counts, counts)
    offs = np.arange(total) - base
    rpos = np.asarray(s)[lrow] + offs
    rrow = np.asarray(order)[rpos] if total else np.zeros((0,), np.int64)
    out = {v: left.column(v)[jnp.asarray(lrow)] for v in left.attrs}
    for v in right.attrs:
        if v not in out:
            out[v] = right.column(v)[jnp.asarray(rrow)]
    return Relation(out)


def binary_join(db: Database, query: JoinQuery) -> Dict[str, jnp.ndarray]:
    """M-BJ plan: join along the join tree bottom-up, materializing every
    intermediate (join order = post-order of the GYO tree)."""
    tree = gyo_join_tree(query)

    def rec(node: JoinTreeNode) -> Relation:
        rel = db.instance_for(node.atom)
        for c in node.children:
            rel = _pairwise_join(rel, rec(c))
        return rel

    return dict(rec(tree).columns)
