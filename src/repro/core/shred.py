"""Shredded random-access index construction (paper §4).

Builds the chained (CSR) and/or unchained (USR) shredded representation of
the 2NSA expression ``mu*(E)`` derived from a join tree, in O(|db| log |db|)
(one argsort per tree edge — the TPU-native replacement for the paper's O(|db|)
hash grouping; see DESIGN.md §3).

Semantics note (zero-weight retention): dangling tuples are *kept* with
weight 0 instead of being compacted away. The flatten order and prefix
vectors are unaffected (a zero-weight tuple produces no flat tuples), which
keeps every shape static under jit while preserving the paper's semantics
exactly. The bottom-up weight product implements the semijoin reduction of
the nested-semijoin build: a root tuple's weight is exactly the number of
join tuples extending it.

Canonical flatten order: root tuples in physical order; within a nested
attribute, tuples in join-key-sorted (stable) order; combinations in the
paper's mixed-radix order (eq. 6-7, first child least significant). CSR and
USR share this order, so their GETs agree tuple-for-tuple.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .database import Database
from .jointree import Atom, JoinQuery, JoinTreeNode, gyo_join_tree, reroot_for
from .relations import Relation, dense_keys

__all__ = ["ShredNode", "Shred", "build_shred", "build_plan"]

I64 = jnp.int64
I32 = jnp.int32


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ShredNode:
    """One Sigma(Y) of the shredded representation (a join-tree node).

    Arrays describing this node's rows:
      data      Relation over this node's variables (n rows).
      weight    (n,) int64 — flatten weight of the nested tuple at each row.
    Arrays describing this node's role as a *child* (grouped by parent key);
    absent (None) on the root:
      nxt       (n,) int32 CSR same-key chain in sorted order (-1 terminates).
      perm      (n,) int32 USR sorted-order -> row id.
      cumw_excl (n+1,) int64 exclusive prefix of weights in sorted order.
    Per-child link columns (tuples aligned with ``children``):
      child_hd    (n,) int32 head row id in child (CSR).       -1 if empty.
      child_start (n,) int64 start offset into child's sorted order (USR).
      child_len   (n,) int32 run length in child's sorted order.
      child_w     (n,) int64 total weight of the joining child group.
    """

    name: str
    variables: Tuple[str, ...]
    owned: Tuple[str, ...]  # variables this node materializes in GET output
    data: Relation
    weight: jnp.ndarray
    children: Tuple["ShredNode", ...] = ()
    nxt: Optional[jnp.ndarray] = None
    perm: Optional[jnp.ndarray] = None
    cumw_excl: Optional[jnp.ndarray] = None
    child_hd: Tuple[jnp.ndarray, ...] = ()
    child_start: Tuple[jnp.ndarray, ...] = ()
    child_len: Tuple[jnp.ndarray, ...] = ()
    child_w: Tuple[jnp.ndarray, ...] = ()

    _ARRAY_FIELDS = ("data", "weight", "children", "nxt", "perm", "cumw_excl",
                     "child_hd", "child_start", "child_len", "child_w")

    def tree_flatten(self):
        leaves = tuple(getattr(self, f) for f in self._ARRAY_FIELDS)
        aux = (self.name, self.variables, self.owned)
        return leaves, aux

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        name, variables, owned = aux
        return cls(name, variables, owned, *leaves)

    @property
    def num_rows(self) -> int:
        return self.weight.shape[0]

    def nodes(self) -> List["ShredNode"]:
        out = [self]
        for c in self.children:
            out.extend(c.nodes())
        return out


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Shred:
    """The full shredded random-access index: root node + root prefix vector.

    root_prefE: (n_root + 1,) int64 exclusive prefix of root weights;
    root_prefE[-1] == |mu*(N)| == |Q(db)|.
    """

    root: ShredNode
    root_prefE: jnp.ndarray
    rep: str  # 'csr' | 'usr' | 'both' (static)

    def tree_flatten(self):
        return (self.root, self.root_prefE), (self.rep,)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(leaves[0], leaves[1], aux[0])

    @property
    def join_size(self) -> jnp.ndarray:
        """|Q(db)| — the full join cardinality, O(1) from the index."""
        return self.root_prefE[-1]


def build_plan(query: JoinQuery) -> JoinTreeNode:
    """Join tree for the query, rerooted so prob_var is flat at the root
    (Proposition 3.1)."""
    tree = gyo_join_tree(query)
    if query.prob_var is not None:
        tree = reroot_for(tree, query.prob_var)
    return tree


def _group_child(
    parent_rel: Relation,
    parent_vars: Tuple[str, ...],
    child: ShredNode,
    rep: str,
):
    """Group the child by the shared join key; compute the parent's link
    columns. This is the sort-based analogue of CSR-GROUP (paper Fig. 3) and
    of the 2-pass USR grouping, unified (DESIGN.md §3)."""
    join_vars = sorted(set(parent_vars) & set(child.variables))
    m = parent_rel.num_rows
    n = child.num_rows
    if join_vars:
        kp, kc = dense_keys(
            [parent_rel.column(v) for v in join_vars],
            [child.data.column(v) for v in join_vars],
        )
    else:  # cross product: single group
        kp = jnp.zeros((m,), I64)
        kc = jnp.zeros((n,), I64)

    order = jnp.argsort(kc, stable=True).astype(I32)  # sorted pos -> row id
    kc_sorted = kc[order]
    w_sorted = child.weight[order]
    cumw_incl = jnp.cumsum(w_sorted)
    cumw_excl = jnp.concatenate([jnp.zeros((1,), I64), cumw_incl])

    # Parent lookup: run boundaries of each parent's key in the sorted child.
    s = jnp.searchsorted(kc_sorted, kp, side="left")
    e = jnp.searchsorted(kc_sorted, kp, side="right")
    child_len = (e - s).astype(I32)
    child_w = cumw_excl[e] - cumw_excl[s]
    child_start = s.astype(I64)
    # CSR head: first row (in sorted order) of the run; -1 when the run is empty.
    if n == 0:
        child_hd = jnp.full((m,), -1, I32)
    else:
        child_hd = jnp.where(e > s, order[jnp.minimum(s, n - 1)], -1).astype(I32)

    nxt = None
    if rep in ("csr", "both"):
        # nxt[row] = successor row in the same-key sorted run, else -1.
        same_next = jnp.concatenate(
            [kc_sorted[1:] == kc_sorted[:-1], jnp.zeros((1,), jnp.bool_)]
        )
        succ = jnp.concatenate([order[1:], jnp.full((1,), -1, I32)])
        nxt_sorted = jnp.where(same_next, succ, -1).astype(I32)
        nxt = jnp.zeros((n,), I32).at[order].set(nxt_sorted)

    perm = order if rep in ("usr", "both") else None
    cume = cumw_excl if rep in ("usr", "both") else None
    return child_hd, child_start, child_len, child_w, nxt, perm, cume


def _build_node(
    tnode: JoinTreeNode, db: Database, rep: str, owned_above: frozenset
) -> ShredNode:
    rel = db.instance_for(tnode.atom)
    rel.validate()
    variables = tuple(tnode.atom.variables)
    owned = tuple(v for v in dict.fromkeys(variables) if v not in owned_above)
    below = owned_above | set(variables)

    children: List[ShredNode] = []
    for c in tnode.children:
        children.append(_build_node(c, db, rep, below))

    n = rel.num_rows
    weight = jnp.ones((n,), I64)
    hds, starts, lens, ws = [], [], [], []
    new_children = []
    for child in children:
        hd, st, ln, w, nxt, perm, cume = _group_child(rel, variables, child, rep)
        hds.append(hd)
        starts.append(st)
        lens.append(ln)
        ws.append(w)
        new_children.append(
            dataclasses.replace(child, nxt=nxt, perm=perm, cumw_excl=cume)
        )
        weight = weight * w  # zero-weight propagation == semijoin reduction

    return ShredNode(
        name=tnode.atom.name,
        variables=variables,
        owned=owned,
        data=rel.project(tuple(dict.fromkeys(variables))),
        weight=weight,
        children=tuple(new_children),
        child_hd=tuple(hds),
        child_start=tuple(starts),
        child_len=tuple(lens),
        child_w=tuple(ws),
    )


def build_shred(db: Database, query: JoinQuery, rep: str = "usr") -> Shred:
    """Construct the random-access index (Proposition 4.4 / 4.5).

    rep='csr'  — chained representation (linked lists; paper's default).
    rep='usr'  — unchained representation (perm + prefix; TPU default).
    rep='both' — build both sets of link columns (shared grouping pass).
    """
    if rep not in ("csr", "usr", "both"):
        raise ValueError(f"rep must be csr|usr|both, got {rep!r}")
    plan = build_plan(query)
    root = _build_node(plan, db, rep, frozenset())
    prefE = jnp.concatenate([jnp.zeros((1,), I64), jnp.cumsum(root.weight)])
    return Shred(root=root, root_prefE=prefE, rep=rep)
