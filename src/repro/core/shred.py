"""Shredded random-access index construction (paper §4).

Builds the chained (CSR) and/or unchained (USR) shredded representation of
the 2NSA expression ``mu*(E)`` derived from a join tree, in O(|db| log |db|)
(one argsort per tree edge — the TPU-native replacement for the paper's O(|db|)
hash grouping; see DESIGN.md §3).

Semantics note (zero-weight retention): dangling tuples are *kept* with
weight 0 instead of being compacted away. The flatten order and prefix
vectors are unaffected (a zero-weight tuple produces no flat tuples), which
keeps every shape static under jit while preserving the paper's semantics
exactly. The bottom-up weight product implements the semijoin reduction of
the nested-semijoin build: a root tuple's weight is exactly the number of
join tuples extending it.

Canonical flatten order: root tuples in physical order; within a nested
attribute, tuples in join-key-sorted (stable) order; combinations in the
paper's mixed-radix order (eq. 6-7, first child least significant). CSR and
USR share this order, so their GETs agree tuple-for-tuple.

Incremental maintenance (DESIGN.md §11): the build is split into reusable
passes (edge keys -> sorted group -> link columns), and
``reshred_incremental`` merges a ``DeltaBatch`` into an existing shred —
sorting only the delta and re-deriving the affected link columns — with the
contract that the result is bit-identical to a from-scratch
``build_shred(db.apply(delta), query, rep)``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as _kops

from .database import Database
from .jointree import Atom, JoinQuery, JoinTreeNode, gyo_join_tree, reroot_for
from .relations import Relation, dense_keys

__all__ = ["ShredNode", "Shred", "build_shred", "build_plan",
           "reshred_incremental", "PackedShred", "PagedArena", "ArenaLayout",
           "ArenaEdge", "pack_arena", "pack_index"]

I64 = jnp.int64
I32 = jnp.int32
_I32_MAX = (1 << 31) - 1


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ShredNode:
    """One Sigma(Y) of the shredded representation (a join-tree node).

    Arrays describing this node's rows:
      data      Relation over this node's variables (n rows).
      weight    (n,) int64 — flatten weight of the nested tuple at each row.
    Arrays describing this node's role as a *child* (grouped by parent key);
    absent (None) on the root:
      nxt       (n,) int32 CSR same-key chain in sorted order (-1 terminates;
                built for rep 'csr'/'both').
      perm      (n,) int32 sorted-order -> row id. Always built: USR-GET
                probes it, and incremental reshred merges deltas into it
                (DESIGN.md §11), so CSR indexes carry it too.
      cumw_excl (n+1,) int64 exclusive prefix of weights in sorted order
                (always built, same reasons).
    Per-child link columns (tuples aligned with ``children``):
      child_hd    (n,) int32 head row id in child (CSR).       -1 if empty.
      child_start (n,) int64 start offset into child's sorted order (USR).
      child_len   (n,) int32 run length in child's sorted order.
      child_w     (n,) int64 total weight of the joining child group.
    """

    name: str
    variables: Tuple[str, ...]
    owned: Tuple[str, ...]  # variables this node materializes in GET output
    data: Relation
    weight: jnp.ndarray
    children: Tuple["ShredNode", ...] = ()
    nxt: Optional[jnp.ndarray] = None
    perm: Optional[jnp.ndarray] = None
    cumw_excl: Optional[jnp.ndarray] = None
    child_hd: Tuple[jnp.ndarray, ...] = ()
    child_start: Tuple[jnp.ndarray, ...] = ()
    child_len: Tuple[jnp.ndarray, ...] = ()
    child_w: Tuple[jnp.ndarray, ...] = ()

    _ARRAY_FIELDS = ("data", "weight", "children", "nxt", "perm", "cumw_excl",
                     "child_hd", "child_start", "child_len", "child_w")

    def tree_flatten(self):
        leaves = tuple(getattr(self, f) for f in self._ARRAY_FIELDS)
        aux = (self.name, self.variables, self.owned)
        return leaves, aux

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        name, variables, owned = aux
        return cls(name, variables, owned, *leaves)

    @property
    def num_rows(self) -> int:
        return self.weight.shape[0]

    def nodes(self) -> List["ShredNode"]:
        out = [self]
        for c in self.children:
            out.extend(c.nodes())
        return out


# ---------------------------------------------------------------------------
# Packed index arena (fused GET, DESIGN.md §4)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ArenaEdge:
    """Static arena addressing of one tree edge (all offsets are element
    indices into the flat int32 arena; baked into the fused kernel)."""

    parent: int    # output slot of the parent node
    slot: int      # output slot of the child node (pre-order)
    cs_off: int    # parent's child_start column for this edge (n_parent,)
    cw_off: int    # parent's child_w column for this edge (n_parent,)
    ce_off: int    # child's cumw_excl (n_child + 1,)
    perm_off: int  # child's perm (n_child,)
    n_child: int


@dataclasses.dataclass(frozen=True)
class ArenaLayout:
    """Hashable static layout of a packed arena: slot names (pre-order,
    slot 0 = root), root prefix length, and per-edge offsets. Passed as a
    static jit argument to ``kernels.tree_probe.tree_probe``."""

    names: Tuple[str, ...]
    n_root: int
    root_len: int  # n_root + 1 (root_prefE lives at offset 0)
    edges: Tuple[ArenaEdge, ...]
    size: int      # total arena length in int32 elements

    @property
    def num_slots(self) -> int:
        return len(self.names)

    def page_bounds(self) -> Tuple[Tuple[int, int], ...]:
        """Per-page ``(start, end)`` element ranges of the paged split
        (DESIGN.md §15): page 0 is the root prefix, page ``i+1`` is edge
        ``i``'s four columns — which ``pack_arena`` lays out consecutively
        (``child_start``/``child_w``/``cumw_excl``/``perm``), so every page
        is one contiguous slice of the monolithic arena and the pages
        concatenate back to it exactly."""
        return ((0, self.root_len),) + tuple(
            (e.cs_off, e.perm_off + e.n_child) for e in self.edges)

    @property
    def max_page(self) -> int:
        """Largest page in int32 elements — the VMEM working set of the
        paged probe (two double-buffered pages of this size), the quantity
        the paged rung gates against ``KernelPolicy.vmem_limit``."""
        return max(end - start for start, end in self.page_bounds())


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedShred:
    """The fused-GET index arena: every per-node table (``root_prefE``,
    ``child_start``, ``child_w``, ``cumw_excl``, ``perm``) narrowed to
    int32 and packed into ONE flat buffer + a static offset layout, so the
    fused tree-probe kernel keeps the whole index VMEM-resident across
    tree levels (DESIGN.md §4 "Fused GET"). Built iff every value fits
    int32 (join_size < 2^31 — the narrowing rule; otherwise the int64
    per-node path stands, DESIGN.md §9)."""

    arena: jnp.ndarray  # (size,) int32
    layout: ArenaLayout

    def tree_flatten(self):
        return (self.arena,), (self.layout,)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(leaves[0], aux[0])


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PagedArena:
    """The page-sliced sibling of ``PackedShred`` (DESIGN.md §15): the same
    int32 index, same ``ArenaLayout``, but held as one array per page
    (``layout.page_bounds()`` — root prefix, then one page per tree edge)
    instead of one monolithic buffer. Built when the arena exceeds the
    VMEM budget but every page fits it: the paged tree-probe streams the
    pages through VMEM (double-buffered DMA on TPU, one launch per page on
    GPU/CPU) instead of dropping to the ~4-9x-slower per-node path.

    Pages are contiguous slices of the monolithic arena, so all in-page
    offsets are the ``ArenaEdge`` offsets rebased by the page start —
    static arithmetic, no extra metadata."""

    pages: Tuple[jnp.ndarray, ...]  # per-page int32, sizes per page_bounds()
    layout: ArenaLayout

    def tree_flatten(self):
        return (self.pages,), (self.layout,)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(leaves[0], aux[0])

    @classmethod
    def from_packed(cls, packed: "PackedShred") -> "PagedArena":
        """Page-slice an existing monolithic arena (static bounds — traces
        cleanly, so a call-time policy with a shrunken ``vmem_limit`` can
        derive the paged view of an already-packed shred on the fly)."""
        pages = tuple(packed.arena[s:e]
                      for s, e in packed.layout.page_bounds())
        return cls(pages, packed.layout)


def _arena_pieces(root: "ShredNode", root_prefE: jnp.ndarray):
    """The shared packing walk: the arena's numpy pieces + its layout, or
    ``None`` when int32 narrowing is refused (an empty node — nothing to
    probe, callers guard ``join_size == 0`` anyway — or any value above
    int32 range, the documented int64 fallback, DESIGN.md §9).

    Piece order: ``root_prefE`` at offset 0, then per tree edge in the
    exact pre-order the per-node GET recurses (``probe._usr_sub``):
    ``child_start``, ``child_w``, ``cumw_excl``, ``perm``.
    """
    if any(nd.num_rows == 0 for nd in root.nodes()):
        return None
    pieces = [np.asarray(root_prefE)]
    names = [root.name]
    edges: List[ArenaEdge] = []
    off = pieces[0].shape[0]

    def walk(node: "ShredNode", parent_slot: int) -> None:
        nonlocal off
        for ci, child in enumerate(node.children):
            slot = len(names)
            names.append(child.name)
            cols = (np.asarray(node.child_start[ci]),
                    np.asarray(node.child_w[ci]),
                    np.asarray(child.cumw_excl),
                    np.asarray(child.perm))
            offs = []
            for c in cols:
                offs.append(off)
                off += c.shape[0]
            pieces.extend(cols)
            edges.append(ArenaEdge(parent_slot, slot, offs[0], offs[1],
                                   offs[2], offs[3], child.num_rows))
            walk(child, slot)

    walk(root, 0)
    for p in pieces:
        if p.size and int(p.max()) > _I32_MAX:
            return None  # narrowing rule: values must fit int32
    layout = ArenaLayout(tuple(names), root.num_rows,
                         pieces[0].shape[0], tuple(edges), off)
    return pieces, layout


def pack_arena(root: "ShredNode",
               root_prefE: jnp.ndarray) -> Optional["PackedShred"]:
    """Pack a shred's probe tables into a monolithic ``PackedShred`` arena,
    or return ``None`` when the fused path cannot apply (``_arena_pieces``
    narrowing refusals, or a total size over the default VMEM table budget
    — an over-budget monolith would be rejected by every consumer, so the
    int32 copy would only waste device memory). Kept as the monolith-only
    back-compat entry point; index builds go through ``pack_index``, which
    adds the paged alternative."""
    got = _arena_pieces(root, root_prefE)
    if got is None:
        return None
    pieces, layout = got
    if layout.size > _kops.VMEM_PREF_LIMIT:
        return None
    arena = jnp.asarray(
        np.concatenate([p.astype(np.int32) for p in pieces]))
    return PackedShred(arena, layout)


def pack_index(root: "ShredNode", root_prefE: jnp.ndarray, policy=None
               ) -> Tuple[Optional["PackedShred"], Optional["PagedArena"]]:
    """Pack a shred's probe tables for the fused GET/draw kernels, choosing
    the representation by size against the active ``KernelPolicy``
    (DESIGN.md §15). Returns ``(packed, paged)``, at most one non-None:

      * arena fits ``vmem_limit``                     -> monolithic
        ``PackedShred`` (the fused one-launch rung);
      * over the budget, but every page fits it and the total is within
        ``config.PAGED_PACK_LIMIT``                   -> ``PagedArena``
        (the paged streaming rung);
      * narrowing refused, or too large even to page  -> ``(None, None)``
        (the int64 per-node path stands, DESIGN.md §9).

    Mutually exclusive by construction — the engine never pays 2x device
    memory for the same int32 index, and a monolithic arena can still be
    page-sliced at call time (``PagedArena.from_packed``) when a scoped
    policy shrinks the budget under it.
    """
    from repro import config  # local: keep shred importable sans config cycle

    pol = config.current_policy(policy)
    got = _arena_pieces(root, root_prefE)
    if got is None:
        return None, None
    pieces, layout = got
    if layout.size <= pol.vmem_limit:
        arena = jnp.asarray(
            np.concatenate([p.astype(np.int32) for p in pieces]))
        return PackedShred(arena, layout), None
    if (layout.size <= config.PAGED_PACK_LIMIT
            and layout.max_page <= pol.vmem_limit):
        bounds = layout.page_bounds()
        flat = np.concatenate([p.astype(np.int32) for p in pieces])
        pages = tuple(jnp.asarray(flat[s:e]) for s, e in bounds)
        return None, PagedArena(pages, layout)
    return None, None


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Shred:
    """The full shredded random-access index: root node + root prefix vector.

    root_prefE: (n_root + 1,) int64 exclusive prefix of root weights;
    root_prefE[-1] == |mu*(N)| == |Q(db)|.
    packed: the optional fused-GET int32 arena (``pack_index``); ``None``
    when narrowing does not apply or the arena is paged instead — its
    presence is *static* (part of the pytree structure), so jitted
    executors dispatch on it at trace time.
    paged: the page-sliced arena (``PagedArena``) when the index exceeds
    the VMEM budget but pages fit it (DESIGN.md §15); mutually exclusive
    with ``packed``, equally static.
    """

    root: ShredNode
    root_prefE: jnp.ndarray
    rep: str  # 'csr' | 'usr' | 'both' (static)
    packed: Optional[PackedShred] = None
    paged: Optional[PagedArena] = None

    def tree_flatten(self):
        return ((self.root, self.root_prefE, self.packed, self.paged),
                (self.rep,))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(leaves[0], leaves[1], aux[0], leaves[2], leaves[3])

    @property
    def join_size(self) -> jnp.ndarray:
        """|Q(db)| — the full join cardinality, O(1) from the index."""
        return self.root_prefE[-1]


def build_plan(query: JoinQuery) -> JoinTreeNode:
    """Join tree for the query, rerooted so prob_var is flat at the root
    (Proposition 3.1)."""
    tree = gyo_join_tree(query)
    if query.prob_var is not None:
        tree = reroot_for(tree, query.prob_var)
    return tree


def _edge_join_vars(parent_vars: Sequence[str],
                    child_vars: Sequence[str]) -> List[str]:
    """The join attributes of one tree edge, in the canonical (sorted)
    order the grouping keys are built from."""
    return sorted(set(parent_vars) & set(child_vars))


def _edge_keys(parent_rel: Relation, parent_vars: Tuple[str, ...],
               child: ShredNode) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pass 1 — edge keys: one dense int64 join key per parent / child row.

    A keyless edge (disjoint atoms, i.e. a cross product) maps every row to
    the single key 0: one all-encompassing group, which the downstream
    passes and both GETs handle uniformly (see jointree._gyo_parents)."""
    join_vars = _edge_join_vars(parent_vars, child.variables)
    if join_vars:
        return dense_keys(
            [parent_rel.column(v) for v in join_vars],
            [child.data.column(v) for v in join_vars],
        )
    return (jnp.zeros((parent_rel.num_rows,), I64),
            jnp.zeros((child.num_rows,), I64))


def _sorted_group(kc: jnp.ndarray, weight: jnp.ndarray):
    """Pass 2 — sorted grouping: stable-sort the child by join key and
    prefix-sum its weights. ``order`` is sorted position -> row id; ties
    keep physical row order (the canonical flatten order depends on it)."""
    order = jnp.argsort(kc, stable=True).astype(I32)
    kc_sorted = kc[order]
    w_sorted = weight[order]
    cumw_excl = jnp.concatenate([jnp.zeros((1,), I64), jnp.cumsum(w_sorted)])
    return order, kc_sorted, cumw_excl


def _link_columns(kp: jnp.ndarray, kc_sorted: jnp.ndarray,
                  order: jnp.ndarray, cumw_excl: jnp.ndarray, rep: str):
    """Pass 3 — link columns: each parent row's run boundaries in the sorted
    child (USR) and the chained successor lists (CSR)."""
    n = order.shape[0]
    s = jnp.searchsorted(kc_sorted, kp, side="left")
    e = jnp.searchsorted(kc_sorted, kp, side="right")
    child_len = (e - s).astype(I32)
    child_w = cumw_excl[e] - cumw_excl[s]
    child_start = s.astype(I64)
    # CSR head: first row (in sorted order) of the run; -1 when the run is empty.
    if n == 0:
        child_hd = jnp.full((kp.shape[0],), -1, I32)
    else:
        child_hd = jnp.where(e > s, order[jnp.minimum(s, n - 1)], -1).astype(I32)

    nxt = None
    if rep in ("csr", "both"):
        # nxt[row] = successor row in the same-key sorted run, else -1.
        same_next = jnp.concatenate(
            [kc_sorted[1:] == kc_sorted[:-1], jnp.zeros((1,), jnp.bool_)]
        )
        succ = jnp.concatenate([order[1:], jnp.full((1,), -1, I32)])
        nxt_sorted = jnp.where(same_next, succ, -1).astype(I32)
        nxt = jnp.zeros((n,), I32).at[order].set(nxt_sorted)
    return child_hd, child_start, child_len, child_w, nxt


def _group_child(
    parent_rel: Relation,
    parent_vars: Tuple[str, ...],
    child: ShredNode,
    rep: str,
):
    """Group the child by the shared join key; compute the parent's link
    columns. This is the sort-based analogue of CSR-GROUP (paper Fig. 3) and
    of the 2-pass USR grouping, unified (DESIGN.md §3) — now a composition
    of the three reusable passes ``reshred_incremental`` also merges into
    (DESIGN.md §11)."""
    kp, kc = _edge_keys(parent_rel, parent_vars, child)
    order, kc_sorted, cumw_excl = _sorted_group(kc, child.weight)
    child_hd, child_start, child_len, child_w, nxt = _link_columns(
        kp, kc_sorted, order, cumw_excl, rep)
    return child_hd, child_start, child_len, child_w, nxt, order, cumw_excl


def _build_node(
    tnode: JoinTreeNode, db: Database, rep: str, owned_above: frozenset
) -> ShredNode:
    rel = db.instance_for(tnode.atom)
    rel.validate()
    variables = tuple(tnode.atom.variables)
    owned = tuple(v for v in dict.fromkeys(variables) if v not in owned_above)
    below = owned_above | set(variables)

    children: List[ShredNode] = []
    for c in tnode.children:
        children.append(_build_node(c, db, rep, below))

    n = rel.num_rows
    weight = jnp.ones((n,), I64)
    hds, starts, lens, ws = [], [], [], []
    new_children = []
    for child in children:
        hd, st, ln, w, nxt, perm, cume = _group_child(rel, variables, child, rep)
        hds.append(hd)
        starts.append(st)
        lens.append(ln)
        ws.append(w)
        new_children.append(
            dataclasses.replace(child, nxt=nxt, perm=perm, cumw_excl=cume)
        )
        weight = weight * w  # zero-weight propagation == semijoin reduction

    return ShredNode(
        name=tnode.atom.name,
        variables=variables,
        owned=owned,
        data=rel.project(tuple(dict.fromkeys(variables))),
        weight=weight,
        children=tuple(new_children),
        child_hd=tuple(hds),
        child_start=tuple(starts),
        child_len=tuple(lens),
        child_w=tuple(ws),
    )


def build_shred(db: Database, query: JoinQuery, rep: str = "usr") -> Shred:
    """Construct the random-access index (Proposition 4.4 / 4.5).

    rep='csr'  — chained representation (linked lists; paper's default).
    rep='usr'  — unchained representation (perm + prefix; TPU default).
    rep='both' — build both sets of link columns (shared grouping pass).
    """
    if rep not in ("csr", "usr", "both"):
        raise ValueError(f"rep must be csr|usr|both, got {rep!r}")
    plan = build_plan(query)
    root = _build_node(plan, db, rep, frozenset())
    prefE = jnp.concatenate([jnp.zeros((1,), I64), jnp.cumsum(root.weight)])
    packed, paged = pack_index(root, prefE)
    return Shred(root=root, root_prefE=prefE, rep=rep,
                 packed=packed, paged=paged)


# ---------------------------------------------------------------------------
# Incremental maintenance (DESIGN.md §11)
# ---------------------------------------------------------------------------
#
# ``reshred_incremental`` replays a ``DeltaBatch`` through the three build
# passes without re-sorting the unchanged rows: the delta is sorted on its
# own (O(|delta| log |delta|)) and *merged* into the existing sorted
# grouping; link columns and prefix vectors are re-derived with linear
# scans / binary searches only on the edges whose endpoints changed. The
# merge runs host-side in numpy — it is bulk data movement, not traced
# computation — and its output is bit-identical to a from-scratch
# ``build_shred`` of the post-delta snapshot (property-tested for both
# representations in tests/test_delta.py).

_PACK_LIMIT = 1 << 62  # packed multi-column keys must stay well inside int64


def _np_i64(col) -> np.ndarray:
    """Join-key column as int64, matching dense_keys' cast semantics."""
    return np.asarray(col).astype(np.int64)


def _lex_scalar_keys(sorted_cols: List[np.ndarray],
                     query_cols: List[np.ndarray]):
    """Collapse multi-column keys on both sides into order-isomorphic int64
    scalars. The total order matches ``dense_keys`` (lexsort convention:
    the LAST column is the primary sort key). Returns None when the value
    ranges cannot be packed into an int64 without overflow."""
    if len(sorted_cols) == 1:
        return sorted_cols[0], query_cols[0]
    mins, widths = [], []
    for sc, qc in zip(sorted_cols, query_cols):
        vals = [c for c in (sc, qc) if c.size]
        lo = min(int(c.min()) for c in vals) if vals else 0
        hi = max(int(c.max()) for c in vals) if vals else 0
        mins.append(lo)
        widths.append(hi - lo + 1)
    total = 1
    for w in widths:
        total *= w
        if total >= _PACK_LIMIT:
            return None

    def pack(cols):
        acc = cols[-1] - mins[-1]
        for c, lo, w in zip(cols[-2::-1], mins[-2::-1], widths[-2::-1]):
            acc = acc * w + (c - lo)
        return acc

    return pack(sorted_cols), pack(query_cols)


def _dense_gids_np(sorted_cols: List[np.ndarray],
                   query_cols: List[np.ndarray]):
    """numpy mirror of ``relations.dense_keys`` for the rare multi-column
    edges whose raw value ranges overflow packing: rank the union of key
    tuples. O((n+m) log (n+m)) — the overflow fallback, not the fast path."""
    n = sorted_cols[0].shape[0]
    cols = [np.concatenate([s, q]) for s, q in zip(sorted_cols, query_cols)]
    order = np.lexsort(tuple(cols))
    diff = np.zeros(order.shape, np.bool_)
    diff[0:1] = True
    for c in cols:
        cs = c[order]
        diff[1:] |= cs[1:] != cs[:-1]
    gid_sorted = np.cumsum(diff.astype(np.int64)) - 1
    gid = np.empty_like(gid_sorted)
    gid[order] = gid_sorted
    return gid[:n], gid[n:]


def _lex_searchsorted(sorted_cols: List[np.ndarray],
                      query_cols: List[np.ndarray], side: str) -> np.ndarray:
    """searchsorted of multi-column keys into a lexicographically sorted
    multi-column sequence (dense_keys total order)."""
    packed = _lex_scalar_keys(sorted_cols, query_cols)
    if packed is None:
        packed = _dense_gids_np(sorted_cols, query_cols)
    return np.searchsorted(packed[0], packed[1], side=side)


def _instance_colmap(atom: Atom, schema: Tuple[str, ...]) -> Dict[str, str]:
    """variable -> physical column, matching Database.instance_for (for a
    variable repeated in the atom, the last occurrence wins)."""
    return {v: c for c, v in zip(schema, atom.variables)}


def _apply_instance_delta(data: Relation, atom: Atom,
                          schema: Tuple[str, ...], rd) -> Relation:
    """The node's post-delta data relation (survivors then inserts), built
    exactly like ``Database.apply`` + ``instance_for`` would (numpy host
    path: one device_put per output column, no eager-op dispatches)."""
    colmap = _instance_colmap(atom, schema)
    keep = ~rd.delete_mask if rd.delete_mask is not None else None
    cols = {}
    for v, col in data.columns.items():
        nv = np.asarray(col)
        if keep is not None:
            nv = nv[keep]
        if rd.inserts:
            ins = np.asarray(rd.inserts[colmap[v]]).astype(nv.dtype)
            nv = np.concatenate([nv, ins])
        cols[v] = jnp.asarray(nv)
    return Relation(cols)


def _edge_key_cols(data: Relation, join_vars: List[str],
                   n: int) -> List[np.ndarray]:
    """Row-order int64 key columns of one edge endpoint; a keyless edge
    (cross product) gets the single all-zero pseudo column."""
    if join_vars:
        return [_np_i64(data.column(v)) for v in join_vars]
    return [np.zeros((n,), np.int64)]


@dataclasses.dataclass
class _MergedOrder:
    """One edge's merged sorted grouping, plus the pieces the parent-side
    boundary adjustment reuses (keep mask in old sorted order, the sorted
    insert keys)."""

    perm: np.ndarray                  # (n_new,) int32 sorted pos -> row id
    keys_sorted: List[np.ndarray]     # merged int64 key cols, sorted order
    keep_sorted: np.ndarray           # (n_old,) bool over OLD sorted order
    ins_keys: List[np.ndarray]        # insert key cols, sorted among selves


def _merge_sorted_order(old_child: ShredNode, join_vars: List[str],
                        atom: Atom, schema: Tuple[str, ...],
                        rd) -> _MergedOrder:
    """Merge a child-relation delta into the child's sorted grouping order.

    Survivors keep their relative (already sorted) order; inserts are
    sorted among themselves and merged in, ties resolved survivors-first
    then insert order — exactly the stable argsort of the post-delta rows.
    """
    perm_old = np.asarray(old_child.perm)
    n_old = old_child.num_rows
    keep = (~rd.delete_mask if rd.delete_mask is not None
            else np.ones((n_old,), np.bool_))
    new_id = np.cumsum(keep) - 1                     # old row -> new row id
    keep_sorted = keep[perm_old]
    surv_rows_old = perm_old[keep_sorted]            # sorted order, filtered
    surv_ids = new_id[surv_rows_old] if surv_rows_old.size else surv_rows_old
    n_surv = int(keep.sum())

    kc_old = _edge_key_cols(old_child.data, join_vars, n_old)
    surv_keys = [k[surv_rows_old] for k in kc_old]

    colmap = _instance_colmap(atom, schema)
    d = rd.num_inserts
    if join_vars and rd.inserts:
        ins_raw = [_np_i64(rd.inserts[colmap[v]]) for v in join_vars]
    else:  # keyless edge, or a delete-only delta (d == 0)
        ins_raw = [np.zeros((d,), np.int64)] * max(len(join_vars), 1)
    ins_order = np.lexsort(tuple(ins_raw))           # stable, last col primary
    ins_keys = [k[ins_order] for k in ins_raw]

    # Insertion points: ties place inserts after equal survivors ('right'),
    # matching stable argsort (survivor ids < insert ids).
    ins_pos = _lex_searchsorted(surv_keys, ins_keys, "right")
    fpos_surv = np.arange(n_surv) + np.searchsorted(
        ins_pos, np.arange(n_surv), side="right")
    fpos_ins = ins_pos + np.arange(d)

    perm_new = np.empty((n_surv + d,), np.int32)
    perm_new[fpos_surv] = surv_ids.astype(np.int32)
    perm_new[fpos_ins] = (n_surv + ins_order).astype(np.int32)
    keys_new = []
    for sk, ik in zip(surv_keys, ins_keys):
        col = np.empty((n_surv + d,), np.int64)
        col[fpos_surv] = sk
        col[fpos_ins] = ik
        keys_new.append(col)
    return _MergedOrder(perm_new, keys_new, keep_sorted, ins_keys)


def _np_nxt(keys_sorted: List[np.ndarray], perm: np.ndarray) -> np.ndarray:
    """numpy re-derivation of the CSR chain over merged sorted keys."""
    n = perm.shape[0]
    same_next = np.ones((n,), np.bool_) if n else np.zeros((0,), np.bool_)
    if n:
        same_next[-1] = False
        for k in keys_sorted:
            same_next[:-1] &= k[1:] == k[:-1]
    succ = np.concatenate([perm[1:], np.full((1,), -1, np.int32)])
    nxt_sorted = np.where(same_next, succ, -1).astype(np.int32)
    nxt = np.zeros((n,), np.int32)
    nxt[perm] = nxt_sorted
    return nxt


def _reshred_node(tnode: JoinTreeNode, snode: ShredNode, db: Database,
                  delta, rep: str):
    """Post-order walk mirroring ``_build_node``. Returns
    ``(new_node, rows_changed, weight_changed)``; untouched subtrees are
    returned by reference (``new_node is snode``)."""
    atom = tnode.atom
    rd = delta.relations.get(atom.relation)
    rows_changed = rd is not None

    results = [_reshred_node(tc, sc, db, delta, rep)
               for tc, sc in zip(tnode.children, snode.children)]
    if not rows_changed and all(nc is sc for (nc, _, _), sc
                                in zip(results, snode.children)):
        return snode, False, False

    schema = db.schemas[atom.relation]
    if rows_changed:
        data_new = _apply_instance_delta(snode.data, atom, schema, rd)
    else:
        data_new = snode.data
    m_new = data_new.num_rows

    weight = np.ones((m_new,), np.int64)
    hds, starts, lens, ws, new_children = [], [], [], [], []
    weight_changed = rows_changed
    for i, ((cnode, c_rows, c_weight), c_old) in enumerate(
            zip(results, snode.children)):
        if not rows_changed and not c_rows and not c_weight:
            # Edge untouched: every link column carries over.
            hds.append(snode.child_hd[i])
            starts.append(snode.child_start[i])
            lens.append(snode.child_len[i])
            ws.append(snode.child_w[i])
            new_children.append(cnode)
            weight *= np.asarray(snode.child_w[i])
            continue
        weight_changed = True
        join_vars = _edge_join_vars(snode.variables, cnode.variables)
        tc_atom = tnode.children[i].atom
        merged = None
        if c_rows:
            merged = _merge_sorted_order(
                c_old, join_vars, tc_atom,
                db.schemas[tc_atom.relation], delta.relations[tc_atom.relation])
            perm = merged.perm
        else:
            perm = np.asarray(c_old.perm)
        if c_rows or c_weight:
            w_sorted = np.asarray(cnode.weight)[perm]
            cumw_excl = np.concatenate(
                [np.zeros((1,), np.int64), np.cumsum(w_sorted)])
        else:
            cumw_excl = np.asarray(c_old.cumw_excl)

        # -- run boundaries (s, e) per parent row -----------------------------
        # Delta-proportional re-derivation, never a full child searchsorted:
        # surviving parent rows *adjust* their stored boundaries (subtract
        # the child keys the delta deleted before them, add the ones it
        # inserted — count arithmetic, bit-exact vs searchsorted), and only
        # parent-inserted rows binary-search the child's sorted keys.
        s_old = np.asarray(snode.child_start[i])
        e_old = s_old + np.asarray(snode.child_len[i])
        if not rows_changed and not c_rows:
            # Only subtree weights moved: the sorted order and every run
            # boundary are unchanged; refresh the weight-dependent columns.
            s, e = s_old, e_old
            hd, ln = snode.child_hd[i], snode.child_len[i]
        else:
            kp_cols = _edge_key_cols(data_new, join_vars, m_new)
            d_p = 0
            s_surv, e_surv = s_old, e_old
            if rows_changed:
                rd_p = delta.relations[atom.relation]
                d_p = rd_p.num_inserts
                if rd_p.delete_mask is not None:
                    keep_p = ~rd_p.delete_mask
                    s_surv, e_surv = s_old[keep_p], e_old[keep_p]
            m_surv = m_new - d_p
            kp_surv = [k[:m_surv] for k in kp_cols]  # survivors lead (canon)
            kp_ins = [k[m_surv:] for k in kp_cols]
            keys_sorted = merged.keys_sorted if merged is not None else None
            if c_rows:
                cum_del = np.concatenate(
                    [np.zeros((1,), np.int64),
                     np.cumsum(~merged.keep_sorted)])
                s_surv = (s_surv - cum_del[s_surv]
                          + _lex_searchsorted(merged.ins_keys, kp_surv, "left"))
                e_surv = (e_surv - cum_del[e_surv]
                          + _lex_searchsorted(merged.ins_keys, kp_surv, "right"))
            if d_p:
                if keys_sorted is None:
                    keys_sorted = [k[perm] for k in _edge_key_cols(
                        cnode.data, join_vars, cnode.num_rows)]
                s = np.concatenate(
                    [s_surv, _lex_searchsorted(keys_sorted, kp_ins, "left")])
                e = np.concatenate(
                    [e_surv, _lex_searchsorted(keys_sorted, kp_ins, "right")])
            else:
                s, e = s_surv, e_surv
            n_child = perm.shape[0]
            if n_child == 0:
                hd = np.full((m_new,), -1, np.int32)
            else:
                hd = np.where(e > s, perm[np.minimum(s, n_child - 1)],
                              -1).astype(np.int32)
            ln = (e - s).astype(np.int32)
            hd, ln = jnp.asarray(hd), jnp.asarray(ln)
        w = cumw_excl[e] - cumw_excl[s]
        start = (snode.child_start[i] if s is s_old
                 else jnp.asarray(s.astype(np.int64)))

        if rep in ("csr", "both") and c_rows:
            nxt = jnp.asarray(_np_nxt(merged.keys_sorted, perm))
        else:
            nxt = c_old.nxt
        new_children.append(dataclasses.replace(
            cnode,
            nxt=nxt,
            perm=jnp.asarray(perm) if c_rows else c_old.perm,
            cumw_excl=(jnp.asarray(cumw_excl) if (c_rows or c_weight)
                       else c_old.cumw_excl),
        ))
        hds.append(hd)
        starts.append(start)
        lens.append(ln)
        ws.append(jnp.asarray(w))
        weight *= np.asarray(w)

    new_node = dataclasses.replace(
        snode,
        data=data_new,
        weight=(jnp.asarray(weight) if (weight_changed or rows_changed)
                else snode.weight),
        children=tuple(new_children),
        child_hd=tuple(hds),
        child_start=tuple(starts),
        child_len=tuple(lens),
        child_w=tuple(ws),
    )
    return new_node, rows_changed, weight_changed


def reshred_incremental(base: Shred, db: Database, query: JoinQuery,
                        delta) -> Shred:
    """Merge ``delta`` (a ``core.delta.DeltaBatch``) into an existing index.

    ``base`` must be ``build_shred(db, query, rep=base.rep)`` for the given
    (pre-delta) snapshot ``db``; the result is bit-identical to
    ``build_shred(db.apply(delta), query, rep=base.rep)`` — same arrays,
    same dtypes, same canonical flatten order — at ``O(|delta| log |delta|
    + affected)`` cost instead of a full ``O(N log N)`` rebuild: only the
    delta is sorted, and only edges with a touched endpoint (or a changed
    subtree weight) re-derive their link columns and prefix vectors.

    Untouched relations' nodes are shared with ``base`` by reference.
    Deltas touching relations outside the query return ``base`` unchanged.
    """
    delta = delta.resolved({n: r.num_rows for n, r in db.relations.items()})
    plan = build_plan(query)
    root, rows_changed, weight_changed = _reshred_node(
        plan, base.root, db, delta, base.rep)
    if root is base.root:
        return base
    if rows_changed or weight_changed:
        prefE = jnp.concatenate(
            [jnp.zeros((1,), I64), jnp.cumsum(root.weight)])
    else:
        prefE = base.root_prefE
    # The fused-GET arena is re-packed from the merged arrays (a flat
    # concat — bulk copy, not sort work), keeping it coherent with the
    # incremental index: bit-identical to a from-scratch build's arena,
    # including the packed-vs-paged verdict (pack_index, DESIGN.md §15).
    packed, paged = pack_index(root, prefE)
    return Shred(root=root, root_prefE=prefE, rep=base.rep,
                 packed=packed, paged=paged)
