"""Delta batches: the unit of change between database snapshots.

The paper's index is built once over a static database; the serving system
needs the database to *move* without paying the full ``O(N log N)`` build
again (DESIGN.md §11). The model here is immutable versioned snapshots:

  * a ``Database`` never mutates — ``Database.apply(delta)`` produces a NEW
    snapshot (version + 1) sharing every untouched relation's arrays;
  * a ``DeltaBatch`` describes one transition: per-relation row inserts
    (appended after the surviving rows) and per-relation delete masks
    (boolean, True = delete);
  * the post-delta physical layout is canonical — surviving rows keep their
    relative order, inserts follow — which is what lets
    ``shred.reshred_incremental`` merge a delta into an existing sorted
    grouping and still be bit-identical to a from-scratch build.

Deltas are host-side objects (numpy): they describe bulk data movement, not
traced computation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

__all__ = ["DeltaBatch", "RelationDelta"]


@dataclasses.dataclass(frozen=True)
class RelationDelta:
    """Changes to one relation: a delete mask over the current rows plus
    rows to insert (column name -> 1-D numpy array, all equal length).

    ``delete_mask`` is None when nothing is deleted; ``inserts`` is an empty
    dict when nothing is inserted. Either side may be empty, not both.
    """

    delete_mask: Optional[np.ndarray] = None
    inserts: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)

    @property
    def num_deletes(self) -> int:
        if self.delete_mask is None:
            return 0
        if self.delete_mask.dtype == np.bool_:
            return int(self.delete_mask.sum())
        return int(self.delete_mask.shape[0])  # index form (pre-resolution)

    @property
    def num_inserts(self) -> int:
        if not self.inserts:
            return 0
        return int(next(iter(self.inserts.values())).shape[0])

    def validate(self, name: str, num_rows: int,
                 schema: Tuple[str, ...]) -> None:
        if self.delete_mask is not None:
            if self.delete_mask.dtype != np.bool_:
                raise ValueError(f"{name}: delete_mask must be boolean, "
                                 f"got {self.delete_mask.dtype}")
            if self.delete_mask.shape != (num_rows,):
                raise ValueError(
                    f"{name}: delete_mask has shape {self.delete_mask.shape}, "
                    f"relation has {num_rows} rows")
        if self.inserts:
            if set(self.inserts) != set(schema):
                raise ValueError(
                    f"{name}: insert columns {sorted(self.inserts)} != "
                    f"schema columns {sorted(schema)}")
            lens = {c: v.shape[0] for c, v in self.inserts.items()}
            if len(set(lens.values())) > 1:
                raise ValueError(f"{name}: ragged insert columns {lens}")
        if self.delete_mask is None and not self.inserts:
            raise ValueError(f"{name}: empty relation delta (no deletes, "
                             f"no inserts)")


@dataclasses.dataclass(frozen=True)
class DeltaBatch:
    """One atomic multi-relation change set: relation name -> RelationDelta.

    Build with ``DeltaBatch.of`` (keyword-per-relation convenience) or the
    raw constructor. Applying the batch via ``Database.apply`` yields a new
    snapshot whose touched relations are "survivors then inserts":

        rows' = rows[~delete_mask] ++ inserts

    Relations not named in the batch are shared by reference with the
    previous snapshot — a delta touching one relation copies nothing else.

    ``lsn`` is the batch's log sequence number once it has been appended to
    a replicated delta log (``launch.fleet.log.DeltaLog``, DESIGN.md §12):
    1-based, assigned by the log at append time, ``None`` for free-standing
    deltas. Along a log, ``snapshot.version == base_version + lsn`` — the
    invariant that lets every replica name "the snapshot this draw must
    read" by a single integer.
    """

    relations: Dict[str, RelationDelta]
    lsn: Optional[int] = None

    def __post_init__(self):
        if not self.relations:
            raise ValueError("DeltaBatch must touch at least one relation")

    def with_lsn(self, lsn: int) -> "DeltaBatch":
        """The same batch stamped with a log sequence number."""
        if self.lsn is not None and self.lsn != lsn:
            raise ValueError(f"delta already has lsn={self.lsn}, "
                             f"refusing to restamp as {lsn}")
        return dataclasses.replace(self, lsn=lsn)

    @staticmethod
    def of(**per_relation) -> "DeltaBatch":
        """Convenience constructor::

            DeltaBatch.of(
                R={"insert": {"x": [1, 2], "p": [0.3, 0.4]}},
                S={"delete": [0, 5]},          # row indices
            )

        ``delete`` accepts row indices or a boolean mask; ``insert`` is a
        column mapping. The delete mask is resolved against the relation's
        current row count at ``Database.apply`` time when given as indices.
        """
        rels = {}
        for name, spec in per_relation.items():
            ins = {c: np.asarray(v) for c, v in spec.get("insert", {}).items()}
            dele = spec.get("delete", None)
            mask = None
            if dele is not None:
                dele = np.asarray(dele)
                if dele.dtype == np.bool_:
                    mask = dele
                else:  # row indices: defer length validation to apply()
                    mask = dele.astype(np.int64)
            rels[name] = RelationDelta(delete_mask=mask, inserts=ins)
        return DeltaBatch(rels)

    def touched(self) -> Tuple[str, ...]:
        """Names of the relations this batch modifies."""
        return tuple(sorted(self.relations))

    def size(self) -> int:
        """|delta| = total rows inserted + deleted."""
        return sum(d.num_deletes + d.num_inserts
                   for d in self.relations.values())

    def resolved(self, num_rows: Mapping[str, int]) -> "DeltaBatch":
        """Normalize index-style delete specs into boolean masks (the form
        ``reshred_incremental`` consumes) against the given row counts.

        Index deletes are validated here: out-of-range (including negative
        — no numpy wraparound) and duplicate indices are errors, so
        ``num_deletes``/``size()`` always agree with what a later apply
        actually removes."""
        rels = {}
        for name, d in self.relations.items():
            mask = d.delete_mask
            if mask is not None and mask.dtype != np.bool_:
                n = num_rows[name]
                if mask.size and (mask.min() < 0 or mask.max() >= n):
                    raise ValueError(
                        f"{name}: delete indices out of range [0, {n}): "
                        f"{mask[(mask < 0) | (mask >= n)][:5].tolist()}")
                if np.unique(mask).size != mask.size:
                    raise ValueError(f"{name}: duplicate delete indices")
                m = np.zeros((n,), np.bool_)
                m[mask] = True
                mask = m
            rels[name] = RelationDelta(delete_mask=mask, inserts=d.inserts)
        return DeltaBatch(rels, lsn=self.lsn)


def apply_relation_delta(columns: Dict[str, jnp.ndarray],
                         d: RelationDelta) -> Dict[str, jnp.ndarray]:
    """Survivors-then-inserts column transform (the canonical layout)."""
    out = {}
    keep = None
    if d.delete_mask is not None:
        keep = jnp.asarray(~d.delete_mask)
    for c, v in columns.items():
        nv = v[keep] if keep is not None else v
        if d.inserts:
            nv = jnp.concatenate([nv, jnp.asarray(d.inserts[c]).astype(nv.dtype)])
        out[c] = nv
    return out
