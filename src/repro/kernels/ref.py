"""Pure-jnp oracles for every Pallas kernel (the ground truth for tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bsearch_probe_ref(pref: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Max j with pref[j] <= q, elementwise over q."""
    flat = jnp.searchsorted(pref, q.reshape(-1), side="right") - 1
    return jnp.maximum(flat, 0).reshape(q.shape).astype(jnp.int32)


def prefix_sum_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Inclusive prefix sum in flat row-major order, same tile shape."""
    return jnp.cumsum(x.reshape(-1)).reshape(x.shape).astype(x.dtype)


def geo_gaps_ref(u: jnp.ndarray, p) -> jnp.ndarray:
    """Fused geometric-gap positions (flat row-major running positions)."""
    p = jnp.clip(jnp.asarray(p, jnp.float32), 1e-12, 1.0 - 1e-7)
    gaps = jnp.floor(jnp.log(jnp.maximum(u, 1e-12)) / jnp.log1p(-p))
    step = jnp.minimum(gaps, 2_000_000_000.0).astype(jnp.int32) + 1
    return (jnp.cumsum(step.reshape(-1)) - 1).reshape(u.shape).astype(jnp.int32)


def flash_decode_ref(q, k, v, bias) -> jnp.ndarray:
    """Dense decode attention with GQA: q (B,H,D), k/v (B,KV_H,S,D), bias (B,S)."""
    B, H, D = q.shape
    _, KV_H, S, _ = k.shape
    group = H // KV_H
    kx = jnp.repeat(k, group, axis=1).astype(jnp.float32)   # (B,H,S,D)
    vx = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    logits = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32), kx) / (D ** 0.5)
    logits = logits + bias[:, None, :]
    w = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return jnp.einsum("bhs,bhsd->bhd", w, vx).astype(q.dtype)


def flash_prefill_ref(q, k, v, causal=True) -> jnp.ndarray:
    """Dense (causal) attention with GQA: q (B,H,S,D), k/v (B,KV,S,D)."""
    B, H, S, D = q.shape
    KV = k.shape[1]
    group = H // KV
    kx = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vx = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kx) / (D ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, vx).astype(q.dtype)
