"""Pallas TPU kernel: causal flash attention for train/prefill (full
sequence), with GQA head mapping.

The training-side compute hot-spot: at S=32k the score matrix is S² and must
never touch HBM. Tiles: one (block_q, D) query tile is resident per grid
step while (block_k, D) K/V tiles stream through VMEM along the innermost
(sequential) grid axis with the online-softmax (m, l, acc) state in VMEM
scratch — the Pallas twin of models/attention.blockwise_attention (the XLA
path the dry-run lowers), validated against it in interpret mode.

Causality is handled by masking inside the kernel; fully-masked KV tiles
(kv_start > q_end) still occupy grid steps — on real TPU the standard
refinement is a lower-triangular grid via PrefetchScalarGridSpec; kept
simple here and noted (the wasted tiles are ≤ 2x for causal attention).

Grid: (B, H, nQ, nKV); KV innermost so scratch carries per (b, h, q-tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_K = 512
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, out_ref, m_ref, l_ref, acc_ref,
            *, scale: float, nk: int, block_q: int, block_k: int,
            causal: bool):
    kv = pl.program_id(3)

    @pl.when(kv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32)   # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)   # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = kv * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)

    m_prev = m_ref[...]                                   # (BQ, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    prob = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(prob, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        prob, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kv == nk - 1)
    def _emit():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        out_ref[0, 0] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_prefill(
    q: jnp.ndarray,   # (B, H, S, D)
    k: jnp.ndarray,   # (B, KV, S, D)
    v: jnp.ndarray,   # (B, KV, S, D)
    *,
    causal: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jnp.ndarray:
    B, H, S, D = q.shape
    KV = k.shape[1]
    assert H % KV == 0 and S % block_q == 0 and S % block_k == 0
    group = H // KV
    nq, nk = S // block_q, S // block_k
    scale = 1.0 / (D ** 0.5)
    grid = (B, H, nq, nk)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, nk=nk, block_q=block_q,
                          block_k=block_k, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, kv: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, kv: (b, h // group, kv, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, kv: (b, h // group, kv, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, kv: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
