"""Threefry-2x32 counter PRNG, pure jnp — usable *inside* Pallas kernels.

The fused one-launch draw (kernels/fused_draw.py, DESIGN.md §14) needs its
randomness generated in-kernel: routing through ``jax.random`` would put
the uniform generation back into separate XLA dispatches, re-creating the
launch ladder the kernel exists to kill. This module is a self-contained
Threefry-2x32 implementation (the same 20-round ARX cipher family JAX's
default PRNG uses) built only from uint32 elementwise ops, so the *same*
function runs inside a kernel body and in the pure-jnp reference path —
which is what makes the fused draw bit-identical to its multi-launch
reference by construction.

The stream is **self-defined**: ``fold``/``uniforms`` do not reproduce
``jax.random.fold_in``/``jax.random.uniform`` bit-for-bit (those interpose
key typing and different counter layouts). Samplers built on this module
therefore draw from their own named stream — the same situation as
``kernels/geo_gaps`` vs the F64 ``sampling.geo_positions`` — and are
validated distributionally plus against their shared-core reference.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["threefry2x32", "fold", "uniforms", "bits_to_uniform"]

U32 = jnp.uint32
# Threefry-2x32 rotation schedule (Salmon et al. 2011, Table 2): 20 rounds
# as 5 groups of 4, alternating these two rotation quads.
_ROT_A = (13, 15, 26, 6)
_ROT_B = (17, 29, 16, 24)
_PARITY = 0x1BD11BDA  # key-schedule parity constant (SkeinKsParity low word)


def _rotl(x, d: int):
    return (x << U32(d)) | (x >> U32(32 - d))


def threefry2x32(key, x0, x1):
    """The 20-round Threefry-2x32 block cipher.

    key: (2,) uint32; x0/x1: broadcast-compatible uint32 counters.
    Returns the two output words. Elementwise uint32 adds/xors/rotates
    only — safe inside Pallas kernel bodies and under vmap.
    """
    k0 = key[0]
    k1 = key[1]
    ks = (k0, k1, k0 ^ k1 ^ U32(_PARITY))
    x0 = x0 + k0
    x1 = x1 + k1
    for group, rot in enumerate((_ROT_A, _ROT_B, _ROT_A, _ROT_B, _ROT_A)):
        for d in rot:
            x0 = x0 + x1
            x1 = _rotl(x1, d) ^ x0
        # Key injection after each 4-round group, with the round-counter
        # increment that breaks the cipher's shift symmetry.
        x0 = x0 + ks[(group + 1) % 3]
        x1 = x1 + ks[(group + 2) % 3] + U32(group + 1)
    return x0, x1


def fold(key, data) -> jnp.ndarray:
    """Derive a (2,) uint32 subkey by encrypting the stream id under the
    parent key — the in-kernel analogue of folding a stream into a key."""
    d = jnp.asarray(data, U32)
    x0, x1 = threefry2x32(key, d, U32(0))
    return jnp.stack([x0, x1])


def bits_to_uniform(bits) -> jnp.ndarray:
    """uint32 -> float32 uniform in [0, 1): keep the top 23 bits as the
    mantissa of a float in [1, 2), subtract 1 (the standard bit trick —
    exactly representable, no rounding)."""
    mant = (bits >> U32(9)) | U32(0x3F800000)
    return jax.lax.bitcast_convert_type(mant, jnp.float32) - jnp.float32(1.0)


def uniforms(key, n: int, stream: int = 0) -> jnp.ndarray:
    """``n`` float32 uniforms in [0, 1) from counter lanes 0..n-1 of the
    given stream. One cipher call over the whole lane vector."""
    sub = fold(key, stream)
    ctr = jnp.arange(n, dtype=U32)
    x0, _ = threefry2x32(sub, ctr, jnp.zeros((n,), U32))
    return bits_to_uniform(x0)
