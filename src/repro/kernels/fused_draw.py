"""ONE Pallas launch from PRNG key to compacted sample rows (DESIGN.md §14).

The multi-launch draw costs ~5+ dispatches per Poisson sample even warm —
key split, arrival generation, prefix searches, per-node GET, compaction —
which is the dispatch floor the B=1/small-batch serving regime pays on
every call. This kernel fuses the whole pipeline: in-kernel Threefry key
folding (kernels/threefry.py), arrival generation, EXPRACE thinning /
PTBERN trials, prefix search over the root prefix, the full pre-order tree
walk against the packed VMEM arena (sharing ``tree_probe.tree_walk`` and
its layout aux), and count/overflow compaction into ``(cap,)`` buffers —
one ``pallas_call``, everything VMEM-resident.

**Bit-identity by construction.** The sampling math lives in pure-jnp
``draw_core``; the kernel body and the multi-launch reference
(``fused_draw_ref`` — plain traced jnp, one XLA dispatch chain) call the
*same* function on the same operands, so in interpret mode they agree bit
for bit (asserted over random acyclic queries by tests/test_fused_draw.py).
The fused stream is **self-defined** (Threefry counters, float32): it does
not reproduce the F64 ``sampling.exprace_positions`` stream — the same
relationship ``kernels/geo_gaps`` has to ``sampling.geo_positions``. The
per-node F64 path remains the precision arbiter; route selection is
static (core/probe.select_draw, engine/plan), with the fallback ladder:
no packed arena / over the VMEM budget / kernels disabled / non-narrowed
shred -> the multi-launch per-node path.

**EXPRACE, sort-free.** The multi-launch EXPRACE draws M ~ Poisson(Lam)
arrival *positions* uniformly and sorts them. In-kernel we instead draw
iid Exp(1) gaps and prefix-sum them: the running sum is a unit-rate
Poisson process on [0, Lam), so arrivals come out *already ascending* and
the scalar Poisson draw, the sort, and every scatter disappear — the
count is just "how many partial sums land below Lam". Cell placement,
dedupe (neighbor compare), per-root success counts, and the l-th-missing-
value complement inversion (p > 1/2) then reduce to branchless binary
searches (``_count_le``) over sorted vectors — gather-only, VMEM-local.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import threefry
from .tree_probe import tree_walk

__all__ = ["PARAM_ORDER", "draw_core", "fused_draw", "fused_draw_ref",
           "fused_sample"]

I32 = jnp.int32
F32 = jnp.float32
_TINY = 1e-12  # python float: weak-typed, so it never captures a constant
# Fixed operand order of the plan-bound parameter vectors (see
# sampling.fused_draw_params): a dict in Python, positional in the kernel.
PARAM_ORDER = ("massE", "lam", "sign", "w32", "prefE32", "cwE", "offE", "p32")


def _count_le(vec, q):
    """#elements of the ascending vector ``vec`` that are <= q, branchless
    power-of-two descent (one VMEM gather per step; any ``q`` shape).

    The counting twin of ``tree_probe._descend``: returns values in
    [0, len(vec)], needs no sentinel padding and no arena-style 0-prefix
    invariant, so it searches arbitrary sorted vectors (float mass
    prefixes, running counts, carry-forward complements)."""
    L = vec.shape[0]
    steps = max(1, math.ceil(math.log2(L + 1)))
    p = jnp.zeros(jnp.shape(q), I32)
    for k in range(steps - 1, -1, -1):
        cand = p + (1 << k)
        val = jnp.take(vec, jnp.minimum(cand, L) - 1)
        ok = jnp.logical_and(cand <= L, val <= q)
        p = jnp.where(ok, cand, p)
    return p


def _exprace_core(key, params, acap: int, cap: int):
    """Sorted-gap EXPRACE (module docstring): key -> (positions, count,
    overflow), all int32/f32, no sort, no scatter. Mirrors the semantics
    of ``sampling.exprace_positions`` step for step — per-root success
    counts, complement inversion, clip rules — on the plan-bound
    ``fused_draw_params`` operands."""
    massE, lam, sign = params["massE"], params["lam"], params["sign"]
    w32, prefE32 = params["w32"], params["prefE32"]
    cwE, offE = params["cwE"], params["offE"]
    R = w32.shape[0]
    n32 = prefE32[R]

    # --- arrivals: cumsum of Exp(1) gaps == unit-rate Poisson process ------
    u = threefry.uniforms(key, acap, stream=0)
    v = jnp.cumsum(-jnp.log1p(-u))
    Lam = massE[R]
    avalid = v < Lam
    more_arrivals = avalid[acap - 1]  # scratch exhausted mid-process

    # --- cell placement (inverse CDF into the mass prefix) -----------------
    r = jnp.clip(_count_le(massE, v) - 1, 0, R - 1)
    cell = jnp.floor((v - jnp.take(massE, r))
                     / jnp.maximum(jnp.take(lam, r), _TINY)).astype(I32)
    cell = jnp.clip(cell, 0, jnp.maximum(jnp.take(w32, r) - 1, 0))
    gid = jnp.where(avalid, jnp.take(prefE32, r) + cell, n32)  # ascending

    # --- dedupe (>=1 arrival == one success/failure) -----------------------
    prev = jnp.concatenate([jnp.full((1,), -1, I32), gid[:-1]])
    uniq = jnp.logical_and(gid < n32, gid != prev)
    # Segment from the *root* prefix (not the mass prefix): zero-width
    # roots share a boundary value and must resolve exactly as the
    # reference's searchsorted-right does.
    seg = jnp.clip(_count_le(prefE32, gid) - 1, 0, R - 1)
    U = jnp.cumsum(uniq.astype(I32))                       # incl. unique rank
    S = jnp.cumsum(jnp.where(uniq, jnp.take(sign, seg), 0))

    # --- per-root output prefix, via boundary counts -----------------------
    # B[j] = #arrival lanes with gid < prefE32[j]; then the j-th output
    # boundary is cwE[j] (complement roots emit w - hits) + the signed hit
    # sum up to that lane. hitsE likewise from the unsigned count.
    B = _count_le(gid, prefE32 - 1)
    SB = jnp.where(B > 0, jnp.take(S, jnp.maximum(B - 1, 0)), 0)
    UB = jnp.where(B > 0, jnp.take(U, jnp.maximum(B - 1, 0)), 0)
    outE = cwE + SB                                        # (R+1,) ascending
    hitsE = UB
    K = outE[R]

    # --- complement support: carry-forward g-values ------------------------
    # g = local - rank_within_segment + offE[seg] is ascending over unique
    # lanes; carrying the last unique value over dup/invalid lanes keeps
    # the whole vector sorted so _count_le can binary-search it, and U at
    # the hit lane recovers the unique-entry count the reference gets from
    # its compacted scatter.
    local = gid - jnp.take(prefE32, seg)
    lrank = (U - 1) - jnp.take(hitsE, seg)
    gval = local - lrank + jnp.take(offE, seg)
    gc = jax.lax.cummax(jnp.where(uniq, gval, jnp.full((), -(1 << 30), I32)))

    # --- emit output slots (gather-only compaction) ------------------------
    t = jnp.arange(cap, dtype=I32)
    rO = jnp.clip(_count_le(outE, t) - 1, 0, R - 1)
    l = t - jnp.take(outE, rO)
    wO = jnp.take(w32, rO)
    # direct roots: the l-th unique arrival of segment rO
    i_star = jnp.minimum(_count_le(U, jnp.take(hitsE, rO) + l), acap - 1)
    direct_local = jnp.take(gid, i_star) - jnp.take(prefE32, rO)
    # complement roots: the l-th missing value among the segment's failures
    q = l + jnp.take(offE, rO)
    Lq = _count_le(gc, q)
    c = jnp.where(Lq > 0, jnp.take(U, jnp.maximum(Lq - 1, 0)), 0) \
        - jnp.take(hitsE, rO)
    comp_pos = l + jnp.clip(c, 0, jnp.maximum(wO - 1, 0) - l + 1)
    local_out = jnp.where(jnp.take(sign, rO) < 0, comp_pos, direct_local)
    pos = jnp.take(prefE32, rO) + jnp.clip(local_out, 0,
                                           jnp.maximum(wO - 1, 0))
    count = jnp.minimum(K, cap)
    tvalid = t < count
    positions = jnp.where(tvalid, pos, n32)
    overflow = jnp.logical_or(more_arrivals, K > cap)
    return positions, count, overflow


def _ptbern_core(key, params, n: int, cap: int):
    """Faithful flat PTBERN in one pass: one Bernoulli trial per flat
    position (Theta(n) lanes — the route gate keeps n within the VMEM
    budget), success compaction via a running-count binary search."""
    prefE32, p32 = params["prefE32"], params["p32"]
    R = p32.shape[0]
    n32 = prefE32[R]
    u = threefry.uniforms(key, n, stream=1)
    flat = jnp.arange(n, dtype=I32)
    r = jnp.clip(_count_le(prefE32, flat) - 1, 0, R - 1)
    mask = u < jnp.take(p32, r)
    C = jnp.cumsum(mask.astype(I32))
    total = C[n - 1]
    t = jnp.arange(cap, dtype=I32)
    pos = jnp.minimum(_count_le(C, t), n - 1)  # first lane with C == t+1
    count = jnp.minimum(total, cap)
    positions = jnp.where(t < count, pos, n32)
    return positions, count, total > cap


def draw_core(key, params, *, method: str, cap: int, acap: int, n: int):
    """The shared draw pipeline: sample positions, then walk them. Returns
    ``(positions, count, overflow)`` with the PositionSample conventions
    (positions ascending over valid lanes, sentinel n beyond ``count``).
    Called from the kernel body AND from ``fused_draw_ref`` — sharing this
    function is the bit-identity argument."""
    if method == "exprace":
        return _exprace_core(key, params, acap, cap)
    if method == "ptbern_flat":
        return _ptbern_core(key, params, n, cap)
    raise ValueError(f"unknown fused draw method {method!r}")


def _kernel(arena_ref, key_ref, *rest, layout, method, cap, acap, n):
    param_refs, (rows_ref, pos_ref, cnt_ref, ovf_ref) = rest[:-4], rest[-4:]
    params = {name: ref[...] for name, ref in zip(PARAM_ORDER, param_refs)}
    positions, count, overflow = draw_core(
        key_ref[...], params, method=method, cap=cap, acap=acap, n=n)
    # Clamp sentinels for the walk (GET's out-of-range lanes are
    # arbitrary-but-masked, same contract as the per-node path).
    wpos = jnp.minimum(positions, params["prefE32"][-1] - 1)
    rows = tree_walk(arena_ref[...], wpos, layout)
    for s, r in enumerate(rows):
        rows_ref[s, :] = r
    pos_ref[...] = positions
    cnt_ref[0] = count
    ovf_ref[0] = overflow.astype(I32)


@functools.partial(
    jax.jit, static_argnames=("layout", "method", "cap", "acap", "n",
                              "interpret"))
def fused_draw(arena, key_data, params, *, layout, method: str, cap: int,
               acap: int = 0, n: int = 0, interpret: bool = True):
    """The one-launch draw. arena: (layout.size,) int32 packed index;
    key_data: (2,) uint32 (``jax.random.key_data``); params: the
    ``sampling.fused_draw_params`` dict. Returns
    ``(rows (num_slots, cap) i32, positions (cap,) i32, count () i32,
    overflow () bool)`` — rows in ``layout.names`` slot order.

    grid=(1,): every operand is pinned VMEM-resident for the whole draw
    (callers own the VMEM-budget gate — core/probe.py, DESIGN.md §9/§14).
    Vmapping over ``key_data`` batches the launch for the small-bucket
    multi-draw route."""
    operands = [arena, key_data] + [params[k] for k in PARAM_ORDER]
    spec1 = [pl.BlockSpec(x.shape, lambda i, nd=x.ndim: (0,) * nd)
             for x in operands]
    rows, pos, cnt, ovf = pl.pallas_call(
        functools.partial(_kernel, layout=layout, method=method, cap=cap,
                          acap=acap, n=n),
        grid=(1,),
        in_specs=spec1,
        out_specs=[
            pl.BlockSpec((layout.num_slots, cap), lambda i: (0, 0)),
            pl.BlockSpec((cap,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((layout.num_slots, cap), I32),
            jax.ShapeDtypeStruct((cap,), I32),
            jax.ShapeDtypeStruct((1,), I32),
            jax.ShapeDtypeStruct((1,), I32),
        ],
        interpret=interpret,
    )(*operands)
    return rows, pos, cnt[0], ovf[0].astype(jnp.bool_)


def _sample_kernel(key_ref, *rest, method, cap, acap, n):
    param_refs, (pos_ref, cnt_ref, ovf_ref) = rest[:-3], rest[-3:]
    params = {name: ref[...] for name, ref in zip(PARAM_ORDER, param_refs)}
    positions, count, overflow = draw_core(
        key_ref[...], params, method=method, cap=cap, acap=acap, n=n)
    pos_ref[...] = positions
    cnt_ref[0] = count
    ovf_ref[0] = overflow.astype(I32)


@functools.partial(
    jax.jit, static_argnames=("method", "cap", "acap", "n", "interpret"))
def fused_sample(key_data, params, *, method: str, cap: int, acap: int = 0,
                 n: int = 0, interpret: bool = True):
    """The sampling HALF of the fused draw as its own one-launch kernel:
    ``draw_core`` without the tree walk — key -> ``(positions (cap,) i32,
    count () i32, overflow () bool)``, PositionSample conventions.

    This is the paged draw route's front end (DESIGN.md §15): when the
    index arena exceeds the VMEM budget the walk must stream pages
    (``tree_probe_paged``) and cannot share the sampler's launch, but the
    sampler itself only touches the root-level parameter vectors — which
    fit VMEM whenever the root page does. Same operands, same Threefry
    streams, so positions are bit-identical to ``fused_draw`` /
    ``fused_draw_ref`` under the same key."""
    operands = [key_data] + [params[k] for k in PARAM_ORDER]
    spec1 = [pl.BlockSpec(x.shape, lambda i, nd=x.ndim: (0,) * nd)
             for x in operands]
    pos, cnt, ovf = pl.pallas_call(
        functools.partial(_sample_kernel, method=method, cap=cap,
                          acap=acap, n=n),
        grid=(1,),
        in_specs=spec1,
        out_specs=[
            pl.BlockSpec((cap,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((cap,), I32),
            jax.ShapeDtypeStruct((1,), I32),
            jax.ShapeDtypeStruct((1,), I32),
        ],
        interpret=interpret,
    )(*operands)
    return pos, cnt[0], ovf[0].astype(jnp.bool_)


@functools.partial(
    jax.jit, static_argnames=("layout", "method", "cap", "acap", "n"))
def fused_draw_ref(arena, key_data, params, *, layout, method: str,
                   cap: int, acap: int = 0, n: int = 0):
    """The multi-launch reference: the *same* ``draw_core`` + ``tree_walk``
    as plain traced jnp (XLA ops, no pallas_call) — the bit-identity
    oracle for the kernel and the ``kernels='reference'`` engine route."""
    positions, count, overflow = draw_core(
        key_data, params, method=method, cap=cap, acap=acap, n=n)
    wpos = jnp.minimum(positions, params["prefE32"][-1] - 1)
    rows = jnp.stack(tree_walk(arena, wpos, layout))
    return rows, positions, count, overflow
