"""Pallas TPU kernel: fused GEO position generation (paper Fig. 6,
vectorized — DESIGN.md §3).

One pass fuses the three stages of the vectorized GEO sampler:
    gap  = floor(ln u / ln(1-p))          (inverse-CDF geometric draw)
    pos  = running_sum(gap + 1) - 1       (carry-chained, like prefix_sum)
so the uniforms tile is read once from VMEM and positions stream out —
instead of three XLA passes (log, floor-div, cumsum) over HBM.

p arrives as a (1, 1) operand pinned to SMEM-like replication (every grid
step sees the same scalar block).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_ROWS = 64


def _kernel(p_ref, u_ref, out_ref, carry_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[0] = jnp.zeros((), jnp.int32)

    p = p_ref[0, 0]
    u = u_ref[...]
    # divide (not multiply by reciprocal): floor() amplifies the last-ulp
    # difference into off-by-one positions vs the oracle at small p.
    denom = jnp.log1p(-jnp.clip(p, 1e-12, 1.0 - 1e-7))
    gaps = jnp.floor(jnp.log(jnp.maximum(u, 1e-12)) / denom)
    step = jnp.minimum(gaps, 2_000_000_000.0).astype(jnp.int32) + 1
    # dtype pinned: under jax x64 (enabled by repro.core) jnp.sum would
    # promote int32 -> int64, which the int32 out_ref store rejects.
    row_sum = jnp.sum(step, axis=1, dtype=jnp.int32)
    row_off = jnp.cumsum(row_sum) - row_sum
    flat = jnp.cumsum(step, axis=1) + row_off[:, None] + carry_ref[0]
    out_ref[...] = flat - 1
    carry_ref[0] = carry_ref[0] + jnp.sum(row_sum, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def geo_gaps_tiles(
    u: jnp.ndarray,
    p: jnp.ndarray,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> jnp.ndarray:
    """u: (R, 128) float32 uniforms in (0,1); p: () probability.
    Returns (R, 128) int32 candidate positions (ascending, flat order)."""
    assert u.ndim == 2 and u.shape[1] == 128, u.shape
    rows = u.shape[0]
    grid = (pl.cdiv(rows, block_rows),)
    p2 = jnp.asarray(p, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((block_rows, 128), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(u.shape, jnp.int32),
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(p2, u)
