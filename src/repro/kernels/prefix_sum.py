"""Pallas TPU kernel: carry-chained prefix sum (weights -> pref vector).

Index construction's only non-sort hot loop is the prefix sum over tuple
weights (paper §4: "The prefix vector can clearly be computed in linear
time"). TPU grids execute sequentially per core, so a single scalar carry in
SMEM threads the running total through the (row-tiled) grid — one pass, no
log-depth scan tree, exactly one VMEM read + write per element.

Layout: 1-D data is retiled to (rows, 128) by ops.py; each grid step owns a
(block_rows, 128) tile and computes its flat (row-major) running sum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_ROWS = 64


def _kernel(x_ref, out_ref, carry_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[0] = jnp.zeros((), x_ref.dtype)

    x = x_ref[...]
    # dtype pinned: under jax x64 (enabled by repro.core) jnp.sum would
    # promote int32 -> int64, which the int32 out_ref store rejects.
    row_sum = jnp.sum(x, axis=1, dtype=x.dtype)
    row_off = jnp.cumsum(row_sum) - row_sum  # exclusive row offsets
    flat = jnp.cumsum(x, axis=1) + row_off[:, None] + carry_ref[0]
    out_ref[...] = flat
    carry_ref[0] = carry_ref[0] + jnp.sum(row_sum, dtype=x.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def prefix_sum_tiles(
    x: jnp.ndarray, *, block_rows: int = DEFAULT_BLOCK_ROWS, interpret: bool = True
) -> jnp.ndarray:
    """Inclusive prefix sum in flat row-major order over (R, 128) tiles."""
    assert x.ndim == 2 and x.shape[1] == 128, x.shape
    rows = x.shape[0]
    grid = (pl.cdiv(rows, block_rows),)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.SMEM((1,), x.dtype)],
        interpret=interpret,
    )(x)
