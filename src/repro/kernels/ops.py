"""Public jitted wrappers around the Pallas kernels.

Each wrapper owns layout plumbing (1-D <-> (rows, 128) retiling, padding)
and the documented fallbacks:
  * int64 offsets (joins > 2^31) fall back to XLA searchsorted/cumsum —
    TPU has no native 64-bit gathers (DESIGN.md §9);
  * prefix tables too large for VMEM fall back likewise.

Kernel selection is resolved *at call time* from the active
``repro.config.KernelPolicy`` (DESIGN.md §14): every wrapper takes an
``interpret=`` override (tests flip it per-case) and a ``policy=``
override, defaulting to ``config.current_policy()`` — which is the
``override(...)`` context if one is installed, else the policy parsed from
the ``REPRO_PALLAS_*`` environment variables (interpret mode in this CPU
container; compiled mode on real TPUs). A disabled policy
(``KernelPolicy(enabled=False)``, historically ``REPRO_PALLAS_DISABLE=1``)
routes every wrapper through its pure-XLA/jnp fallback (the
searchsorted/cumsum fallbacks for the index kernels, the ``ref`` oracles
for GEO and attention) — the operator escape hatch for a kernel bug,
exercised per-case by the tests (``TestOpsDispatch``).
"""
from __future__ import annotations

import math  # noqa: F401  (re-exported convenience; hoisted per style rule)
from typing import Optional

import jax
import jax.numpy as jnp

from repro import config

from . import ref as _ref
from .autotune import tile_for
from .bsearch_probe import bsearch_probe as _bsearch_tiles
from .geo_gaps import geo_gaps_tiles as _geo_tiles
from .prefix_sum import prefix_sum_tiles as _prefix_tiles
from .flash_decode import flash_decode as _flash_decode
from .flash_prefill import flash_prefill as _flash_prefill

# int32 table entries kept fully VMEM-resident (bsearch prefix tables and
# the fused-GET arena share this budget — core/probe.py reads the active
# policy's ``vmem_limit``; this constant is the policy default).
VMEM_PREF_LIMIT = config.DEFAULT_VMEM_LIMIT
_VMEM_PREF_LIMIT = VMEM_PREF_LIMIT  # back-compat alias


def interpret_default(policy: Optional[config.KernelPolicy] = None) -> bool:
    """Interpret-mode default, resolved from the active ``KernelPolicy``
    at call time (so tests and CI legs can flip the env var or install an
    ``override(...)`` without re-importing the module)."""
    return config.current_policy(policy).interpret


def pallas_enabled(policy: Optional[config.KernelPolicy] = None) -> bool:
    """False when the active policy disables kernels (historically
    ``REPRO_PALLAS_DISABLE=1``): every wrapper (and the fused dispatches
    in core/probe.py) uses its pure-XLA fallback instead."""
    return config.current_policy(policy).enabled


def pallas_preferred(policy: Optional[config.KernelPolicy] = None) -> bool:
    """Should jitted hot paths *prefer* Pallas kernels over their XLA
    twins when both are available? True in compiled mode (real TPU — the
    kernels are the point); in interpret mode (this CPU container) the
    interpreter's per-access overhead loses to XLA inside an already-jitted
    executor, so hot paths default to XLA unless the policy's ``prefer``
    pins the kernel path (the CI matrix leg sets ``REPRO_PALLAS_PREFER=1``,
    so the interpret-mode kernels are exercised by the whole tier-1 suite,
    not only by the explicit-rep tests). Capability gates
    (``pallas_enabled``, dtype/VMEM fallbacks) still apply on top; explicit
    ``rep='usr_fused'`` / ``kernels='fused'`` requests bypass this
    preference. Resolved at trace time (``KernelPolicy.preferred``)."""
    return config.current_policy(policy).preferred


def _interpret(override: Optional[bool],
               policy: Optional[config.KernelPolicy] = None) -> bool:
    return interpret_default(policy) if override is None else override


def to_tiles(x: jnp.ndarray, fill=0) -> jnp.ndarray:
    """Pad a 1-D vector to a whole number of 128-lanes rows and retile."""
    n = x.shape[0]
    rows = -(-n // 128)
    pad = rows * 128 - n
    return jnp.pad(x, (0, pad), constant_values=fill).reshape(rows, 128)


def searchsorted_prefix(pref: jnp.ndarray, q: jnp.ndarray,
                        *, interpret: Optional[bool] = None,
                        policy: Optional[config.KernelPolicy] = None,
                        ) -> jnp.ndarray:
    """Bulk 'locate offset in prefix vector': max j with pref[j] <= q
    (== ``searchsorted(pref, q, 'right') - 1`` clamped at 0).

    Pallas fast path for int32 tables/queries that fit VMEM; identical XLA
    fallback for every other dtype (int64 joins > 2^31, float mass
    vectors) or oversized table — "where dtypes permit" (DESIGN.md §9).
    """
    n = q.shape[0]
    pol = config.current_policy(policy)
    if (pref.dtype != jnp.int32 or q.dtype != jnp.int32
            or pref.shape[0] > pol.vmem_limit or not pallas_enabled(pol)):
        return jnp.maximum(jnp.searchsorted(pref, q, side="right") - 1, 0)
    tiles = to_tiles(q)
    out = _bsearch_tiles(pref, tiles,
                         block_rows=tile_for("bsearch_probe", n, pol),
                         interpret=_interpret(interpret, pol))
    return out.reshape(-1)[:n]


def prefix_sum(x: jnp.ndarray, exclusive: bool = False,
               *, interpret: Optional[bool] = None,
               policy: Optional[config.KernelPolicy] = None) -> jnp.ndarray:
    """Prefix sum of a 1-D vector (the index's pref column)."""
    n = x.shape[0]
    pol = config.current_policy(policy)
    if x.dtype == jnp.int64 or not pallas_enabled(pol):
        s = jnp.cumsum(x)
    else:
        s = _prefix_tiles(to_tiles(x),
                          interpret=_interpret(interpret, pol)).reshape(-1)[:n]
    if exclusive:
        s = jnp.concatenate([jnp.zeros((1,), s.dtype), s[:-1]])
    return s


def geo_positions_fused(u: jnp.ndarray, p,
                        *, interpret: Optional[bool] = None,
                        policy: Optional[config.KernelPolicy] = None,
                        ) -> jnp.ndarray:
    """Fused uniform->geometric->positions transform (ascending int32)."""
    n = u.shape[0]
    pol = config.current_policy(policy)
    if not pallas_enabled(pol):
        return _ref.geo_gaps_ref(u.astype(jnp.float32), p)
    tiles = to_tiles(u.astype(jnp.float32), 1.0 - 1e-7)
    return _geo_tiles(tiles, p,
                      interpret=_interpret(interpret, pol)).reshape(-1)[:n]


def decode_attention(q, k, v, bias=None, *, block_s: Optional[int] = None,
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """Online-softmax decode attention; pads S up to a block multiple.
    ``block_s=None`` resolves the KV tile through the tuning table
    (``autotune.tile_for``, keyed by sequence length); an explicit value
    pins it."""
    B, H, D = q.shape
    _, KV_H, S, _ = k.shape
    if bias is None:
        bias = jnp.zeros((B, S), jnp.float32)
    if not pallas_enabled():
        return _ref.flash_decode_ref(q, k, v, bias)
    if block_s is None:
        block_s = tile_for("flash_decode", S)
    pad = (-S) % block_s
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        bias = jnp.pad(bias, ((0, 0), (0, pad)), constant_values=-1e30)
    return _flash_decode(q, k, v, bias, block_s=block_s,
                         interpret=_interpret(interpret))


def prefill_attention(q, k, v, *, causal: bool = True,
                      block_q: Optional[int] = None,
                      block_k: Optional[int] = None,
                      interpret: Optional[bool] = None) -> jnp.ndarray:
    """Causal flash attention over full sequences (train/prefill); pads S up
    to the block lcm. ``block_q``/``block_k`` default to the tuning-table
    pair (``autotune.tile_for('flash_prefill', S)``); explicit values pin
    either axis independently."""
    B, H, S, D = q.shape
    if not pallas_enabled():
        return _ref.flash_prefill_ref(q, k, v, causal=causal)
    tq, tk = tile_for("flash_prefill", S)
    block_q = tq if block_q is None else block_q
    block_k = tk if block_k is None else block_k
    step = math.lcm(block_q, block_k)
    pad = (-S) % step
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    out = _flash_prefill(q, k, v, causal=causal, block_q=block_q,
                         block_k=block_k, interpret=_interpret(interpret))
    return out[:, :, :S]


# Re-export oracles so tests can write ops.X vs ops.ref.X_ref.
ref = _ref
