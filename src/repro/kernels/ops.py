"""Public jitted wrappers around the Pallas kernels.

Each wrapper owns layout plumbing (1-D <-> (rows, 128) retiling, padding)
and the documented fallbacks:
  * int64 offsets (joins > 2^31) fall back to XLA searchsorted/cumsum —
    TPU has no native 64-bit gathers (DESIGN.md §9);
  * prefix tables too large for VMEM fall back likewise.
``interpret=True`` everywhere in this container (CPU); on real TPUs the flag
flips to False via the REPRO_PALLAS_INTERPRET env var.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from . import ref as _ref
from .bsearch_probe import bsearch_probe as _bsearch_tiles
from .geo_gaps import geo_gaps_tiles as _geo_tiles
from .prefix_sum import prefix_sum_tiles as _prefix_tiles
from .flash_decode import flash_decode as _flash_decode
from .flash_prefill import flash_prefill as _flash_prefill

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"
_VMEM_PREF_LIMIT = 1 << 21  # int32 prefix entries kept fully VMEM-resident


def _to_tiles(x: jnp.ndarray, fill) -> jnp.ndarray:
    n = x.shape[0]
    rows = -(-n // 128)
    pad = rows * 128 - n
    return jnp.pad(x, (0, pad), constant_values=fill).reshape(rows, 128)


def searchsorted_prefix(pref: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Bulk 'locate offset in prefix vector': max j with pref[j] <= q.

    Pallas fast path for int32-representable tables; XLA fallback otherwise.
    """
    n = q.shape[0]
    if (pref.dtype == jnp.int64 or q.dtype == jnp.int64
            or pref.shape[0] > _VMEM_PREF_LIMIT):
        return jnp.maximum(jnp.searchsorted(pref, q, side="right") - 1, 0)
    tiles = _to_tiles(q.astype(jnp.int32), 0)
    out = _bsearch_tiles(pref.astype(jnp.int32), tiles, interpret=INTERPRET)
    return out.reshape(-1)[:n]


def prefix_sum(x: jnp.ndarray, exclusive: bool = False) -> jnp.ndarray:
    """Prefix sum of a 1-D vector (the index's pref column)."""
    n = x.shape[0]
    if x.dtype == jnp.int64:
        s = jnp.cumsum(x)
    else:
        s = _prefix_tiles(_to_tiles(x, 0), interpret=INTERPRET).reshape(-1)[:n]
    if exclusive:
        s = jnp.concatenate([jnp.zeros((1,), s.dtype), s[:-1]])
    return s


def geo_positions_fused(u: jnp.ndarray, p) -> jnp.ndarray:
    """Fused uniform->geometric->positions transform (ascending int32)."""
    n = u.shape[0]
    tiles = _to_tiles(u.astype(jnp.float32), 1.0 - 1e-7)
    return _geo_tiles(tiles, p, interpret=INTERPRET).reshape(-1)[:n]


def decode_attention(q, k, v, bias=None, *, block_s: int = 512) -> jnp.ndarray:
    """Online-softmax decode attention; pads S up to a block multiple."""
    B, H, D = q.shape
    _, KV_H, S, _ = k.shape
    if bias is None:
        bias = jnp.zeros((B, S), jnp.float32)
    pad = (-S) % block_s
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        bias = jnp.pad(bias, ((0, 0), (0, pad)), constant_values=-1e30)
    return _flash_decode(q, k, v, bias, block_s=block_s, interpret=INTERPRET)


def prefill_attention(q, k, v, *, causal: bool = True,
                      block_q: int = 256, block_k: int = 512) -> jnp.ndarray:
    """Causal flash attention over full sequences (train/prefill); pads S up
    to the block lcm."""
    B, H, S, D = q.shape
    import math
    step = math.lcm(block_q, block_k)
    pad = (-S) % step
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    out = _flash_prefill(q, k, v, causal=causal, block_q=block_q,
                         block_k=block_k, interpret=INTERPRET)
    return out[:, :, :S]


# Re-export oracles so tests can write ops.X vs ops.ref.X_ref.
ref = _ref
