"""Backend-aware kernel tile autotuning (DESIGN.md §15).

Every Pallas kernel in this package used to hardcode one tile shape
(``DEFAULT_BLOCK_ROWS = 8`` in ``tree_probe``/``bsearch_probe``, 512-wide
KV tiles in ``flash_decode``, ...) — tuned for exactly one regime on one
substrate. This module replaces the module constants with a three-rung
resolution ladder, applied at trace time by ``kernels/ops.py`` and
``core/probe.py``:

    1. ``KernelPolicy.tile_overrides``  — per-call/operator pin, wins;
    2. ``TUNE_TABLE.json``              — the committed table, keyed by
       ``config.backend_key()`` (``'<backend>/<device-kind>'``) with a
       mandatory ``'default'`` entry, then by problem-size bucket
       (``bucket_of``: power-of-two buckets, ``'*'`` = any size);
    3. the kernel's builtin default     — the historical constant.

The table is *data, not measurement*: CI and every fresh checkout resolve
tiles deterministically from the committed JSON (the ``default`` entry
mirrors the builtin defaults, so an unknown backend behaves exactly like
the pre-autotuner code). Winners are (re)measured explicitly::

    PYTHONPATH=src python -m repro.kernels.autotune --sweep --write

which times a small static candidate grid per (kernel, size bucket) on
the live backend — via ``benchmarks/timing.time_fn`` when the repo
harness is importable, a minimal local twin otherwise — and persists the
winners under this process's ``backend_key()``. Tile shapes never change
results (every kernel is bit-identical across its candidate grid — the
grid only re-tiles the probe/query axis), so a stale table is a
performance bug, not a correctness bug.

``--check`` is the CI schema gate (the ``tune-smoke`` step): the
committed table must parse, carry the current schema version, name only
live kernels (a renamed kernel fails the gate instead of silently
orphaning its rows), and provide a ``default`` row for every registered
kernel.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import sys
import time
from pathlib import Path
from typing import Callable, Dict, Optional, Sequence

from repro import config

__all__ = [
    "KERNELS", "TABLE_PATH", "TABLE_VERSION", "TunableKernel", "bucket_of",
    "load_table", "tile_for", "sweep", "check_table", "main",
]

TABLE_PATH = Path(__file__).resolve().parent / "TUNE_TABLE.json"
TABLE_VERSION = 1


@dataclasses.dataclass(frozen=True)
class TunableKernel:
    """One autotunable kernel: the tile parameter it exposes, the static
    candidate grid the sweep measures, and the builtin default (the
    pre-autotuner module constant, kept as the last resolution rung)."""

    param: str
    candidates: tuple
    default: object
    sizes: tuple  # representative problem sizes swept per bucket


# The registry: names are the public tuning identity (table keys, policy
# override keys). Candidates are deliberately small static grids — the
# point is killing the hardcoded constant, not an exhaustive search.
KERNELS: Dict[str, TunableKernel] = {
    # probe-tile rows of the fused GET walk (kernels/tree_probe.tree_probe)
    "tree_probe": TunableKernel(
        "block_rows", (4, 8, 16, 32), 8, (512, 1 << 14)),
    # probe-tile rows of the paged walk (tree_probe_paged, DESIGN.md §15)
    "tree_probe_paged": TunableKernel(
        "block_rows", (4, 8, 16, 32), 8, (512, 1 << 14)),
    # query-tile rows of the bulk prefix bsearch (bsearch_probe)
    "bsearch_probe": TunableKernel(
        "block_rows", (4, 8, 16, 32), 8, (512, 1 << 14)),
    # KV tile length of online-softmax decode attention (flash_decode)
    "flash_decode": TunableKernel(
        "block_s", (256, 512, 1024), 512, (2048,)),
    # (block_q, block_k) of causal flash attention (flash_prefill)
    "flash_prefill": TunableKernel(
        "(block_q, block_k)", ((128, 256), (256, 256), (256, 512)),
        (256, 512), (1024,)),
}


def bucket_of(size: int) -> str:
    """The power-of-two problem-size bucket ``size`` lands in: ``'p<k>'``
    with the smallest k such that ``size <= 2**k`` (``p0`` for sizes <= 1).
    Shapes within one bucket share a tuned tile — the same granularity the
    engine's batch bucketing uses (DESIGN.md §10), so warm paths never
    retrace on a tile flip within a bucket."""
    return f"p{max(int(size) - 1, 0).bit_length()}"


def _normalize(value, spec: TunableKernel):
    """JSON round-trips tuples as lists; fold them back so values compare
    and hash like the candidate grid entries. Raises ``TypeError``/
    ``ValueError`` on anything not shaped like the kernel's parameter
    (``--check`` turns that into a schema failure)."""
    if isinstance(spec.default, tuple):
        if isinstance(value, (str, bytes)) or len(value) != len(spec.default):
            raise ValueError(f"want a {len(spec.default)}-tuple, got {value!r}")
        return tuple(int(v) for v in value)
    if isinstance(value, (str, bytes)):
        raise ValueError(f"want an int, got {value!r}")
    return int(value)


@functools.lru_cache(maxsize=None)
def _load_raw(path_str: str, mtime: float) -> dict:
    return json.loads(Path(path_str).read_text())


def load_table(path: Path = None) -> dict:
    """The parsed tuning table ({} when absent). Cached per (path, mtime)
    so trace-time ``tile_for`` calls never re-read the file, while a
    ``--write`` from the same process is picked up."""
    path = path or TABLE_PATH
    try:
        return _load_raw(str(path), path.stat().st_mtime)
    except (OSError, json.JSONDecodeError):
        return {}


def tile_for(kernel: str, size: int,
             policy: Optional[config.KernelPolicy] = None):
    """Resolve ``kernel``'s tile for a problem of ``size`` through the
    ladder: policy ``tile_overrides`` > committed table (backend entry,
    then ``default``; size bucket, then ``'*'``) > builtin default.

    Called at trace time from the ops wrappers — ``size`` is a static
    shape, so the resolved tile is a static kernel parameter and distinct
    buckets are distinct cached traces (same economics as ``cap``)."""
    spec = KERNELS[kernel]
    pol = config.current_policy(policy)
    override = pol.tile_override(kernel)
    if override is not None:
        return _normalize(override, spec)
    if not pol.tuned:
        return spec.default
    entries = load_table().get("entries", {})
    for key in (config.backend_key(), "default"):
        rows = entries.get(key, {}).get(kernel)
        if not rows:
            continue
        value = rows.get(bucket_of(size), rows.get("*"))
        if value is not None:
            return _normalize(value, spec)
    return spec.default


# ---------------------------------------------------------------------------
# Sweep: measure the candidate grid on the live backend.
# ---------------------------------------------------------------------------

def _default_timer(fn: Callable[[], object]) -> float:
    """Median wall-microseconds of ``fn()`` — ``benchmarks.timing.time_fn``
    when the repo harness is on the path (the documented invocation runs
    from the repo root), else a minimal local twin with the same
    warmup/median discipline."""
    try:
        from benchmarks.timing import time_fn
        return time_fn(fn)
    except ImportError:
        import jax
        for _ in range(2):
            jax.block_until_ready(fn())
        times = []
        for _ in range(5):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            times.append(time.perf_counter() - t0)
        times.sort()
        return times[len(times) // 2] * 1e6


def _candidate_thunks(kernel: str, size: int, interpret: bool):
    """Build ``candidate -> zero-arg timed thunk`` for one (kernel, size).
    Imports jax/core lazily — the module itself must stay importable for
    the stdlib-only ``--check`` path."""
    import jax
    import jax.numpy as jnp

    if kernel in ("tree_probe", "tree_probe_paged", "bsearch_probe"):
        if kernel == "bsearch_probe":
            from .bsearch_probe import bsearch_probe
            n = 1 << 15
            pref = jnp.concatenate([
                jnp.zeros((1,), jnp.int32),
                jnp.cumsum(jnp.ones((n - 1,), jnp.int32))])
            rows = -(-size // 128)
            q = jax.random.randint(jax.random.key(0), (rows, 128), 0, n,
                                   dtype=jnp.int32)

            def make(cand):
                def thunk():
                    return jax.block_until_ready(
                        bsearch_probe(pref, q, block_rows=cand,
                                      interpret=interpret))
                return thunk
            return make
        from repro.core import Atom, Database, JoinQuery, build_shred
        from repro.core.shred import PagedArena
        from .tree_probe import tree_probe, tree_probe_paged
        import numpy as np
        rng = np.random.default_rng(0)
        m = 512
        db = Database.from_columns({
            "R": {"x": rng.integers(0, m // 4, m),
                  "y": rng.integers(0, m // 4, m)},
            "S": {"y": rng.integers(0, m // 4, m),
                  "z": rng.integers(0, m // 4, m)},
            "T": {"z": rng.integers(0, m // 4, m),
                  "u": rng.integers(0, m // 4, m)},
        })
        q = JoinQuery((Atom.of("R", "x", "y"), Atom.of("S", "y", "z"),
                       Atom.of("T", "z", "u")))
        shred = build_shred(db, q, rep="usr")
        packed = shred.packed
        if packed is None:
            raise RuntimeError("sweep workload failed to pack an arena")
        n = int(shred.join_size)
        rows = -(-size // 128)
        qs = jax.random.randint(jax.random.key(1), (rows, 128), 0, max(n, 1),
                                dtype=jnp.int32)
        if kernel == "tree_probe":
            def make(cand):
                def thunk():
                    return jax.block_until_ready(tree_probe(
                        packed.arena, qs, layout=packed.layout,
                        block_rows=cand, interpret=interpret))
                return thunk
            return make
        paged = PagedArena.from_packed(packed)

        def make(cand):
            def thunk():
                return jax.block_until_ready(tree_probe_paged(
                    paged.pages, qs, layout=paged.layout, block_rows=cand,
                    interpret=interpret))
            return thunk
        return make

    if kernel == "flash_decode":
        from .flash_decode import flash_decode
        B, H, D, S = 2, 4, 64, size
        key = jax.random.key(2)
        qv = jax.random.normal(key, (B, H, D), jnp.float32)
        kv = jax.random.normal(key, (B, H, S, D), jnp.float32)
        bias = jnp.zeros((B, S), jnp.float32)

        def make(cand):
            def thunk():
                return jax.block_until_ready(flash_decode(
                    qv, kv, kv, bias, block_s=cand, interpret=interpret))
            return thunk
        return make

    if kernel == "flash_prefill":
        from .flash_prefill import flash_prefill
        B, H, D, S = 1, 2, 64, size
        key = jax.random.key(3)
        qv = jax.random.normal(key, (B, H, S, D), jnp.float32)

        def make(cand):
            def thunk():
                return jax.block_until_ready(flash_prefill(
                    qv, qv, qv, block_q=cand[0], block_k=cand[1],
                    interpret=interpret))
            return thunk
        return make

    raise ValueError(f"no sweep workload for kernel {kernel!r}")


def sweep(kernels: Optional[Sequence[str]] = None, *,
          timer: Optional[Callable[[Callable], float]] = None,
          candidates: Optional[dict] = None,
          sizes: Optional[dict] = None,
          entry_key: Optional[str] = None,
          write: bool = False,
          path: Optional[Path] = None,
          out: Callable[[str], None] = print) -> dict:
    """Measure the candidate grid per (kernel, size bucket) and return the
    winner map ``{kernel: {bucket: value}}``; with ``write=True`` persist
    it under ``entry_key`` (default: this process's ``backend_key()``) in
    ``TUNE_TABLE.json``, creating the table (with its mandatory builtin
    ``default`` entry) if absent.

    ``timer`` is injectable (tests pass a deterministic fake — the unit
    leg never depends on wall clocks); ``candidates``/``sizes`` override
    the registry grids per kernel name."""
    timer = timer or _default_timer
    names = list(kernels) if kernels else list(KERNELS)
    pol = config.current_policy()
    winners: dict = {}
    for name in names:
        spec = KERNELS[name]  # KeyError = caller bug, surfaced as-is
        cands = tuple((candidates or {}).get(name, spec.candidates))
        ksizes = tuple((sizes or {}).get(name, spec.sizes))
        winners[name] = {}
        for size in ksizes:
            best, best_us = None, None
            for cand in cands:
                make = _candidate_thunks(name, size, pol.interpret)
                us = timer(make(cand))
                out(f"autotune: {name}[{bucket_of(size)}] "
                    f"{spec.param}={cand}: {us:.1f}us")
                if best_us is None or us < best_us:
                    best, best_us = cand, us
            winners[name][bucket_of(size)] = best
            out(f"autotune: {name}[{bucket_of(size)}] winner: "
                f"{spec.param}={best}")
    if write:
        key = entry_key or config.backend_key()
        _write_table(winners, key, path or TABLE_PATH, out)
    return winners


def default_entry() -> dict:
    """The mandatory ``default`` table entry: every registered kernel's
    builtin default under the any-size bucket — byte-for-byte what an
    unknown backend resolves to, committed so CI can diff it."""
    return {name: {"*": spec.default} for name, spec in KERNELS.items()}


def _write_table(winners: dict, entry_key: str, path: Path, out) -> None:
    table = load_table(path) or {"version": TABLE_VERSION, "entries": {}}
    table.setdefault("entries", {})["default"] = default_entry()
    entry = table["entries"].setdefault(entry_key, {})
    for name, rows in winners.items():
        entry.setdefault(name, {}).update(rows)
    path.write_text(json.dumps(table, indent=2, sort_keys=True) + "\n")
    _load_raw.cache_clear()
    out(f"autotune: wrote {path} (entry {entry_key!r})")


# ---------------------------------------------------------------------------
# --check: the CI schema gate (tune-smoke step). Stdlib-only on purpose.
# ---------------------------------------------------------------------------

def check_table(path: Optional[Path] = None,
                out: Callable[[str], None] = print) -> int:
    """Validate the committed table: parses, current version, a ``default``
    entry covering every registered kernel, no stale kernel names, and
    every value shaped like its kernel's parameter. Returns 0 (ok) or 1."""
    path = path or TABLE_PATH
    errors = []
    if not path.is_file():
        errors.append(f"missing {path.name} — run "
                      f"`python -m repro.kernels.autotune --sweep --write` "
                      f"or commit the default table")
        table = {}
    else:
        try:
            table = json.loads(path.read_text())
        except json.JSONDecodeError as e:
            errors.append(f"{path.name} is not valid JSON: {e}")
            table = {}
    if table:
        if table.get("version") != TABLE_VERSION:
            errors.append(f"version {table.get('version')!r} != "
                          f"{TABLE_VERSION} (schema drift)")
        entries = table.get("entries")
        if not isinstance(entries, dict) or "default" not in entries:
            errors.append("entries.default missing — every checkout must "
                          "resolve tiles without live tuning")
            entries = entries if isinstance(entries, dict) else {}
        for ekey, entry in entries.items():
            stale = sorted(set(entry) - set(KERNELS))
            if stale:
                errors.append(f"entry {ekey!r} names unknown kernels "
                              f"{stale} — renamed? prune or re-sweep")
            for kname, rows in entry.items():
                if kname not in KERNELS:
                    continue
                spec = KERNELS[kname]
                for bucket, value in rows.items():
                    if bucket != "*" and not (
                            bucket.startswith("p")
                            and bucket[1:].isdigit()):
                        errors.append(f"{ekey}/{kname}: bad bucket "
                                      f"{bucket!r} (want 'p<k>' or '*')")
                    try:
                        _normalize(value, spec)
                    except (TypeError, ValueError):
                        errors.append(f"{ekey}/{kname}[{bucket}]: value "
                                      f"{value!r} does not parse as "
                                      f"{spec.param}")
        if "default" in entries:
            missing = sorted(set(KERNELS) - set(entries["default"]))
            if missing:
                errors.append(f"default entry missing rows for {missing} — "
                              f"every kernel needs a deterministic default")
    if errors:
        out(f"autotune --check: FAILED ({path})")
        for e in errors:
            out(f"  {e}")
        return 1
    n = sum(len(rows) for e in table["entries"].values()
            for rows in e.values())
    out(f"autotune --check: ok ({len(table['entries'])} entries, "
        f"{n} rows, {len(KERNELS)} kernels)")
    return 0


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Kernel tile autotuner (DESIGN.md §15)")
    ap.add_argument("--check", action="store_true",
                    help="validate TUNE_TABLE.json (the CI tune-smoke gate)")
    ap.add_argument("--sweep", action="store_true",
                    help="measure the candidate grids on the live backend")
    ap.add_argument("--kernel", default=None,
                    help="comma-separated kernel names (default: all)")
    ap.add_argument("--write", action="store_true",
                    help="persist sweep winners to TUNE_TABLE.json under "
                         "this backend's key")
    args = ap.parse_args(argv)
    if args.check:
        return check_table()
    if args.sweep:
        names = args.kernel.split(",") if args.kernel else None
        unknown = sorted(set(names or ()) - set(KERNELS))
        if unknown:
            print(f"autotune: unknown kernels {unknown} "
                  f"(have: {sorted(KERNELS)})", file=sys.stderr)
            return 2
        sweep(names, write=args.write)
        return 0
    ap.print_help()
    return 2


if __name__ == "__main__":
    sys.exit(main())
