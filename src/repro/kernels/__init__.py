"""Pallas TPU kernels for the framework's compute hot-spots.

    bsearch_probe  bulk binary search into prefix vectors (USR-GET inner loop)
    tree_probe     fused single-pass USR-GET over the packed index arena
    prefix_sum     carry-chained weights -> pref vector (index build)
    geo_gaps       fused GEO position generation (uniform sampling)
    flash_decode   online-softmax decode attention (serving, long KV)

Wrappers + fallbacks live in ops.py; pure-jnp oracles in ref.py. Kernels are
written for TPU (BlockSpec VMEM tiling) and validated with interpret=True on
CPU in this container.
"""
from . import ops, ref  # noqa: F401
