"""Pallas TPU kernel: fused single-pass USR-GET over a packed index arena.

The per-node USR-GET (core/probe.py) issues one XLA ``searchsorted`` plus
separate ``perm``/``child_start``/``child_w`` gathers *per tree node per
probe batch* — ~``3·depth`` HBM-resident ops per GET. This kernel fuses the
whole walk: for one probe tile it performs root-locate plus the full
pre-order tree descent — mixed-radix split (paper eq. 6-7), branchless
power-of-two binary search into each child's exclusive weight prefix, and
``perm`` resolution — in a single ``pallas_call``, reading every per-node
table from ONE flat int32 **index arena** that stays VMEM-resident across
tree levels (DESIGN.md §4 "Fused GET").

The arena is packed at shred-build time (``core.shred.pack_arena``):
``root_prefE`` first, then per tree edge (pre-order) the parent-indexed
``child_start``/``child_w`` columns and the child's ``cumw_excl``/``perm``.
All offsets are static Python ints baked into the kernel via the hashable
``layout`` aux, so the walk unrolls at trace time with zero control flow.

int32-only by design: the arena exists iff every packed value fits int32
(join_size < 2^31 — the common case; larger joins keep the int64 per-node
path per DESIGN.md §9). Probe positions are narrowed to int32 by the
caller, which is exact under the same bound.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 8  # (8, 128) int32 probe tile


def _descend(arena, off: int, length: int, q):
    """max j in [0, length-1] with arena[off + j] <= q, branchless descent.

    Requires arena[off] == 0 <= q (prefix vectors start at 0), the same
    invariant as ``bsearch_probe``; one VMEM gather per power-of-two step.
    """
    steps = max(1, math.ceil(math.log2(max(length, 2))))
    p = jnp.zeros(q.shape, jnp.int32)
    for k in range(steps - 1, -1, -1):
        cand = p + (1 << k)
        val = jnp.take(arena, off + jnp.minimum(cand, length - 1))
        ok = jnp.logical_and(cand < length, val <= q)
        p = jnp.where(ok, cand, p)
    return p


def tree_walk(arena, pos, layout):
    """The full pre-order USR walk as pure (shape-agnostic) jnp: int32
    probe positions -> per-slot row indices, slot order = ``layout.names``.

    Factored out of the kernel body so the fused one-launch draw
    (kernels/fused_draw.py, DESIGN.md §14) runs the *same* walk on its
    in-kernel sampled positions — one shared implementation is what keeps
    the fused GET and the fused draw's probe phase bit-identical. Only
    elementwise ops and VMEM gathers (``jnp.take``): safe inside Pallas
    kernel bodies (any ``pos`` shape) and in plain traced code.
    """
    # Root locate: pos -> (root row j, local offset) — paper Fig. 4 line 3.
    j = _descend(arena, 0, layout.root_len, pos)
    j = jnp.minimum(j, layout.n_root - 1)
    local = pos - jnp.take(arena, j)
    rows = {0: j}
    locs = {0: local}
    # Pre-order walk, unrolled: edges are emitted in the exact recursion
    # order of probe._usr_sub, so each parent's local offset is peeled in
    # child order (child 0 least significant — paper eq. 6-7).
    for e in layout.edges:
        prow = rows[e.parent]
        w = jnp.take(arena, e.cw_off + prow)
        w_safe = jnp.maximum(w, 1)
        idx = locs[e.parent] % w_safe
        locs[e.parent] = locs[e.parent] // w_safe
        start = jnp.take(arena, e.cs_off + prow)
        target = jnp.take(arena, e.ce_off + start) + idx
        jj = _descend(arena, e.ce_off, e.n_child + 1, target)
        jj = jnp.minimum(jj, e.n_child - 1)
        clocal = target - jnp.take(arena, e.ce_off + jj)
        crow = jnp.take(arena, e.perm_off + jj)
        rows[e.slot] = crow
        locs[e.slot] = clocal
    return [rows[s] for s in range(len(rows))]


def _kernel(arena_ref, q_ref, out_ref, *, layout):
    rows = tree_walk(arena_ref[...], q_ref[...], layout)
    for s, r in enumerate(rows):
        out_ref[s, :, :] = r


@functools.partial(jax.jit,
                   static_argnames=("layout", "block_rows", "interpret"))
def tree_probe(
    arena: jnp.ndarray,
    q: jnp.ndarray,
    *,
    layout,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> jnp.ndarray:
    """arena: (layout.size,) int32 packed index; q: (R, 128) int32 probe
    positions. Returns (layout.num_slots, R, 128) int32 — the row index of
    every tree node (slot order = ``layout.names``) for each probe lane.

    The arena is kept wholly VMEM-resident (BlockSpec pinned to block 0);
    callers own the VMEM-budget fallback (core/probe.py, DESIGN.md §9).
    """
    assert q.ndim == 2 and q.shape[1] == 128, q.shape
    rows = q.shape[0]
    grid = (pl.cdiv(rows, block_rows),)
    return pl.pallas_call(
        functools.partial(_kernel, layout=layout),
        grid=grid,
        in_specs=[
            pl.BlockSpec((layout.size,), lambda i: (0,)),      # whole arena
            pl.BlockSpec((block_rows, 128), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((layout.num_slots, block_rows, 128),
                               lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((layout.num_slots,) + q.shape,
                                       jnp.int32),
        interpret=interpret,
    )(arena, q)
