"""Pallas TPU kernel: fused single-pass USR-GET over a packed index arena.

The per-node USR-GET (core/probe.py) issues one XLA ``searchsorted`` plus
separate ``perm``/``child_start``/``child_w`` gathers *per tree node per
probe batch* — ~``3·depth`` HBM-resident ops per GET. This kernel fuses the
whole walk: for one probe tile it performs root-locate plus the full
pre-order tree descent — mixed-radix split (paper eq. 6-7), branchless
power-of-two binary search into each child's exclusive weight prefix, and
``perm`` resolution — in a single ``pallas_call``, reading every per-node
table from ONE flat int32 **index arena** that stays VMEM-resident across
tree levels (DESIGN.md §4 "Fused GET").

The arena is packed at shred-build time (``core.shred.pack_arena``):
``root_prefE`` first, then per tree edge (pre-order) the parent-indexed
``child_start``/``child_w`` columns and the child's ``cumw_excl``/``perm``.
All offsets are static Python ints baked into the kernel via the hashable
``layout`` aux, so the walk unrolls at trace time with zero control flow.

int32-only by design: the arena exists iff every packed value fits int32
(join_size < 2^31 — the common case; larger joins keep the int64 per-node
path per DESIGN.md §9). Probe positions are narrowed to int32 by the
caller, which is exact under the same bound.

**Paged variant** (``tree_probe_paged``, DESIGN.md §15): when the arena
exceeds the VMEM budget but every page (root prefix, then one page per
tree edge — ``core.shred.PagedArena``) fits it, the same walk streams the
pages through VMEM instead of falling back to the per-node path. Two
backend-shaped strategies behind one entry point: on TPU, ONE launch that
double-buffers the pages HBM->VMEM with ``pltpu.make_async_copy`` (copy of
page i+2 overlaps the walk over page i); on GPU/CPU, one small launch per
page with only that page VMEM/shared-resident — no ``pltpu``-only
primitives on that path, so the kernels compile under Pallas's other
lowerings. Both are bit-identical to ``tree_walk`` by construction: the
per-page step is the same arithmetic with page-rebased offsets.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import config

DEFAULT_BLOCK_ROWS = 8  # (8, 128) int32 probe tile


def _descend(arena, off: int, length: int, q):
    """max j in [0, length-1] with arena[off + j] <= q, branchless descent.

    Requires arena[off] == 0 <= q (prefix vectors start at 0), the same
    invariant as ``bsearch_probe``; one VMEM gather per power-of-two step.
    """
    steps = max(1, math.ceil(math.log2(max(length, 2))))
    p = jnp.zeros(q.shape, jnp.int32)
    for k in range(steps - 1, -1, -1):
        cand = p + (1 << k)
        val = jnp.take(arena, off + jnp.minimum(cand, length - 1))
        ok = jnp.logical_and(cand < length, val <= q)
        p = jnp.where(ok, cand, p)
    return p


def tree_walk(arena, pos, layout):
    """The full pre-order USR walk as pure (shape-agnostic) jnp: int32
    probe positions -> per-slot row indices, slot order = ``layout.names``.

    Factored out of the kernel body so the fused one-launch draw
    (kernels/fused_draw.py, DESIGN.md §14) runs the *same* walk on its
    in-kernel sampled positions — one shared implementation is what keeps
    the fused GET and the fused draw's probe phase bit-identical. Only
    elementwise ops and VMEM gathers (``jnp.take``): safe inside Pallas
    kernel bodies (any ``pos`` shape) and in plain traced code.
    """
    # Root locate: pos -> (root row j, local offset) — paper Fig. 4 line 3.
    j = _descend(arena, 0, layout.root_len, pos)
    j = jnp.minimum(j, layout.n_root - 1)
    local = pos - jnp.take(arena, j)
    rows = {0: j}
    locs = {0: local}
    # Pre-order walk, unrolled: edges are emitted in the exact recursion
    # order of probe._usr_sub, so each parent's local offset is peeled in
    # child order (child 0 least significant — paper eq. 6-7).
    for e in layout.edges:
        prow = rows[e.parent]
        w = jnp.take(arena, e.cw_off + prow)
        w_safe = jnp.maximum(w, 1)
        idx = locs[e.parent] % w_safe
        locs[e.parent] = locs[e.parent] // w_safe
        start = jnp.take(arena, e.cs_off + prow)
        target = jnp.take(arena, e.ce_off + start) + idx
        jj = _descend(arena, e.ce_off, e.n_child + 1, target)
        jj = jnp.minimum(jj, e.n_child - 1)
        clocal = target - jnp.take(arena, e.ce_off + jj)
        crow = jnp.take(arena, e.perm_off + jj)
        rows[e.slot] = crow
        locs[e.slot] = clocal
    return [rows[s] for s in range(len(rows))]


def _kernel(arena_ref, q_ref, out_ref, *, layout):
    rows = tree_walk(arena_ref[...], q_ref[...], layout)
    for s, r in enumerate(rows):
        out_ref[s, :, :] = r


@functools.partial(jax.jit,
                   static_argnames=("layout", "block_rows", "interpret"))
def tree_probe(
    arena: jnp.ndarray,
    q: jnp.ndarray,
    *,
    layout,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> jnp.ndarray:
    """arena: (layout.size,) int32 packed index; q: (R, 128) int32 probe
    positions. Returns (layout.num_slots, R, 128) int32 — the row index of
    every tree node (slot order = ``layout.names``) for each probe lane.

    The arena is kept wholly VMEM-resident (BlockSpec pinned to block 0);
    callers own the VMEM-budget fallback (core/probe.py, DESIGN.md §9).
    """
    assert q.ndim == 2 and q.shape[1] == 128, q.shape
    rows = q.shape[0]
    grid = (pl.cdiv(rows, block_rows),)
    return pl.pallas_call(
        functools.partial(_kernel, layout=layout),
        grid=grid,
        in_specs=[
            pl.BlockSpec((layout.size,), lambda i: (0,)),      # whole arena
            pl.BlockSpec((block_rows, 128), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((layout.num_slots, block_rows, 128),
                               lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((layout.num_slots,) + q.shape,
                                       jnp.int32),
        interpret=interpret,
    )(arena, q)


# ---------------------------------------------------------------------------
# Paged walk (DESIGN.md §15): stream pages through VMEM instead of pinning
# the whole arena. Bit-identical to tree_walk — same arithmetic, offsets
# rebased to each page's start.
# ---------------------------------------------------------------------------

def _root_page_step(page, pos, *, root_len: int, n_root: int):
    """Root locate against page 0 (== ``tree_walk``'s root phase: the root
    prefix lives at arena offset 0, so the page needs no rebasing)."""
    j = _descend(page, 0, root_len, pos)
    j = jnp.minimum(j, n_root - 1)
    return j, pos - jnp.take(page, j)


def _edge_page_step(page, edge, prow, plocal):
    """One edge of the walk against its own page: identical to the edge
    body of ``tree_walk`` with every arena offset rebased by the page
    start (``edge.cs_off`` — child_start leads the page, so its rebased
    offset is 0). Returns ``(child_row, child_local, parent_local')`` —
    the peeled parent local is threaded back by the caller, mirroring
    ``tree_walk``'s in-place ``locs[e.parent]`` update."""
    base = edge.cs_off
    w = jnp.take(page, (edge.cw_off - base) + prow)
    w_safe = jnp.maximum(w, 1)
    idx = plocal % w_safe
    plocal_new = plocal // w_safe
    start = jnp.take(page, prow)                      # cs rebased to 0
    ce = edge.ce_off - base
    target = jnp.take(page, ce + start) + idx
    jj = _descend(page, ce, edge.n_child + 1, target)
    jj = jnp.minimum(jj, edge.n_child - 1)
    clocal = target - jnp.take(page, ce + jj)
    crow = jnp.take(page, (edge.perm_off - base) + jj)
    return crow, clocal, plocal_new


def _root_page_kernel(page_ref, q_ref, out_ref, *, root_len, n_root):
    j, local = _root_page_step(page_ref[...], q_ref[...],
                               root_len=root_len, n_root=n_root)
    out_ref[0, :, :] = j
    out_ref[1, :, :] = local


def _edge_page_kernel(page_ref, prow_ref, ploc_ref, out_ref, *, edge):
    crow, clocal, pnew = _edge_page_step(page_ref[...], edge,
                                         prow_ref[...], ploc_ref[...])
    out_ref[0, :, :] = crow
    out_ref[1, :, :] = clocal
    out_ref[2, :, :] = pnew


def _paged_launches(pages, q, *, layout, block_rows, interpret):
    """GPU/CPU-shaped paged walk: one small ``pallas_call`` per page, only
    that page resident — portable Pallas (grids + BlockSpecs only, no
    ``pltpu`` primitives). The jitted driver threads parent locals between
    launches (the mixed-radix peel ``tree_walk`` does in-place)."""
    grid = (pl.cdiv(q.shape[0], block_rows),)
    tile = pl.BlockSpec((block_rows, 128), lambda i: (i, 0))

    def stacked(nbuf):
        return (pl.BlockSpec((nbuf, block_rows, 128), lambda i: (0, i, 0)),
                jax.ShapeDtypeStruct((nbuf,) + q.shape, jnp.int32))

    out_spec, out_shape = stacked(2)
    jl = pl.pallas_call(
        functools.partial(_root_page_kernel, root_len=layout.root_len,
                          n_root=layout.n_root),
        grid=grid,
        in_specs=[pl.BlockSpec((layout.root_len,), lambda i: (0,)), tile],
        out_specs=out_spec, out_shape=out_shape,
        interpret=interpret,
    )(pages[0], q)
    rows = {0: jl[0]}
    locs = {0: jl[1]}
    for k, e in enumerate(layout.edges):
        page = pages[k + 1]
        out_spec, out_shape = stacked(3)
        out = pl.pallas_call(
            functools.partial(_edge_page_kernel, edge=e),
            grid=grid,
            in_specs=[pl.BlockSpec((page.shape[0],), lambda i: (0,)),
                      tile, tile],
            out_specs=out_spec, out_shape=out_shape,
            interpret=interpret,
        )(page, rows[e.parent], locs[e.parent])
        rows[e.slot] = out[0]
        locs[e.slot] = out[1]
        locs[e.parent] = out[2]
    return jnp.stack([rows[s] for s in range(layout.num_slots)])


def _dma_paged_kernel(pages_ref, q_ref, out_ref, buf, sem, *, layout):
    """TPU-shaped paged walk: the whole pre-order walk in ONE launch, pages
    double-buffered HBM->VMEM with async copies — the DMA of page i+2
    starts the moment page i's compute frees its buffer slot, so the walk
    over page i+1 overlaps the copy behind it."""
    from jax.experimental.pallas import tpu as pltpu

    npages = len(layout.edges) + 1

    def copy(i, slot):
        return pltpu.make_async_copy(pages_ref.at[i], buf.at[slot],
                                     sem.at[slot])

    copy(0, 0).start()
    if npages > 1:
        copy(1, 1).start()
    pos = q_ref[...]
    copy(0, 0).wait()
    j, local = _root_page_step(buf[0], pos, root_len=layout.root_len,
                               n_root=layout.n_root)
    rows = {0: j}
    locs = {0: local}
    if 2 < npages:
        copy(2, 0).start()              # root page's slot just freed
    for k, e in enumerate(layout.edges):
        i = k + 1
        slot = i % 2
        copy(i, slot).wait()
        crow, clocal, pnew = _edge_page_step(buf[slot], e, rows[e.parent],
                                             locs[e.parent])
        rows[e.slot] = crow
        locs[e.slot] = clocal
        locs[e.parent] = pnew
        if i + 2 < npages:
            copy(i + 2, slot).start()   # page i's slot just freed
    for s in range(layout.num_slots):
        out_ref[s, :, :] = rows[s]


def _paged_dma(pages, q, *, layout, block_rows, interpret):
    from jax.experimental.pallas import tpu as pltpu

    # Pages ride to the kernel stacked+padded in unconstrained (HBM) memory;
    # lane-align the page stride for the DMA engine.
    P = -(-layout.max_page // 128) * 128
    stacked = jnp.stack([jnp.pad(p, (0, P - p.shape[0])) for p in pages])
    grid = (pl.cdiv(q.shape[0], block_rows),)
    return pl.pallas_call(
        functools.partial(_dma_paged_kernel, layout=layout),
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY),
                  pl.BlockSpec((block_rows, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((layout.num_slots, block_rows, 128),
                               lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((layout.num_slots,) + q.shape,
                                       jnp.int32),
        scratch_shapes=[pltpu.VMEM((2, P), jnp.int32),
                        pltpu.SemaphoreType.DMA((2,))],
        interpret=interpret,
    )(stacked, q)


@functools.partial(jax.jit, static_argnames=("layout", "block_rows",
                                             "interpret", "dma"))
def _tree_probe_paged(pages, q, *, layout, block_rows, interpret, dma):
    assert q.ndim == 2 and q.shape[1] == 128, q.shape
    run = _paged_dma if dma else _paged_launches
    return run(tuple(pages), q, layout=layout, block_rows=block_rows,
               interpret=interpret)


def tree_probe_paged(
    pages,
    q: jnp.ndarray,
    *,
    layout,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
    dma: bool = None,
) -> jnp.ndarray:
    """Paged twin of ``tree_probe``: same contract — ``q`` is (R, 128)
    int32 probe positions, returns (num_slots, R, 128) int32 rows — but the
    index arrives as ``PagedArena.pages`` (per-page slices, layout bounds)
    and only ~one page (plus a double buffer) is VMEM-resident at a time.
    ``dma=None`` picks the strategy from the detected backend
    (``config.backend()``): the in-kernel DMA pipeline on TPU, per-page
    launches elsewhere; tests pin either explicitly. Callers own the
    max-page-vs-budget gate (core/probe.py, DESIGN.md §15)."""
    if dma is None:
        dma = config.backend() == "tpu"
    return _tree_probe_paged(tuple(pages), q, layout=layout,
                             block_rows=block_rows, interpret=interpret,
                             dma=bool(dma))
