"""Pallas TPU kernel: bulk binary search of probe offsets into a prefix
vector — the inner loop of USR-GET (paper Fig. 5 line 7) and of root
location (Fig. 4 line 3).

For a sorted exclusive-prefix array ``pref`` (pref[0] = 0) and a batch of
probe offsets ``q``, computes for each lane the largest j with
pref[j] <= q — i.e. ``searchsorted(pref, q, 'right') - 1`` — using the
branchless power-of-two descent (one VMEM gather per step, log2(N) steps,
no divergent control flow, which is what the VPU wants).

Tiling: queries are tiled (BQ_ROWS, 128) into VMEM; the prefix table is kept
wholly VMEM-resident (BlockSpec index_map pinned to block 0). A 16 MiB v5e
VMEM comfortably holds 2^21 int32 prefix entries + tiles; the ops.py wrapper
falls back to XLA searchsorted above that (and for int64 offsets — TPU has
no native int64 gathers; joins > 2^31 use the fallback, see DESIGN.md §9).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 8  # (8, 128) int32 query tile


def _kernel(pref_ref, q_ref, out_ref, *, steps: int, np_len: int):
    q = q_ref[...]
    pref = pref_ref[...]
    pos = jnp.zeros(q.shape, jnp.int32)
    # Invariant: pref[pos] <= q (pref[0] == 0 <= q). Descend set bits.
    for k in range(steps - 1, -1, -1):
        cand = pos + (1 << k)
        val = jnp.take(pref, jnp.minimum(cand, np_len - 1), axis=0)
        take = jnp.logical_and(cand < np_len, val <= q)
        pos = jnp.where(take, cand, pos)
    out_ref[...] = pos


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def bsearch_probe(
    pref: jnp.ndarray,
    q: jnp.ndarray,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> jnp.ndarray:
    """pref: (NP,) int32 sorted with pref[0]==0; q: (R, 128) int32.
    Returns (R, 128) int32: max j with pref[j] <= q."""
    assert q.ndim == 2 and q.shape[1] == 128, q.shape
    np_len = pref.shape[0]
    steps = max(1, math.ceil(math.log2(max(np_len, 2))))
    rows = q.shape[0]
    grid = (pl.cdiv(rows, block_rows),)
    return pl.pallas_call(
        functools.partial(_kernel, steps=steps, np_len=np_len),
        grid=grid,
        in_specs=[
            pl.BlockSpec((np_len,), lambda i: (0,)),          # whole table
            pl.BlockSpec((block_rows, 128), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, jnp.int32),
        interpret=interpret,
    )(pref, q)
