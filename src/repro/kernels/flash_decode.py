"""Pallas TPU kernel: online-softmax decode attention (1 query token vs a
long KV cache), with GQA head mapping.

The serving-side compute hot-spot for the decode_32k / long_500k shapes:
per new token, attention reads the whole KV cache once — purely
memory-bound. The kernel streams K/V in (block_s, head_dim) tiles through
VMEM, maintaining the numerically-stable online softmax (m, l, acc) in VMEM
scratch; nothing of size S is ever materialized. Additive bias (0 / -inf)
carries both padding and windowed-attention masks (zamba2 long-context).

Grid: (batch, q_heads, S_blocks); S is the innermost (sequential) axis so
the (m, l, acc) scratch carries across S tiles of one (b, h) pair.
GQA: q head h reads kv head h // (H // KV_H) via the BlockSpec index_map —
no KV duplication in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_S = 512
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, bias_ref, out_ref, m_ref, l_ref, acc_ref,
            *, scale: float, ns_blocks: int):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]            # (1, D)
    k = k_ref[0, 0]         # (BS, D)
    v = v_ref[0, 0]         # (BS, D)
    bias = bias_ref[...]    # (1, BS)

    logits = jnp.dot(q.astype(jnp.float32), k.astype(jnp.float32).T,
                     preferred_element_type=jnp.float32) * scale + bias
    m_prev = m_ref[...]                     # (1, 1)
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    probs = jnp.exp(logits - m_new)         # (1, BS)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(probs, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        probs, v.astype(jnp.float32), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(s == ns_blocks - 1)
    def _emit():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        out_ref[0] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def flash_decode(
    q: jnp.ndarray,      # (B, H, D)
    k: jnp.ndarray,      # (B, KV_H, S, D)
    v: jnp.ndarray,      # (B, KV_H, S, D)
    bias: jnp.ndarray,   # (B, S)  additive, 0 or -inf (padding/window mask)
    *,
    block_s: int = DEFAULT_BLOCK_S,
    interpret: bool = True,
) -> jnp.ndarray:
    B, H, D = q.shape
    _, KV_H, S, _ = k.shape
    assert H % KV_H == 0 and S % block_s == 0, (H, KV_H, S, block_s)
    group = H // KV_H
    ns = S // block_s
    scale = 1.0 / (D ** 0.5)
    grid = (B, H, ns)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, ns_blocks=ns),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, D), lambda b, h, s: (b, h, 0)),
            pl.BlockSpec((1, 1, block_s, D), lambda b, h, s: (b, h // group, s, 0)),
            pl.BlockSpec((1, 1, block_s, D), lambda b, h, s: (b, h // group, s, 0)),
            pl.BlockSpec((1, block_s), lambda b, h, s: (b, s)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, h, s: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        scratch_shapes=[
            # (m, l, acc) online-softmax state, carried across the S axis
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, bias)
