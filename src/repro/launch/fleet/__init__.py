"""repro.launch.fleet — the replicated serving library (DESIGN.md §12).

One router, N engine replicas, an append-only delta log, and an
in-process deterministic transport:

    from repro.launch.fleet import Fleet, JoinSampleRequest, UpdateRequest

    fleet = Fleet(db, replicas=4)
    res = fleet.submit(JoinSampleRequest(query=q, seed=7))   # None | Rejected
    fleet.submit(UpdateRequest(delta))                       # commit = log append
    done = fleet.drain()                                     # every accepted req

Draws are pure given (query, seed, version), updates are totally ordered
by the log, and replicas apply deltas at version barriers — so the fleet's
per-seed results are bit-identical to the single-engine micro-batcher
serving the same stream, replica crashes included (the router's retry is
exact). No sockets anywhere: the transport is a discrete-event loop with
an injectable clock and a fault-injection hook, which is what makes the
crash/drop/delay tests and the determinism harness deterministic.

Public API:
    Fleet              router + replicas + log behind one facade
    serve_fleet        closed-loop serving of a request stream
    Router, Rejected   admission control + affine routing + exact retry
    Replica            one engine + micro-batcher behind a mailbox
    Transport, SimClock, FaultInjector, DROP, CRASH
    DeltaLog           append-only DeltaBatch log with LSNs
    MicroBatcher, JoinSampleRequest, UpdateRequest, serve_join_samples
"""
from __future__ import annotations

import time
from typing import List, Optional, Union

from repro.engine import CacheStats

from .batcher import (
    JoinSampleRequest, MicroBatcher, UpdateRequest, serve_join_samples,
)
from .log import DeltaLog
from .replica import DOWN, DRAINING, UP, Replica
from .router import Rejected, Router
from .transport import CRASH, DROP, FaultInjector, SimClock, Transport

__all__ = [
    "Fleet", "serve_fleet", "Router", "Rejected", "Replica", "Transport",
    "SimClock", "FaultInjector", "DROP", "CRASH", "DeltaLog", "MicroBatcher",
    "JoinSampleRequest", "UpdateRequest", "serve_join_samples",
    "UP", "DRAINING", "DOWN",
]


class Fleet:
    """N replicas behind a router, serving one database lineage.

    ``clock="sim"`` (default) runs on a ``SimClock`` — time moves only via
    ``advance``, so tests are fully deterministic; ``clock="real"`` uses
    ``time.perf_counter`` for meaningful latencies (demo, benchmark).
    """

    def __init__(self, db, *, replicas: int = 2, max_batch: int = 8,
                 max_wait_ms: float = 2.0, max_inflight: int = 64,
                 retry_timeout_s: float = 0.25, clock="sim",
                 faults: Optional[FaultInjector] = None,
                 collect_rows: bool = False):
        if replicas < 1:
            raise ValueError(f"need at least one replica, got {replicas}")
        if clock == "sim":
            clock = SimClock()
        elif clock == "real":
            clock = time.perf_counter
        self.transport = Transport(clock=clock, faults=faults)
        self.log = DeltaLog(base_version=db.version)
        self.replicas = [
            Replica(f"replica{i}", db, self.log, self.transport,
                    max_batch=max_batch, max_wait_ms=max_wait_ms,
                    collect_rows=collect_rows)
            for i in range(replicas)
        ]
        self.router = Router(self.transport, self.log,
                             [r.name for r in self.replicas],
                             max_inflight=max_inflight,
                             retry_timeout_s=retry_timeout_s)

    # -- serving -------------------------------------------------------------
    def submit(self, req) -> Optional[Rejected]:
        """Admit one request and deliver everything already due. Returns
        ``Rejected`` or None; harvest completions via ``take_completed``."""
        res = self.router.submit(req)
        self.transport.pump()
        return res

    def take_completed(self) -> List[object]:
        return self.router.take_completed()

    def advance(self, dt: float) -> List[object]:
        """SimClock: move time forward (deadline flushes, retry timers fire
        on schedule) and return what completed."""
        self.transport.advance(dt)
        return self.take_completed()

    def pump(self) -> List[object]:
        self.transport.pump()
        return self.take_completed()

    def drain(self) -> List[object]:
        """Flush every replica, catch them all up to the log head, and
        return every remaining completion. After this the fleet rejects."""
        self.router.start_drain()
        self.transport.run()
        return self.take_completed()

    def crash(self, replica: Union[int, str]) -> None:
        """Test/demo hook: fail-stop one replica right now."""
        r = self.replicas[replica] if isinstance(replica, int) else \
            next(x for x in self.replicas if x.name == replica)
        r.crash()
        self.transport.pump()

    # -- observability -------------------------------------------------------
    def stats(self) -> CacheStats:
        """Replica-aware aggregation: field-wise sum of every replica's
        engine CacheStats (affinity shows up as one plan miss per shape
        per homing replica)."""
        return CacheStats.aggregate(r.engine.stats for r in self.replicas)

    def health(self) -> dict:
        return dict(self.router.health)

    @property
    def db_version(self) -> int:
        """The committed version (log head) — replicas converge to it at
        their next barrier; ``drain`` forces convergence."""
        return self.log.head_version


def serve_fleet(db, requests: List, *, replicas: int = 2, max_batch: int = 8,
                max_wait_ms: float = 2.0, max_inflight: int = 256,
                retry_timeout_s: float = 0.25, clock="sim",
                faults: Optional[FaultInjector] = None,
                collect_rows: bool = False,
                arrival_gap_s: float = 0.0,
                crash_at: Optional[int] = None,
                crash_replica: int = 0) -> List[object]:
    """Closed-loop fleet serving: submit the stream in order, drain, and
    return ``(done, fleet)`` — completions (rejected requests appear as
    ``Rejected`` wrappers in arrival position) plus the fleet for stats
    inspection. ``crash_at=k`` fail-stops ``crash_replica`` after the k-th
    submission — the fault-tolerance demo path (DESIGN.md §12)."""
    fleet = Fleet(db, replicas=replicas, max_batch=max_batch,
                  max_wait_ms=max_wait_ms, max_inflight=max_inflight,
                  retry_timeout_s=retry_timeout_s, clock=clock, faults=faults,
                  collect_rows=collect_rows)
    done: List[object] = []
    for i, req in enumerate(requests):
        res = fleet.submit(req)
        if res is not None:
            done.append(res)
        done += fleet.take_completed()
        if crash_at is not None and i + 1 == crash_at:
            fleet.crash(crash_replica)
            done += fleet.take_completed()
        if arrival_gap_s and isinstance(fleet.transport.clock, SimClock):
            done += fleet.advance(arrival_gap_s)
    done += fleet.drain()
    return done, fleet
