"""In-process deterministic transport for the replicated fleet
(DESIGN.md §12).

The fleet's router and replicas never touch sockets: they exchange
messages through a ``Transport``, a discrete-event loop with

  * an **injectable clock** — ``SimClock`` (tests: time advances only when
    the driver says so, so deadline flushes are reproducible) or any
    0-argument callable returning seconds (the demo/benchmark pass
    ``time.perf_counter`` for real latencies);
  * **total delivery order** — messages are delivered in
    ``(deliver_time, send_sequence)`` order, so two runs over the same
    arrival schedule and fault plan are bit-identical;
  * a **fault-injection hook** (``FaultInjector``) that can drop or delay
    individual messages and crash endpoints at named code points
    ("the 2nd delivery from router to replica1", "replica0's next flush"),
    again fully deterministically.

Endpoints register a handler; ``send`` enqueues, ``pump``/``advance``/
``run`` deliver. Delivery to a crashed endpoint silently drops (the wire
does not buffer for the dead) — crash *notification* is the monitor's
(router's) job via the ``on_crash`` callback.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Dict, List, Optional, Tuple

__all__ = ["SimClock", "FaultInjector", "Envelope", "Transport",
           "DROP", "CRASH"]

DROP = "drop"
CRASH = "crash"


class SimClock:
    """A manually-advanced clock (seconds). ``Transport.advance``/``run``
    move it forward; nothing else does."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t


@dataclasses.dataclass
class _Rule:
    """One scheduled fault: fire ``action`` on the ``at``-th (1-based)
    occurrence of ``point``."""

    point: str
    action: object  # DROP | CRASH | ("delay", seconds)
    at: int = 1
    fired: bool = False


class FaultInjector:
    """A deterministic schedule of faults keyed by named code points.

    Points are plain strings; the fleet uses two families:

      * ``"deliver:<src>-><dst>"`` — consulted by ``Transport.send`` for
        every message on that edge (actions: ``DROP``, ``("delay", s)``,
        ``CRASH`` = crash the destination instead of delivering);
      * ``"<replica>:flush"`` / ``"<replica>:apply"`` — consulted by the
        replica before flushing a micro-batch / applying a log delta
        (action: ``CRASH``).

    ``inject(point, action, at=n)`` arms the n-th occurrence (1-based);
    occurrences are counted per point, so a plan like "drop the 3rd
    response from replica2" is one line in a test.
    """

    def __init__(self):
        self._rules: List[_Rule] = []
        self._counts: Dict[str, int] = {}

    def inject(self, point: str, action, *, at: int = 1) -> "FaultInjector":
        if isinstance(action, tuple):
            kind, delay = action
            if kind != "delay" or delay < 0:
                raise ValueError(f"bad fault action {action!r}")
        elif action not in (DROP, CRASH):
            raise ValueError(f"bad fault action {action!r}")
        self._rules.append(_Rule(point, action, at=at))
        return self

    def fire(self, point: str):
        """Count one occurrence of ``point``; return the armed action for
        this occurrence, or None."""
        n = self._counts.get(point, 0) + 1
        self._counts[point] = n
        for rule in self._rules:
            if rule.point == point and rule.at == n and not rule.fired:
                rule.fired = True
                return rule.action
        return None

    @property
    def pending(self) -> int:
        """Armed rules that have not fired yet (tests assert 0 at exit —
        a fault plan that never triggered is usually a test bug)."""
        return sum(1 for r in self._rules if not r.fired)


@dataclasses.dataclass
class Envelope:
    src: str
    dst: str
    payload: object
    send_t: float
    deliver_t: float
    seq: int


class Transport:
    """The in-process wire: named endpoints, ordered delivery, faults.

    ``clock`` may be a ``SimClock`` (default) or any callable -> seconds.
    With a ``SimClock``, ``advance(dt)`` moves time and delivers everything
    that comes due, in order; with a real clock, ``pump()`` delivers what
    is already due and ``run()`` drains regardless of wall time.
    """

    def __init__(self, *, clock: Optional[Callable[[], float]] = None,
                 faults: Optional[FaultInjector] = None):
        self.clock = clock if clock is not None else SimClock()
        self.faults = faults or FaultInjector()
        self._handlers: Dict[str, Callable[[Envelope], None]] = {}
        self._down: Dict[str, bool] = {}
        self._queue: List[Tuple[float, int, Envelope]] = []
        self._seq = itertools.count()
        self.on_crash: Optional[Callable[[str], None]] = None
        self.delivered = 0
        self.dropped = 0

    # -- endpoints -----------------------------------------------------------
    def register(self, name: str, handler: Callable[[Envelope], None]) -> None:
        if name in self._handlers:
            raise ValueError(f"endpoint {name!r} already registered")
        self._handlers[name] = handler
        self._down[name] = False

    def is_up(self, name: str) -> bool:
        return name in self._handlers and not self._down[name]

    def crash(self, name: str) -> None:
        """Mark an endpoint dead. Queued and future messages to it drop;
        the monitor (router) is told exactly once."""
        if self._down.get(name):
            return
        self._down[name] = True
        if self.on_crash is not None:
            self.on_crash(name)

    # -- sending -------------------------------------------------------------
    def send(self, src: str, dst: str, payload, *,
             delay: float = 0.0) -> None:
        """Enqueue ``payload`` for delivery ``delay`` seconds from now.
        The edge's fault point fires here (send time), so a drop costs the
        wire nothing and a delay is added on top of ``delay``."""
        now = self.clock()
        action = self.faults.fire(f"deliver:{src}->{dst}")
        if action == DROP:
            self.dropped += 1
            return
        if action == CRASH:
            self.dropped += 1
            self.crash(dst)
            return
        if isinstance(action, tuple):  # ("delay", seconds)
            delay += action[1]
        env = Envelope(src, dst, payload, now, now + delay, next(self._seq))
        heapq.heappush(self._queue, (env.deliver_t, env.seq, env))

    def call_later(self, dst: str, dt: float, payload) -> None:
        """A timer: the endpoint sends itself a message ``dt`` seconds out.
        Timers bypass fault points — they model local clocks, not wires."""
        now = self.clock()
        env = Envelope(dst, dst, payload, now, now + dt, next(self._seq))
        heapq.heappush(self._queue, (env.deliver_t, env.seq, env))

    # -- delivery ------------------------------------------------------------
    def _deliver(self, env: Envelope) -> None:
        if self._down.get(env.dst, True):
            self.dropped += 1  # the dead do not receive
            return
        self.delivered += 1
        self._handlers[env.dst](env)

    def pump(self) -> int:
        """Deliver everything already due (``deliver_t <= now``) in order.
        Returns the number of messages delivered."""
        n = 0
        while self._queue and self._queue[0][0] <= self.clock():
            _, _, env = heapq.heappop(self._queue)
            self._deliver(env)
            n += 1
        return n

    def advance(self, dt: float) -> int:
        """SimClock only: move time forward by ``dt``, delivering due
        messages at their own timestamps along the way."""
        if not isinstance(self.clock, SimClock):
            raise TypeError("advance() needs a SimClock; real clocks move "
                            "on their own — use pump()/run()")
        target = self.clock.t + dt
        n = 0
        while self._queue and self._queue[0][0] <= target:
            self.clock.t = max(self.clock.t, self._queue[0][0])
            n += self.pump()
        self.clock.t = target
        return n

    def run(self) -> int:
        """Drain the queue completely (delivery may enqueue more; keep
        going until quiet). With a ``SimClock``, time jumps to each
        message's deliver_t; with a real clock, late messages deliver
        immediately — draining never busy-waits."""
        n = 0
        while self._queue:
            deliver_t, _, _ = self._queue[0]
            if isinstance(self.clock, SimClock):
                self.clock.t = max(self.clock.t, deliver_t)
            _, _, env = heapq.heappop(self._queue)
            self._deliver(env)
            n += 1
        return n

    @property
    def queued(self) -> int:
        return len(self._queue)
