"""The micro-batching request loop over ``QueryEngine.sample_batch``
(DESIGN.md §10) — the per-engine serving core, shared by the single-engine
serve loop and every fleet replica (DESIGN.md §12).

Moved here from ``launch/serve.py`` when the fleet library landed;
``launch.serve`` re-exports these names, so existing imports keep working.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["JoinSampleRequest", "UpdateRequest", "MicroBatcher",
           "serve_join_samples"]


@dataclasses.dataclass
class JoinSampleRequest:
    """One tenant request: draw an independent Poisson sample of ``query``."""

    query: "JoinQuery"
    seed: int = 0
    count: Optional[int] = None       # filled by the service
    overflow: Optional[bool] = None   # filled by the service
    latency_s: Optional[float] = None  # enqueue -> results routed back
    enqueued_s: Optional[float] = None  # set by MicroBatcher.submit
    db_version: Optional[int] = None  # snapshot version the draw was served from
    rows: Optional[Dict[str, np.ndarray]] = None  # collect_rows=True only


@dataclasses.dataclass
class UpdateRequest:
    """One tenant update: advance the engine's snapshot by ``delta`` (a
    ``core.delta.DeltaBatch``). Serialized against draws by the micro-batch
    loop (DESIGN.md §11): draws enqueued before the update are flushed
    against the pre-delta snapshot first, so no in-flight batch ever mixes
    versions."""

    delta: object
    applied_version: Optional[int] = None  # post-apply db version
    latency_s: Optional[float] = None
    enqueued_s: Optional[float] = None


class MicroBatcher:
    """Micro-batching front end over ``QueryEngine.sample_batch``
    (DESIGN.md §10).

    Requests accumulate in an arrival-ordered queue and are flushed as
    batched dispatches when either trigger fires:

      * **size** — the queue reaches ``max_batch`` requests;
      * **deadline** — the oldest pending request has waited
        ``max_wait_ms`` (checked by ``poll()``, which the serving loop
        calls between arrivals).

    A flush groups pending requests by query fingerprint and issues ONE
    ``sample_batch`` dispatch per distinct shape — mixed-tenant queues
    share the engine's plan cache (one plan per shape, reused across
    flushes), and per-request results are routed back by lane index.
    ``clock`` is injectable so deadline behavior is unit-testable
    (``tests/test_serve_batcher.py``).

    ``UpdateRequest``s interleave with draws (DESIGN.md §11): an update
    acts as a barrier — pending draws flush first (reading the pre-delta
    snapshot), then the delta is applied via ``engine.apply_delta`` (warm
    cache entries upgrade in place, so the next flush pays no rebuild),
    and draws submitted afterwards read the new version. Every completed
    draw records the ``db_version`` it was served from.

    ``collect_rows=True`` additionally copies each draw's valid sample
    rows (the first ``count`` lanes, host-side numpy) onto
    ``JoinSampleRequest.rows`` — the fleet's determinism harness compares
    these bit-for-bit against the single-engine baseline (DESIGN.md §12).
    Off by default: it forces a device->host transfer per flush.
    """

    def __init__(self, engine, *, max_batch: int = 64,
                 max_wait_ms: float = 2.0, mesh=None, axes=None,
                 clock=time.perf_counter, collect_rows: bool = False):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.engine = engine
        self.max_batch = max_batch
        self.max_wait_ms = max_wait_ms
        self.mesh = mesh
        self.axes = axes
        self.clock = clock
        self.collect_rows = collect_rows
        self.pending: List[JoinSampleRequest] = []
        self.flushes = 0
        self.dispatches = 0
        self.served = 0
        self.updates_applied = 0

    def submit(self, req) -> List:
        """Enqueue one request; returns completed requests (non-empty only
        when this arrival triggered work: a full batch for draws, or the
        flush-then-apply barrier for updates)."""
        req.enqueued_s = self.clock()
        if isinstance(req, UpdateRequest):
            return self._apply_update(req)
        self.pending.append(req)
        if len(self.pending) >= self.max_batch:
            return self.flush()
        return []

    def _apply_update(self, req: UpdateRequest) -> List:
        """The update barrier: drain pending draws on the current snapshot,
        then advance it. In-flight batches therefore always read ONE
        consistent version; later draws read the next."""
        done = self.flush()
        self.engine.apply_delta(req.delta)
        req.applied_version = self.engine.db.version
        req.latency_s = self.clock() - req.enqueued_s
        self.updates_applied += 1
        return done + [req]

    def poll(self) -> List[JoinSampleRequest]:
        """Deadline check: flush iff the oldest pending request has waited
        at least ``max_wait_ms``. Call between arrivals / when idle."""
        if self.pending and \
                (self.clock() - self.pending[0].enqueued_s) * 1e3 >= self.max_wait_ms:
            return self.flush()
        return []

    def flush(self) -> List[JoinSampleRequest]:
        """Dispatch everything pending now (one batched draw per distinct
        query fingerprint) and route results back to their requests."""
        from repro.engine import query_fingerprint

        batch, self.pending = self.pending, []
        if not batch:
            return []
        groups: Dict[str, List[JoinSampleRequest]] = {}
        for r in batch:
            groups.setdefault(query_fingerprint(r.query), []).append(r)
        version = getattr(self.engine.db, "version", 0)
        for reqs in groups.values():
            keys = jnp.stack([jax.random.key(r.seed) for r in reqs])
            smp = self.engine.sample_batch(reqs[0].query, keys,
                                           mesh=self.mesh, axes=self.axes)
            jax.block_until_ready(smp.count)
            done_t = self.clock()
            counts = np.asarray(smp.count)
            overflow = np.asarray(smp.overflow)
            cols = ({c: np.asarray(v) for c, v in smp.columns.items()}
                    if self.collect_rows else None)
            for lane, r in enumerate(reqs):
                r.count = int(counts[lane])
                r.overflow = bool(overflow[lane])
                r.latency_s = done_t - r.enqueued_s
                r.db_version = version
                if cols is not None:
                    r.rows = {c: v[lane, : r.count].copy()
                              for c, v in cols.items()}
            self.dispatches += 1
        self.flushes += 1
        self.served += len(batch)
        return batch


def serve_join_samples(engine, requests: List, mesh=None,
                       max_batch: int = 64, max_wait_ms: float = 2.0,
                       collect_rows: bool = False) -> List:
    """Serve a request list through the micro-batcher (closed loop: submit
    everything, then drain). The list may interleave ``JoinSampleRequest``
    draws with ``UpdateRequest`` deltas; updates barrier the stream in
    arrival order (DESIGN.md §11). Kept as the library entry point the demo
    and tests share; results are routed back onto the request objects.

    This is also the fleet's single-engine *baseline*: ``Fleet`` serving
    the same stream must reproduce these results bit-for-bit per
    (seed, version) (DESIGN.md §12)."""
    mb = MicroBatcher(engine, max_batch=max_batch, max_wait_ms=max_wait_ms,
                      mesh=mesh, collect_rows=collect_rows)
    done: List[JoinSampleRequest] = []
    for r in requests:
        done += mb.submit(r)
        done += mb.poll()
    done += mb.flush()  # drain the tail regardless of deadline
    return done
