"""The fleet router: admission control, fingerprint-affine routing, and
exact retry (DESIGN.md §12).

**Admission** is a bounded in-flight window: past ``max_inflight``
accepted-but-incomplete draws, ``submit`` returns an explicit ``Rejected``
— backpressure is a *response*, never a silent drop. (The seam where an
AGM/OUT-style output-size bound — Kim et al., arXiv 2304.00715 — would
set the window per query shape is ``Router.admit``; today it is a plain
count.)

**Routing** is affine on the query fingerprint: each shape hashes to a
home replica (stable across runs — md5, not the salted builtin ``hash``),
so each replica compiles only the shapes it homes — one plan-cache miss
per shape per replica, observable in the aggregated ``CacheStats``.

**Retry is exact, not at-least-once-approximate**: every accepted draw is
stamped with the log head version at admission, and a draw is a pure
function of (query, seed, version). When a replica crashes or a message
drops, the router re-sends the same stamped draw to a healthy replica and
gets the *bit-identical* result the lost serving would have produced.
Responses are deduplicated by request id (first one wins), and replicas
answer repeated ids from their served cache, so nothing is ever delivered
to the client twice.

**Updates** commit at the log append — the returned ``applied_version``
is ``base_version + lsn``. Replicas apply them later, at their own
version barriers; draws admitted after the update are stamped with the
new version and therefore observe it wherever they are served.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
from typing import Dict, List, Optional

from repro.engine import query_fingerprint

from .batcher import JoinSampleRequest, UpdateRequest
from .log import DeltaLog
from .replica import DOWN, DRAINING, Drain, DrainDone, Draw, DrawDone, UP
from .transport import Envelope, Transport

__all__ = ["Rejected", "Router"]


@dataclasses.dataclass
class Rejected:
    """An explicit backpressure response: the request was NOT admitted and
    will never complete — resubmit later or shed load."""

    request: object
    reason: str


@dataclasses.dataclass
class _RetryTimer:
    rid: int
    attempt: int


@dataclasses.dataclass
class _InFlight:
    req: JoinSampleRequest
    fingerprint: str
    version: int
    replica: str
    attempt: int = 1


class Router:
    def __init__(self, transport: Transport, log: DeltaLog,
                 replicas: List[str], *, name: str = "router",
                 max_inflight: int = 64, retry_timeout_s: float = 0.25):
        if not replicas:
            raise ValueError("router needs at least one replica")
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        self.name = name
        self.transport = transport
        self.log = log
        self.replicas = list(replicas)
        self.max_inflight = max_inflight
        self.retry_timeout_s = retry_timeout_s
        self.health: Dict[str, str] = {r: UP for r in replicas}
        self.inflight: Dict[int, _InFlight] = {}
        self.completed: List[object] = []
        self.drained: Dict[str, DrainDone] = {}
        self.accepted = 0
        self.rejected = 0
        self.retries = 0
        self.duplicates = 0
        self.updates = 0
        self._rid = itertools.count(1)
        transport.register(name, self.handle)
        transport.on_crash = self._on_replica_crash

    # -- admission -----------------------------------------------------------
    def admit(self, req: JoinSampleRequest) -> Optional[str]:
        """The admission-control policy seam: return a rejection reason or
        None to admit. Today: a bounded in-flight window."""
        if len(self.inflight) >= self.max_inflight:
            return (f"admission queue full "
                    f"({len(self.inflight)}/{self.max_inflight} in flight)")
        if not any(h == UP for h in self.health.values()):
            return "no healthy replicas"
        return None

    def submit(self, req) -> Optional[Rejected]:
        """Admit one request. Returns ``Rejected`` (with the reason) or
        None on acceptance; completions surface via ``take_completed``."""
        req.enqueued_s = self.transport.clock()
        if isinstance(req, UpdateRequest):
            lsn = self.log.append(req.delta)
            req.applied_version = self.log.base_version + lsn
            req.latency_s = self.transport.clock() - req.enqueued_s
            self.updates += 1
            self.completed.append(req)
            return None
        reason = self.admit(req)
        if reason is not None:
            self.rejected += 1
            return Rejected(req, reason)
        self.accepted += 1
        rid = next(self._rid)
        fp = query_fingerprint(req.query)
        fl = _InFlight(req, fp, self.log.head_version, self._route(fp))
        self.inflight[rid] = fl
        self._send(rid, fl)
        return None

    # -- routing -------------------------------------------------------------
    def _route(self, fingerprint: str) -> str:
        """The fingerprint's home replica, or the next healthy one ring-wise
        when the home is down/draining."""
        n = len(self.replicas)
        home = int(hashlib.md5(fingerprint.encode()).hexdigest(), 16) % n
        for i in range(n):
            cand = self.replicas[(home + i) % n]
            if self.health[cand] == UP:
                return cand
        raise RuntimeError("no healthy replicas to route to")

    def _send(self, rid: int, fl: _InFlight) -> None:
        self.transport.send(self.name, fl.replica,
                            Draw(rid, fl.req.query, fl.req.seed, fl.version))
        self.transport.call_later(self.name, self.retry_timeout_s,
                                  _RetryTimer(rid, fl.attempt))

    def _retry(self, rid: int, fl: _InFlight) -> None:
        self.retries += 1
        fl.attempt += 1
        fl.replica = self._route(fl.fingerprint)
        self._send(rid, fl)

    # -- mailbox -------------------------------------------------------------
    def handle(self, env: Envelope) -> None:
        msg = env.payload
        if isinstance(msg, DrawDone):
            fl = self.inflight.pop(msg.rid, None)
            if fl is None:
                self.duplicates += 1  # a retry raced the original; first won
                return
            if msg.db_version != fl.version:
                raise AssertionError(
                    f"rid {msg.rid}: served at version {msg.db_version}, "
                    f"stamped {fl.version} — the version barrier leaked")
            r = fl.req
            r.count = msg.count
            r.overflow = msg.overflow
            r.db_version = msg.db_version
            r.rows = msg.rows
            r.latency_s = self.transport.clock() - r.enqueued_s
            self.completed.append(r)
        elif isinstance(msg, _RetryTimer):
            fl = self.inflight.get(msg.rid)
            if fl is not None and fl.attempt == msg.attempt:
                self._retry(msg.rid, fl)
        elif isinstance(msg, DrainDone):
            self.drained[msg.replica] = msg
            self.health[msg.replica] = DOWN  # cleanly drained = out of rotation
        else:
            raise TypeError(f"router: unexpected message {msg!r}")

    def _on_replica_crash(self, name: str) -> None:
        if self.health.get(name) == DOWN:
            return
        self.health[name] = DOWN
        # Exact retry: every in-flight draw assigned to the dead replica is
        # re-sent, same stamp, to a healthy one.
        for rid, fl in list(self.inflight.items()):
            if fl.replica == name:
                self._retry(rid, fl)

    # -- lifecycle -----------------------------------------------------------
    def take_completed(self) -> List[object]:
        done, self.completed = self.completed, []
        return done

    def start_drain(self) -> None:
        """Tell every live replica to finish pending work, catch up to the
        log head, and stop. New submissions reject from here on."""
        for r in self.replicas:
            if self.health[r] == UP:
                self.health[r] = DRAINING
                self.transport.send(self.name, r, Drain())
