"""The append-only delta log: the fleet's single source of write truth
(DESIGN.md §12).

All updates flow through one ``DeltaLog``. ``append`` stamps each
``DeltaBatch`` with a 1-based log sequence number (LSN) and the append IS
the commit point: an update is durable (fleet-visible) the moment it has
an LSN, before any replica has applied it. Replicas consume the log
independently — each keeps its own cursor and applies entries *at version
barriers* (when a draw stamped with a newer version arrives, or at drain),
so along the log

    snapshot.version == base_version + lsn

holds on every replica, and each replica's snapshot sequence is
bit-identical to ``Database.apply``-ing the log entries in order on a
single engine (property-tested in tests/test_fleet_replay.py).
"""
from __future__ import annotations

from typing import List

from repro.core.delta import DeltaBatch

__all__ = ["DeltaLog"]


class DeltaLog:
    """Append-only, in-process. ``base_version`` is the version of the
    snapshot the log starts from (entry ``lsn`` advances it to
    ``base_version + lsn``)."""

    def __init__(self, base_version: int = 0):
        self.base_version = base_version
        self._entries: List[DeltaBatch] = []

    def append(self, delta: DeltaBatch) -> int:
        """Commit ``delta``; returns its LSN (1-based)."""
        lsn = len(self._entries) + 1
        self._entries.append(delta.with_lsn(lsn))
        return lsn

    @property
    def head(self) -> int:
        """The highest committed LSN (0 when empty)."""
        return len(self._entries)

    @property
    def head_version(self) -> int:
        """The snapshot version a fully caught-up replica sits at."""
        return self.base_version + self.head

    def entry(self, lsn: int) -> DeltaBatch:
        if not 1 <= lsn <= self.head:
            raise IndexError(f"lsn {lsn} outside [1, {self.head}]")
        return self._entries[lsn - 1]

    def read(self, after_lsn: int, upto_lsn: int) -> List[DeltaBatch]:
        """Entries with ``after_lsn < lsn <= upto_lsn`` in order — what a
        replica at ``after_lsn`` replays to reach ``upto_lsn``."""
        if upto_lsn > self.head:
            raise IndexError(f"read past the head: {upto_lsn} > {self.head}")
        return self._entries[after_lsn:upto_lsn]

    def version_to_lsn(self, version: int) -> int:
        return version - self.base_version

    def __len__(self) -> int:
        return self.head
