"""A fleet replica: one ``QueryEngine`` + ``MicroBatcher`` behind a
transport mailbox (DESIGN.md §12).

Every replica starts from the same base snapshot and consumes the shared
``DeltaLog`` independently. Deltas are applied **at version barriers**:
when a draw stamped with a version ahead of the replica's snapshot
arrives (or at drain), the replica first flushes its pending micro-batch
— those draws read the old snapshot, exactly like the single-engine
update barrier (DESIGN.md §11) — then replays log entries in LSN order.
Because application order is the log order everywhere, every replica's
snapshot sequence is bit-identical to ``Database.apply``-ing the log on
one engine.

Draws are *pure* given (query, seed, version): the replica keeps its
snapshot history, so a draw stamped with an **older** version (delayed or
retried after the replica advanced) is served from the historical
snapshot — the result is still exactly the stamped version's, never an
approximation. Served responses are cached by request id, so a retried
draw whose response was dropped is answered idempotently, not recomputed
into a second serving.

Health states: ``up`` (serving), ``draining`` (finish pending + catch up
to the log head, then stop), ``down`` (crashed, or drained). A crash
clears the pending micro-batch — the router's retry logic (exact, thanks
to purity) is what makes that loss invisible to clients.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, Optional

import jax
import numpy as np

from repro.engine import QueryEngine

from .batcher import JoinSampleRequest, MicroBatcher
from .log import DeltaLog
from .transport import CRASH, Envelope, Transport

__all__ = ["Draw", "DrawDone", "Drain", "DrainDone", "FlushTimer",
           "Replica", "UP", "DRAINING", "DOWN"]

UP, DRAINING, DOWN = "up", "draining", "down"


# -- wire messages -----------------------------------------------------------

@dataclasses.dataclass
class Draw:
    """Router -> replica: serve one Poisson draw at exactly ``version``."""

    rid: int
    query: object
    seed: int
    version: int


@dataclasses.dataclass
class DrawDone:
    """Replica -> router: the draw's result. ``db_version`` echoes the
    snapshot actually read — the router asserts it equals the stamp."""

    rid: int
    count: int
    overflow: bool
    db_version: int
    replica: str
    rows: Optional[Dict[str, np.ndarray]] = None


@dataclasses.dataclass
class Drain:
    """Router -> replica: finish pending work, catch up to the log head,
    then stop accepting draws."""


@dataclasses.dataclass
class DrainDone:
    replica: str
    db_version: int
    stats: object  # engine CacheStats snapshot


@dataclasses.dataclass
class FlushTimer:
    """Self-timer armed when the queue goes non-empty: fires the deadline
    flush at exactly enqueue + max_wait_ms (reproducible under SimClock)."""


@dataclasses.dataclass
class _Draw(JoinSampleRequest):
    """A micro-batcher request carrying its fleet request id."""

    rid: int = -1


class Replica:
    def __init__(self, name: str, db, log: DeltaLog, transport: Transport,
                 *, router: str = "router", max_batch: int = 8,
                 max_wait_ms: float = 2.0, collect_rows: bool = False,
                 max_stale_engines: int = 4):
        self.name = name
        self.log = log
        self.transport = transport
        self.router = router
        self.max_batch = max_batch
        self.max_wait_ms = max_wait_ms
        self.collect_rows = collect_rows
        self.state = UP
        self.engine = QueryEngine(db)
        # The batcher never self-flushes on size: the replica owns both
        # triggers so every flush passes the "<name>:flush" fault point.
        self.batcher = MicroBatcher(
            self.engine, max_batch=1 << 30, max_wait_ms=max_wait_ms,
            clock=transport.clock, collect_rows=collect_rows)
        # version -> snapshot, for exact service of older-stamped draws.
        self.snapshots: Dict[int, object] = {db.version: db}
        self._stale_engines: "collections.OrderedDict[int, QueryEngine]" = \
            collections.OrderedDict()
        self._max_stale = max_stale_engines
        self.served: Dict[int, DrawDone] = {}
        self.duplicates = 0
        self.stale_serves = 0
        transport.register(name, self.handle)

    # -- mailbox -------------------------------------------------------------
    def handle(self, env: Envelope) -> None:
        msg = env.payload
        if isinstance(msg, Draw):
            self._on_draw(msg)
        elif isinstance(msg, FlushTimer):
            self._on_timer()
        elif isinstance(msg, Drain):
            self._on_drain()
        else:
            raise TypeError(f"{self.name}: unexpected message {msg!r}")

    def _on_draw(self, msg: Draw) -> None:
        cached = self.served.get(msg.rid)
        if cached is not None:
            # Idempotent retry: the draw was already served (its response
            # was dropped, or the router timed out early) — resend the
            # cached result instead of serving twice.
            self.duplicates += 1
            self.transport.send(self.name, self.router, cached)
            return
        if self.state != UP:
            return  # draining/down replicas take no new work; retry covers it
        if msg.version > self.engine.db.version:
            if not self._catch_up(msg.version):
                return  # crashed at the barrier
        if msg.version < self.engine.db.version:
            self._serve_stale(msg)
            return
        req = _Draw(query=msg.query, seed=msg.seed, rid=msg.rid)
        if len(self.batcher.pending) + 1 >= self.max_batch:
            self.batcher.submit(req)
            self._respond_all(self._flush())
        else:
            self.batcher.submit(req)
            if len(self.batcher.pending) == 1:
                self.transport.call_later(self.name, self.max_wait_ms * 1e-3,
                                          FlushTimer())

    def _on_timer(self) -> None:
        if self.state != UP or not self.batcher.pending:
            return
        waited_ms = (self.transport.clock()
                     - self.batcher.pending[0].enqueued_s) * 1e3
        if waited_ms >= self.max_wait_ms - 1e-9:
            self._respond_all(self._flush())
        else:
            # The guarded request flushed already; a younger one now heads
            # the queue. Re-arm for its remaining wait.
            self.transport.call_later(
                self.name, self.max_wait_ms * 1e-3 - waited_ms * 1e-3,
                FlushTimer())

    def _on_drain(self) -> None:
        if self.state != UP:
            return
        self.state = DRAINING
        self._respond_all(self._flush())
        if self.state == DOWN:
            return  # crashed mid-drain; the router's retries take over
        self._catch_up(self.log.head_version)
        if self.state == DOWN:
            return
        self.state = DOWN  # cleanly drained
        self.transport.send(self.name, self.router, DrainDone(
            self.name, self.engine.db.version, self.engine.stats.snapshot()))

    # -- serving -------------------------------------------------------------
    def _flush(self):
        """Every flush passes the fault point — "crash mid-flush" loses the
        whole pending batch, which is exactly what retry must survive."""
        if self.state == DOWN:
            return []
        if self.transport.faults.fire(f"{self.name}:flush") == CRASH:
            self.crash()
            return []
        return self.batcher.flush()

    def _catch_up(self, version: int) -> bool:
        """The version barrier: drain pending draws on the current
        snapshot, then replay log entries up to ``version`` in LSN order,
        recording every intermediate snapshot."""
        self._respond_all(self._flush())
        if self.state == DOWN:
            return False
        cur = self.log.version_to_lsn(self.engine.db.version)
        for delta in self.log.read(cur, self.log.version_to_lsn(version)):
            if self.transport.faults.fire(f"{self.name}:apply") == CRASH:
                self.crash()
                return False
            self.engine.apply_delta(delta)
            self.snapshots[self.engine.db.version] = self.engine.db
        return True

    def _serve_stale(self, msg: Draw) -> None:
        """Serve a draw stamped with a version this replica has already
        moved past — from the historical snapshot, so the result is
        bit-identical to what a replica still at that version returns."""
        db = self.snapshots.get(msg.version)
        if db is None:
            raise KeyError(f"{self.name}: no snapshot for version "
                           f"{msg.version} (have {sorted(self.snapshots)})")
        eng = self._stale_engines.get(msg.version)
        if eng is None:
            eng = QueryEngine(db)
            self._stale_engines[msg.version] = eng
            while len(self._stale_engines) > self._max_stale:
                self._stale_engines.popitem(last=False)
        else:
            self._stale_engines.move_to_end(msg.version)
        smp = eng.sample(msg.query, jax.random.key(msg.seed))
        self.stale_serves += 1
        count = int(smp.count)
        rows = None
        if self.collect_rows:
            rows = {c: np.asarray(v)[:count].copy()
                    for c, v in smp.columns.items()}
        resp = DrawDone(msg.rid, count, bool(smp.overflow), msg.version,
                        self.name, rows=rows)
        self.served[msg.rid] = resp
        self.transport.send(self.name, self.router, resp)

    def _respond_all(self, done) -> None:
        for r in done:
            resp = DrawDone(r.rid, r.count, r.overflow, r.db_version,
                            self.name, rows=r.rows)
            self.served[r.rid] = resp
            self.transport.send(self.name, self.router, resp)

    def crash(self) -> None:
        """Fail-stop: pending draws are lost (never half-served), queued
        messages to this replica drop, and the transport tells the
        monitor (router) exactly once."""
        self.state = DOWN
        self.batcher.pending.clear()
        self.transport.crash(self.name)
