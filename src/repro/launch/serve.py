"""Serving drivers: (1) LM batched prefill + decode with a request queue
(continuous-batching-lite) on the reduced configs, and (2) a join-sampling
service built on ``repro.engine.QueryEngine`` — a micro-batching request
loop (DESIGN.md §10) over the multi-tenant pattern where many concurrent
requests (possibly over the same handful of query shapes) share one
compiled-plan cache, so only the first request of each shape pays GYO +
index build + XLA trace (DESIGN.md §7). Requests accumulate up to
``--max-batch`` or ``--max-wait-ms`` and flush as ONE ``sample_batch``
dispatch per query shape; the loop reports p50/p99 latency and draws/sec.
``UpdateRequest``s carry database deltas and interleave with draws: each
acts as a flush barrier, so in-flight batches always read one consistent
snapshot version and warm plans upgrade in place between flushes
(DESIGN.md §11).

The decode step function is the same one the dry-run lowers for the
decode_32k / long_500k cells (launch/dryrun.py `make_serve_step`); here it
runs eagerly on CPU to demonstrate correctness and the batching behavior.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import decode_step, encode, forward, init_cache, init_model, prefill


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new: int = 16
    out: Optional[List[int]] = None


def serve_batch(arch: str, requests: List[Request], seed: int = 0,
                greedy: bool = True) -> List[Request]:
    """Pad requests to one batch, prefill, then decode in lockstep."""
    cfg = configs.reduced(configs.get_config(arch))
    params = init_model(cfg, jax.random.key(seed))
    B = len(requests)
    plens = [len(r.prompt) for r in requests]
    S = max(plens)
    max_new = max(r.max_new for r in requests)
    total = S + max_new + 1
    toks = np.zeros((B, S), np.int32)
    for i, r in enumerate(requests):
        toks[i, : len(r.prompt)] = r.prompt

    mem = None
    if cfg.n_memory_tokens and not cfg.has_encoder:
        mem = jnp.zeros((B, cfg.n_memory_tokens, cfg.d_model), jnp.float32)
    if cfg.has_encoder:
        frames = jnp.zeros((B, cfg.n_memory_tokens, cfg.enc_d_model), jnp.float32)
        mem = encode(params, cfg, frames)

    _, cache = prefill(params, cfg, jnp.asarray(toks), total, mem)
    step = jax.jit(lambda c, t, cur: decode_step(params, cfg, c, t, cur))

    outs = [[] for _ in requests]
    cur_tok = jnp.asarray(toks[:, -1:])
    for t in range(max_new):
        logits, cache = step(cache, cur_tok, jnp.asarray(S + t, jnp.int32))
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        cur_tok = nxt[:, None]
        for i in range(B):
            if t < requests[i].max_new:
                outs[i].append(int(nxt[i]))
    for r, o in zip(requests, outs):
        r.out = o
    return requests


# ---------------------------------------------------------------------------
# Join-sampling service (engine-backed): micro-batching request loop
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class JoinSampleRequest:
    """One tenant request: draw an independent Poisson sample of ``query``."""

    query: "JoinQuery"
    seed: int = 0
    count: Optional[int] = None       # filled by the service
    overflow: Optional[bool] = None   # filled by the service
    latency_s: Optional[float] = None  # enqueue -> results routed back
    enqueued_s: Optional[float] = None  # set by MicroBatcher.submit
    db_version: Optional[int] = None  # snapshot version the draw was served from


@dataclasses.dataclass
class UpdateRequest:
    """One tenant update: advance the engine's snapshot by ``delta`` (a
    ``core.delta.DeltaBatch``). Serialized against draws by the micro-batch
    loop (DESIGN.md §11): draws enqueued before the update are flushed
    against the pre-delta snapshot first, so no in-flight batch ever mixes
    versions."""

    delta: object
    applied_version: Optional[int] = None  # post-apply db version
    latency_s: Optional[float] = None
    enqueued_s: Optional[float] = None


class MicroBatcher:
    """Micro-batching front end over ``QueryEngine.sample_batch``
    (DESIGN.md §10).

    Requests accumulate in an arrival-ordered queue and are flushed as
    batched dispatches when either trigger fires:

      * **size** — the queue reaches ``max_batch`` requests;
      * **deadline** — the oldest pending request has waited
        ``max_wait_ms`` (checked by ``poll()``, which the serving loop
        calls between arrivals).

    A flush groups pending requests by query fingerprint and issues ONE
    ``sample_batch`` dispatch per distinct shape — mixed-tenant queues
    share the engine's plan cache (one plan per shape, reused across
    flushes), and per-request results are routed back by lane index.
    ``clock`` is injectable so deadline behavior is unit-testable
    (``tests/test_serve_batcher.py``).

    ``UpdateRequest``s interleave with draws (DESIGN.md §11): an update
    acts as a barrier — pending draws flush first (reading the pre-delta
    snapshot), then the delta is applied via ``engine.apply_delta`` (warm
    cache entries upgrade in place, so the next flush pays no rebuild),
    and draws submitted afterwards read the new version. Every completed
    draw records the ``db_version`` it was served from.
    """

    def __init__(self, engine, *, max_batch: int = 64,
                 max_wait_ms: float = 2.0, mesh=None, axes=None,
                 clock=time.perf_counter):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.engine = engine
        self.max_batch = max_batch
        self.max_wait_ms = max_wait_ms
        self.mesh = mesh
        self.axes = axes
        self.clock = clock
        self.pending: List[JoinSampleRequest] = []
        self.flushes = 0
        self.dispatches = 0
        self.served = 0
        self.updates_applied = 0

    def submit(self, req) -> List:
        """Enqueue one request; returns completed requests (non-empty only
        when this arrival triggered work: a full batch for draws, or the
        flush-then-apply barrier for updates)."""
        req.enqueued_s = self.clock()
        if isinstance(req, UpdateRequest):
            return self._apply_update(req)
        self.pending.append(req)
        if len(self.pending) >= self.max_batch:
            return self.flush()
        return []

    def _apply_update(self, req: UpdateRequest) -> List:
        """The update barrier: drain pending draws on the current snapshot,
        then advance it. In-flight batches therefore always read ONE
        consistent version; later draws read the next."""
        done = self.flush()
        self.engine.apply_delta(req.delta)
        req.applied_version = self.engine.db.version
        req.latency_s = self.clock() - req.enqueued_s
        self.updates_applied += 1
        return done + [req]

    def poll(self) -> List[JoinSampleRequest]:
        """Deadline check: flush iff the oldest pending request has waited
        at least ``max_wait_ms``. Call between arrivals / when idle."""
        if self.pending and \
                (self.clock() - self.pending[0].enqueued_s) * 1e3 >= self.max_wait_ms:
            return self.flush()
        return []

    def flush(self) -> List[JoinSampleRequest]:
        """Dispatch everything pending now (one batched draw per distinct
        query fingerprint) and route results back to their requests."""
        from repro.engine import query_fingerprint

        batch, self.pending = self.pending, []
        if not batch:
            return []
        groups: Dict[str, List[JoinSampleRequest]] = {}
        for r in batch:
            groups.setdefault(query_fingerprint(r.query), []).append(r)
        version = getattr(self.engine.db, "version", 0)
        for reqs in groups.values():
            keys = jnp.stack([jax.random.key(r.seed) for r in reqs])
            smp = self.engine.sample_batch(reqs[0].query, keys,
                                           mesh=self.mesh, axes=self.axes)
            jax.block_until_ready(smp.count)
            done_t = self.clock()
            counts = np.asarray(smp.count)
            overflow = np.asarray(smp.overflow)
            for lane, r in enumerate(reqs):
                r.count = int(counts[lane])
                r.overflow = bool(overflow[lane])
                r.latency_s = done_t - r.enqueued_s
                r.db_version = version
            self.dispatches += 1
        self.flushes += 1
        self.served += len(batch)
        return batch


def serve_join_samples(engine, requests: List, mesh=None,
                       max_batch: int = 64, max_wait_ms: float = 2.0,
                       ) -> List:
    """Serve a request list through the micro-batcher (closed loop: submit
    everything, then drain). The list may interleave ``JoinSampleRequest``
    draws with ``UpdateRequest`` deltas; updates barrier the stream in
    arrival order (DESIGN.md §11). Kept as the library entry point the demo
    and tests share; results are routed back onto the request objects."""
    mb = MicroBatcher(engine, max_batch=max_batch, max_wait_ms=max_wait_ms,
                      mesh=mesh)
    done: List[JoinSampleRequest] = []
    for r in requests:
        done += mb.submit(r)
        done += mb.poll()
    done += mb.flush()  # drain the tail regardless of deadline
    return done


def _pctl(xs: List[float], q: float) -> float:
    ys = sorted(xs)
    return ys[min(len(ys) - 1, int(q * len(ys)))]


def _join_demo(n_requests: int, devices: int = 1, max_batch: int = 64,
               max_wait_ms: float = 2.0, updates: int = 0) -> None:
    from repro.core import Atom, JoinQuery
    from repro.core.delta import DeltaBatch
    from repro.data.pipeline import make_corpus_db
    from repro.engine import QueryEngine
    from repro.launch.mesh import force_host_devices

    mesh = None
    if devices > 1:
        n = force_host_devices(devices)
        mesh = jax.make_mesh((n,), ("data",))

    db = make_corpus_db(n_docs=20_000, n_clusters=64, seq_len=8, vocab=256)
    # Two tenant query shapes sharing one plan cache (same db, same engine).
    q_qual = JoinQuery((Atom.of("ClusterQuality", "clust", "p"),
                        Atom.of("Doc", "doc", "clust")), prob_var="p")
    q_flat = JoinQuery((Atom.of("ClusterQuality", "clust", "p"),),
                       prob_var="p")
    engine = QueryEngine(db)
    mb = MicroBatcher(engine, max_batch=max_batch, max_wait_ms=max_wait_ms,
                      mesh=mesh)
    rng = np.random.default_rng(0)
    reqs: List = [JoinSampleRequest(query=q_qual if i % 3 else q_flat, seed=i)
                  for i in range(n_requests)]
    if updates:
        # Shape-preserving doc churn (k in, k out) spread through the stream:
        # warm plans upgrade in place, zero rebuilds between flushes.
        n_docs = int(db.relations["Doc"].num_rows)
        every = max(1, n_requests // updates)
        for u in range(updates):
            delta = DeltaBatch.of(Doc={
                "insert": {"doc": rng.integers(0, n_docs, 4),
                           "clust": rng.integers(0, 64, 4)},
                "delete": rng.choice(n_docs, size=4, replace=False)})
            reqs.insert(min((u + 1) * every + u, len(reqs)),
                        UpdateRequest(delta))
    t0 = time.perf_counter()
    done: List = []
    for r in reqs:
        done += mb.submit(r)
        done += mb.poll()
    done += mb.flush()
    wall = time.perf_counter() - t0
    assert len(done) == n_requests + (updates or 0)
    draws = [r for r in done if isinstance(r, JoinSampleRequest)]
    lats = [r.latency_s * 1e3 for r in draws]
    st = engine.stats
    shards = ""
    if mesh is not None:  # the planner may degrade to the unsharded plan
        from repro.engine import ShardedPlan
        plan = engine.compile_sharded(q_qual, mesh)
        shards = (f"  shards={plan.num_shards}"
                  if isinstance(plan, ShardedPlan) else "  shards=1")
    print(f"[serve-join] {n_requests} requests in {mb.flushes} flushes "
          f"({mb.dispatches} dispatches){shards}  "
          f"max_batch={max_batch} max_wait={max_wait_ms}ms")
    print(f"  draws/sec={n_requests/wall:,.0f}  latency p50={_pctl(lats, .5):.1f}ms "
          f"p99={_pctl(lats, .99):.1f}ms  (incl. cold compile in early flushes)")
    print(f"  cache: shred_builds={st.shred_builds} shred_hits={st.shred_hits} "
          f"plan_hits={st.plan_hits} plan_misses={st.plan_misses}")
    if updates:
        print(f"  updates: applied={mb.updates_applied} "
              f"db_version={engine.db.version} "
              f"upgrades: shred={st.shred_upgrades} plan={st.plan_upgrades}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("lm", "join"), default="lm")
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--devices", type=int, default=1,
                    help="join mode: serve through the engine's sharded plan "
                         "on this many (virtual) host devices")
    ap.add_argument("--requests", type=int, default=256,
                    help="join mode: number of requests in the demo stream")
    ap.add_argument("--max-batch", type=int, default=64,
                    help="join mode: flush when this many requests are queued")
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="join mode: flush when the oldest pending request "
                         "has waited this long")
    ap.add_argument("--updates", type=int, default=0,
                    help="join mode: interleave this many shape-preserving "
                         "update requests into the demo stream")
    args = ap.parse_args()
    if args.mode == "join":
        _join_demo(args.requests, devices=args.devices,
                   max_batch=args.max_batch, max_wait_ms=args.max_wait_ms,
                   updates=args.updates)
        return
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=list(rng.integers(1, 200, rng.integers(4, 12))),
                    max_new=args.max_new) for _ in range(args.batch)]
    t0 = time.time()
    done = serve_batch(args.arch, reqs)
    dt = time.time() - t0
    ntok = sum(len(r.out) for r in done)
    print(f"[serve] {len(done)} requests, {ntok} tokens in {dt:.2f}s "
          f"({ntok/dt:.1f} tok/s batched)")
    for i, r in enumerate(done):
        print(f"  req{i}: prompt[:4]={r.prompt[:4]} -> out[:8]={r.out[:8]}")


if __name__ == "__main__":
    main()
