"""Serving drivers: (1) LM batched prefill + decode with a request queue
(continuous-batching-lite) on the reduced configs, and (2) a join-sampling
service built on ``repro.engine.QueryEngine`` — the multi-tenant pattern
where many concurrent requests (possibly over the same handful of query
shapes) share one compiled-plan cache, so only the first request of each
shape pays GYO + index build + XLA trace (DESIGN.md §7).

The decode step function is the same one the dry-run lowers for the
decode_32k / long_500k cells (launch/dryrun.py `make_serve_step`); here it
runs eagerly on CPU to demonstrate correctness and the batching behavior.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import decode_step, encode, forward, init_cache, init_model, prefill


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new: int = 16
    out: Optional[List[int]] = None


def serve_batch(arch: str, requests: List[Request], seed: int = 0,
                greedy: bool = True) -> List[Request]:
    """Pad requests to one batch, prefill, then decode in lockstep."""
    cfg = configs.reduced(configs.get_config(arch))
    params = init_model(cfg, jax.random.key(seed))
    B = len(requests)
    plens = [len(r.prompt) for r in requests]
    S = max(plens)
    max_new = max(r.max_new for r in requests)
    total = S + max_new + 1
    toks = np.zeros((B, S), np.int32)
    for i, r in enumerate(requests):
        toks[i, : len(r.prompt)] = r.prompt

    mem = None
    if cfg.n_memory_tokens and not cfg.has_encoder:
        mem = jnp.zeros((B, cfg.n_memory_tokens, cfg.d_model), jnp.float32)
    if cfg.has_encoder:
        frames = jnp.zeros((B, cfg.n_memory_tokens, cfg.enc_d_model), jnp.float32)
        mem = encode(params, cfg, frames)

    _, cache = prefill(params, cfg, jnp.asarray(toks), total, mem)
    step = jax.jit(lambda c, t, cur: decode_step(params, cfg, c, t, cur))

    outs = [[] for _ in requests]
    cur_tok = jnp.asarray(toks[:, -1:])
    for t in range(max_new):
        logits, cache = step(cache, cur_tok, jnp.asarray(S + t, jnp.int32))
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        cur_tok = nxt[:, None]
        for i in range(B):
            if t < requests[i].max_new:
                outs[i].append(int(nxt[i]))
    for r, o in zip(requests, outs):
        r.out = o
    return requests


# ---------------------------------------------------------------------------
# Join-sampling service (engine-backed)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class JoinSampleRequest:
    """One tenant request: draw an independent Poisson sample of ``query``."""

    query: "JoinQuery"
    seed: int = 0
    count: Optional[int] = None  # filled by the service
    latency_s: Optional[float] = None


def serve_join_samples(engine, requests: List[JoinSampleRequest], mesh=None
                       ) -> List[JoinSampleRequest]:
    """Serve a queue of Poisson-sample requests from one shared engine.

    Every request with a previously-seen query fingerprint is a warm hit:
    no GYO, no index rebuild, no retrace — a dict lookup plus one cached
    XLA dispatch. With ``mesh``, requests route through the engine's
    sharded plan (DESIGN.md §8) and the warm path likewise performs zero
    stacked-index rebuilds. The cold/warm latency gap printed per request
    is the compiled-plan cache doing its job
    (benchmarks/bench_engine_cache.py measures it in isolation).
    """
    for r in requests:
        t0 = time.perf_counter()
        s = engine.sample(r.query, jax.random.key(r.seed), mesh=mesh)
        jax.block_until_ready(s.positions)
        r.latency_s = time.perf_counter() - t0
        r.count = int(s.count)
    return requests


def _join_demo(n_requests: int, devices: int = 1) -> None:
    from repro.core import Atom, JoinQuery
    from repro.data.pipeline import make_corpus_db
    from repro.engine import QueryEngine
    from repro.launch.mesh import force_host_devices

    mesh = None
    if devices > 1:
        n = force_host_devices(devices)
        mesh = jax.make_mesh((n,), ("data",))

    db = make_corpus_db(n_docs=20_000, n_clusters=64, seq_len=8, vocab=256)
    q = JoinQuery((Atom.of("ClusterQuality", "clust", "p"),
                   Atom.of("Doc", "doc", "clust")), prob_var="p")
    engine = QueryEngine(db)
    reqs = [JoinSampleRequest(query=q, seed=i) for i in range(n_requests)]
    done = serve_join_samples(engine, reqs, mesh=mesh)
    for i, r in enumerate(done):
        tag = "cold" if i == 0 else "warm"
        print(f"  req{i} ({tag}): k={r.count} in {r.latency_s*1e3:.1f} ms")
    st = engine.stats
    shards = ""
    if mesh is not None:  # the planner may degrade to the unsharded plan
        from repro.engine import ShardedPlan
        plan = engine.compile_sharded(q, mesh)
        shards = (f"  shards={plan.num_shards}"
                  if isinstance(plan, ShardedPlan) else "  shards=1")
    print(f"[serve-join] {len(done)} requests{shards}  "
          f"shred_builds={st.shred_builds} shred_hits={st.shred_hits} "
          f"plan_hits={st.plan_hits} plan_misses={st.plan_misses}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("lm", "join"), default="lm")
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--devices", type=int, default=1,
                    help="join mode: serve through the engine's sharded plan "
                         "on this many (virtual) host devices")
    args = ap.parse_args()
    if args.mode == "join":
        _join_demo(args.batch, devices=args.devices)
        return
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=list(rng.integers(1, 200, rng.integers(4, 12))),
                    max_new=args.max_new) for _ in range(args.batch)]
    t0 = time.time()
    done = serve_batch(args.arch, reqs)
    dt = time.time() - t0
    ntok = sum(len(r.out) for r in done)
    print(f"[serve] {len(done)} requests, {ntok} tokens in {dt:.2f}s "
          f"({ntok/dt:.1f} tok/s batched)")
    for i, r in enumerate(done):
        print(f"  req{i}: prompt[:4]={r.prompt[:4]} -> out[:8]={r.out[:8]}")


if __name__ == "__main__":
    main()
