"""Serving demos: (1) LM batched prefill + decode with a request queue
(continuous-batching-lite) on the reduced configs, and (2) a join-sampling
service — single-engine micro-batching (DESIGN.md §10) or, with
``--replicas N``, a replicated fleet (DESIGN.md §12) behind a router with
log-shipped deltas and an injected replica crash.

The serving *library* lives in ``repro.launch.fleet`` (router, replica,
transport, log, micro-batcher); this module is a thin demo over it and
re-exports the single-engine names (``MicroBatcher`` & co.) so existing
imports keep working.

The decode step function is the same one the dry-run lowers for the
decode_32k / long_500k cells (launch/dryrun.py `make_serve_step`); here it
runs eagerly on CPU to demonstrate correctness and the batching behavior.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.metrics import percentile
from repro.launch.fleet import (  # noqa: F401  (re-exported public API)
    JoinSampleRequest, MicroBatcher, Rejected, UpdateRequest,
    serve_fleet, serve_join_samples,
)
from repro.models import decode_step, encode, forward, init_cache, init_model, prefill


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new: int = 16
    out: Optional[List[int]] = None


def serve_batch(arch: str, requests: List[Request], seed: int = 0,
                greedy: bool = True) -> List[Request]:
    """Pad requests to one batch, prefill, then decode in lockstep."""
    cfg = configs.reduced(configs.get_config(arch))
    params = init_model(cfg, jax.random.key(seed))
    B = len(requests)
    plens = [len(r.prompt) for r in requests]
    S = max(plens)
    max_new = max(r.max_new for r in requests)
    total = S + max_new + 1
    toks = np.zeros((B, S), np.int32)
    for i, r in enumerate(requests):
        toks[i, : len(r.prompt)] = r.prompt

    mem = None
    if cfg.n_memory_tokens and not cfg.has_encoder:
        mem = jnp.zeros((B, cfg.n_memory_tokens, cfg.d_model), jnp.float32)
    if cfg.has_encoder:
        frames = jnp.zeros((B, cfg.n_memory_tokens, cfg.enc_d_model), jnp.float32)
        mem = encode(params, cfg, frames)

    _, cache = prefill(params, cfg, jnp.asarray(toks), total, mem)
    step = jax.jit(lambda c, t, cur: decode_step(params, cfg, c, t, cur))

    outs = [[] for _ in requests]
    cur_tok = jnp.asarray(toks[:, -1:])
    for t in range(max_new):
        logits, cache = step(cache, cur_tok, jnp.asarray(S + t, jnp.int32))
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        cur_tok = nxt[:, None]
        for i in range(B):
            if t < requests[i].max_new:
                outs[i].append(int(nxt[i]))
    for r, o in zip(requests, outs):
        r.out = o
    return requests


# ---------------------------------------------------------------------------
# Join-sampling demos (engine-backed): single-engine loop and fleet
# ---------------------------------------------------------------------------

def _demo_stream(db, n_requests: int, updates: int):
    """The shared demo workload: two tenant query shapes + optional
    shape-preserving doc churn spread through the stream."""
    from repro.core import Atom, JoinQuery
    from repro.core.delta import DeltaBatch

    q_qual = JoinQuery((Atom.of("ClusterQuality", "clust", "p"),
                        Atom.of("Doc", "doc", "clust")), prob_var="p")
    q_flat = JoinQuery((Atom.of("ClusterQuality", "clust", "p"),),
                       prob_var="p")
    rng = np.random.default_rng(0)
    reqs: List = [JoinSampleRequest(query=q_qual if i % 3 else q_flat, seed=i)
                  for i in range(n_requests)]
    if updates:
        n_docs = int(db.relations["Doc"].num_rows)
        every = max(1, n_requests // updates)
        for u in range(updates):
            delta = DeltaBatch.of(Doc={
                "insert": {"doc": rng.integers(0, n_docs, 4),
                           "clust": rng.integers(0, 64, 4)},
                "delete": rng.choice(n_docs, size=4, replace=False)})
            reqs.insert(min((u + 1) * every + u, len(reqs)),
                        UpdateRequest(delta))
    return reqs, (q_qual, q_flat)


def _join_demo(n_requests: int, devices: int = 1, max_batch: int = 64,
               max_wait_ms: float = 2.0, updates: int = 0) -> None:
    from repro.data.pipeline import make_corpus_db
    from repro.engine import QueryEngine
    from repro.launch.mesh import force_host_devices

    mesh = None
    if devices > 1:
        n = force_host_devices(devices)
        mesh = jax.make_mesh((n,), ("data",))

    db = make_corpus_db(n_docs=20_000, n_clusters=64, seq_len=8, vocab=256)
    reqs, (q_qual, _) = _demo_stream(db, n_requests, updates)
    engine = QueryEngine(db)
    mb = MicroBatcher(engine, max_batch=max_batch, max_wait_ms=max_wait_ms,
                      mesh=mesh)
    t0 = time.perf_counter()
    done: List = []
    for r in reqs:
        done += mb.submit(r)
        done += mb.poll()
    done += mb.flush()
    wall = time.perf_counter() - t0
    assert len(done) == n_requests + (updates or 0)
    draws = [r for r in done if isinstance(r, JoinSampleRequest)]
    lats = [r.latency_s * 1e3 for r in draws]
    st = engine.stats
    shards = ""
    if mesh is not None:  # the planner may degrade to the unsharded plan
        from repro.engine import ShardedPlan
        plan = engine.compile_sharded(q_qual, mesh)
        shards = (f"  shards={plan.num_shards}"
                  if isinstance(plan, ShardedPlan) else "  shards=1")
    print(f"[serve-join] {n_requests} requests in {mb.flushes} flushes "
          f"({mb.dispatches} dispatches){shards}  "
          f"max_batch={max_batch} max_wait={max_wait_ms}ms")
    print(f"  draws/sec={n_requests/wall:,.0f}  "
          f"latency p50={percentile(lats, .5):.1f}ms "
          f"p99={percentile(lats, .99):.1f}ms  "
          f"(incl. cold compile in early flushes)")
    print(f"  cache: shred_builds={st.shred_builds} shred_hits={st.shred_hits} "
          f"plan_hits={st.plan_hits} plan_misses={st.plan_misses}")
    if updates:
        print(f"  updates: applied={mb.updates_applied} "
              f"db_version={engine.db.version} "
              f"upgrades: shred={st.shred_upgrades} plan={st.plan_upgrades}")


def _fleet_demo(n_requests: int, replicas: int, max_batch: int = 64,
                max_wait_ms: float = 2.0, updates: int = 0,
                crash: bool = True) -> None:
    """The replicated fleet demo (DESIGN.md §12): serve the same stream
    through ``--replicas N`` engine replicas, fail-stop one replica
    mid-stream, and verify the results bit-identical to the single-engine
    micro-batcher baseline per (seed, version)."""
    from repro.data.pipeline import make_corpus_db
    from repro.engine import QueryEngine

    db = make_corpus_db(n_docs=20_000, n_clusters=64, seq_len=8, vocab=256)
    reqs, _ = _demo_stream(db, n_requests, updates)
    crash_at = n_requests // 2 if crash and replicas > 1 else None

    t0 = time.perf_counter()
    done, fleet = serve_fleet(
        db, reqs, replicas=replicas, max_batch=max_batch,
        max_wait_ms=max_wait_ms, clock="real", retry_timeout_s=30.0,
        crash_at=crash_at, crash_replica=replicas - 1)
    wall = time.perf_counter() - t0

    draws = [r for r in done if isinstance(r, JoinSampleRequest)]
    rejected = [r for r in done if isinstance(r, Rejected)]
    assert len(draws) + len(rejected) == n_requests, \
        f"lost requests: {len(draws)}+{len(rejected)} != {n_requests}"
    assert len({id(r) for r in draws}) == len(draws), "request served twice"

    # Bit-identical to the single-engine baseline, per (seed, version).
    baseline = {}
    for r in serve_join_samples(QueryEngine(db),
                                _demo_stream(db, n_requests, updates)[0],
                                max_batch=max_batch):
        if isinstance(r, JoinSampleRequest):
            baseline[(r.seed, r.db_version)] = (r.count, r.overflow)
    mismatches = [r.seed for r in draws
                  if baseline.get((r.seed, r.db_version))
                  != (r.count, r.overflow)]
    assert not mismatches, f"fleet != single-engine for seeds {mismatches}"

    lats = [r.latency_s * 1e3 for r in draws]
    st = fleet.stats()
    rt = fleet.router
    crashed = [r.name for r in fleet.replicas
               if r.state == "down" and r.name not in rt.drained]
    print(f"[serve-fleet] {n_requests} requests over {replicas} replicas  "
          f"max_batch={max_batch} max_wait={max_wait_ms}ms  "
          f"crash_injected={crash_at is not None}")
    print(f"  draws/sec={len(draws)/wall:,.0f}  "
          f"latency p50={percentile(lats, .5):.1f}ms "
          f"p99={percentile(lats, .99):.1f}ms  "
          f"rejected={len(rejected)} retries={rt.retries} "
          f"crashed_replicas={len(crashed)}")
    print(f"  fleet cache (aggregated): shred_builds={st.shred_builds} "
          f"plan_misses={st.plan_misses} plan_hits={st.plan_hits} "
          f"upgrades: shred={st.shred_upgrades} plan={st.plan_upgrades}")
    print(f"  log: head_lsn={fleet.log.head} "
          f"committed_version={fleet.db_version}  "
          f"results bit-identical to single-engine baseline: OK")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("lm", "join"), default="lm")
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--devices", type=int, default=1,
                    help="join mode: serve through the engine's sharded plan "
                         "on this many (virtual) host devices")
    ap.add_argument("--replicas", type=int, default=1,
                    help="join mode: serve through a replicated fleet of "
                         "this many engine replicas (DESIGN.md §12)")
    ap.add_argument("--requests", type=int, default=256,
                    help="join mode: number of requests in the demo stream")
    ap.add_argument("--max-batch", type=int, default=64,
                    help="join mode: flush when this many requests are queued")
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="join mode: flush when the oldest pending request "
                         "has waited this long")
    ap.add_argument("--updates", type=int, default=0,
                    help="join mode: interleave this many shape-preserving "
                         "update requests into the demo stream")
    ap.add_argument("--no-crash", action="store_true",
                    help="fleet mode: skip the injected mid-stream replica "
                         "crash")
    args = ap.parse_args()
    if args.mode == "join":
        if args.replicas > 1:
            _fleet_demo(args.requests, args.replicas,
                        max_batch=args.max_batch,
                        max_wait_ms=args.max_wait_ms, updates=args.updates,
                        crash=not args.no_crash)
        else:
            _join_demo(args.requests, devices=args.devices,
                       max_batch=args.max_batch,
                       max_wait_ms=args.max_wait_ms, updates=args.updates)
        return
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=list(rng.integers(1, 200, rng.integers(4, 12))),
                    max_new=args.max_new) for _ in range(args.batch)]
    t0 = time.time()
    done = serve_batch(args.arch, reqs)
    dt = time.time() - t0
    ntok = sum(len(r.out) for r in done)
    print(f"[serve] {len(done)} requests, {ntok} tokens in {dt:.2f}s "
          f"({ntok/dt:.1f} tok/s batched)")
    for i, r in enumerate(done):
        print(f"  req{i}: prompt[:4]={r.prompt[:4]} -> out[:8]={r.out[:8]}")


if __name__ == "__main__":
    main()
