"""Latency metrics shared by the serve loop, the fleet router, and the
serve benchmark (DESIGN.md §12).

``percentile`` is the *nearest-rank* estimator: the q-th percentile of a
sample of N values is the ``ceil(q * N)``-th smallest (1-indexed), clamped
to the sample. This is the standard order-statistic definition — p50 of
``[1, 2, 3, 4]`` is 2 (the 2nd smallest), and p99 of a short list is its
maximum only when ``ceil(0.99 * N) == N``. The previous inline helper in
``launch/serve.py`` used ``int(q * len(ys))`` as a 0-based index, which is
biased one rank high: p50 of ``[1, 2, 3, 4]`` returned the 3rd element and
p99 systematically overshot on short lists.
"""
from __future__ import annotations

import math
from typing import Dict, Sequence

__all__ = ["percentile", "latency_summary"]


def percentile(xs: Sequence[float], q: float) -> float:
    """Nearest-rank q-th percentile of ``xs`` (q in [0, 1])."""
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"q must be in [0, 1], got {q}")
    if len(xs) == 0:
        raise ValueError("percentile of an empty sequence")
    ys = sorted(xs)
    rank = max(1, math.ceil(q * len(ys)))  # 1-indexed nearest rank
    return ys[rank - 1]


def latency_summary(latencies_s: Sequence[float]) -> Dict[str, float]:
    """p50/p99/max of a latency sample, in milliseconds."""
    if len(latencies_s) == 0:
        return {"p50_ms": 0.0, "p99_ms": 0.0, "max_ms": 0.0}
    return {
        "p50_ms": percentile(latencies_s, 0.50) * 1e3,
        "p99_ms": percentile(latencies_s, 0.99) * 1e3,
        "max_ms": max(latencies_s) * 1e3,
    }
