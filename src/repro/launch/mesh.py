"""Production mesh construction (assignment MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5 exposes explicit axis types; older releases don't.
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - version-dependent
    AxisType = None


def _make_mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) = ("data", "model") single pod (256 v5e chips), or
    (2, 16, 16) = ("pod", "data", "model") for 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever devices exist, data-parallel (CPU tests / tiny training)."""
    n = len(jax.devices())
    assert n % model == 0
    return _make_mesh((n // model, model), ("data", "model"))


def force_host_devices(n: int) -> int:
    """Ask XLA for ``n`` virtual host (CPU) devices; returns the count
    actually available. Platform/env setup is centralized in
    ``repro.config`` (DESIGN.md §14) — this re-export keeps the historical
    launch-layer call sites working."""
    from repro import config

    return config.force_host_devices(n)


def batch_axes(mesh) -> tuple:
    """The data-parallel axes present in this mesh ((pod,)data)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
