"""Production mesh construction (assignment MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5 exposes explicit axis types; older releases don't.
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - version-dependent
    AxisType = None


def _make_mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) = ("data", "model") single pod (256 v5e chips), or
    (2, 16, 16) = ("pod", "data", "model") for 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever devices exist, data-parallel (CPU tests / tiny training)."""
    n = len(jax.devices())
    assert n % model == 0
    return _make_mesh((n // model, model), ("data", "model"))


def force_host_devices(n: int) -> int:
    """Ask XLA for ``n`` virtual host (CPU) devices; returns the count
    actually available. Only effective before the backend initializes —
    appends ``--xla_force_host_platform_device_count`` to ``XLA_FLAGS``
    and reports (rather than raises) when the backend beat us to it, so
    callers degrade to the real device count."""
    import os
    import sys

    if n > 1:
        flags = os.environ.get("XLA_FLAGS", "")
        flag = f"--xla_force_host_platform_device_count={n}"
        if "--xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = f"{flags} {flag}".strip()
    got = len(jax.devices())
    if got < n:
        print(f"[mesh] requested {n} host devices, backend has {got} "
              f"(already initialized, or XLA_FLAGS pre-set); using {got}",
              file=sys.stderr)
    return got


def batch_axes(mesh) -> tuple:
    """The data-parallel axes present in this mesh ((pod,)data)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
