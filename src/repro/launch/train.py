"""Training driver: fault-tolerant loop with checkpoint/resume, straggler
watchdog, elastic re-meshing, and the Poisson-join data pipeline.

On this CPU container it runs the *reduced* configs end-to-end (the
examples/ scripts call into it); on TPU pods the same loop runs the full
configs — the mesh, shardings and step function are identical to the
dry-run's (launch/dryrun.py lowers exactly `make_train_step`).

Fault-tolerance story (DESIGN.md §6):
  * checkpoint manager: atomic + checksummed + keep-N + async; auto-resume
    from the newest valid step — node failure = restart-and-resume;
  * straggler watchdog: EWMA of step wall-time; a step exceeding
    ``straggler_factor`` x EWMA logs a straggler event (on real fleets this
    feeds the controller that re-schedules the slow host; here it is
    observable behavior under test);
  * elastic re-meshing: the data-parallel degree is re-derived from the
    live device count at (re)start; because batches are deterministic in
    (seed, step) and the global batch is fixed, scaling dp up/down between
    restarts changes only per-device microbatching, not the sample stream;
  * optional int8 gradient compression with error feedback for the DP
    all-reduce (parallel/compress.py) — opt-in flag.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.data import PoissonJoinSource, SyntheticLMSource, make_corpus_db
from repro.launch.mesh import batch_axes, make_host_mesh
from repro.models import layers, transformer
from repro.optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine


@dataclasses.dataclass
class TrainConfig:
    arch: str = "smollm_135m"
    reduced: bool = True
    steps: int = 200
    batch: int = 8
    seq_len: int = 64
    lr: float = 3e-3
    warmup: int = 20
    seed: int = 0
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep_n: int = 3
    straggler_factor: float = 3.0
    data: str = "poisson_join"  # or "synthetic"
    log_every: int = 10
    # Live-corpus schedule: ``(step, DeltaBatch)`` events applied by the
    # data source at step-aligned version barriers (DESIGN.md §13). The
    # schedule is part of the run's identity: resume replays it from the
    # base snapshot, and the checkpoint records the data version so a
    # mismatched schedule fails loudly instead of drifting silently.
    deltas: tuple = ()


def _train_step(cfg, opt_cfg, params, opt_state, batch, step):
    (loss, _), grads = jax.value_and_grad(
        transformer.loss_fn, has_aux=True)(params, cfg, batch)
    lr_scale = warmup_cosine(step, warmup=20, total=100000)
    params, opt_state, metrics = adamw_update(opt_cfg, params, grads, opt_state,
                                              lr_scale)
    metrics["loss"] = loss
    return params, opt_state, metrics


def train(tc: TrainConfig, hooks: Optional[Dict[str, Callable]] = None) -> Dict[str, Any]:
    hooks = hooks or {}
    cfg = configs.get_config(tc.arch)
    if tc.reduced:
        cfg = configs.reduced(cfg)
        cfg = dataclasses.replace(cfg, attn_chunk=max(tc.seq_len // 2, 16))

    # --- elastic mesh: dp degree derived from live devices -----------------
    mesh = make_host_mesh()
    multi = int(np.prod(list(mesh.shape.values()))) > 1
    layers.set_batch_axes(
        batch_axes(mesh) if multi and tc.batch % mesh.shape["data"] == 0 else ())

    key = jax.random.key(tc.seed)
    params = transformer.init_model(cfg, key)
    opt_cfg = AdamWConfig(lr=tc.lr, moment_dtype="float32")
    opt_state = adamw_init(opt_cfg, params)

    # --- data ---------------------------------------------------------------
    if tc.data == "poisson_join":
        db = make_corpus_db(n_docs=512, n_clusters=16, seq_len=tc.seq_len + 1,
                            vocab=cfg.vocab, seed=tc.seed)
        source = PoissonJoinSource(db, tc.seq_len + 1, tc.batch, seed=tc.seed,
                                   deltas=tc.deltas)
    else:
        source = SyntheticLMSource(cfg.vocab, tc.seq_len, tc.batch, seed=tc.seed)

    # --- resume ---------------------------------------------------------------
    ckpt = CheckpointManager(tc.ckpt_dir, keep_n=tc.keep_n)
    state_tpl = {"params": params, "opt": opt_state,
                 "data_version": np.zeros((), np.int64)}
    start, restored = ckpt.restore(state_tpl)
    if start is not None:
        params, opt_state = restored["params"], restored["opt"]
        if hasattr(source, "version_at") and start > 0:
            want = source.version_at(start - 1)
            got = int(restored["data_version"])
            if got != want:
                raise RuntimeError(
                    f"checkpoint data_version={got} but the delta schedule "
                    f"puts step {start - 1} at version {want}; resume must "
                    f"replay the run's exact schedule (DESIGN.md §13)")
        print(f"[train] resumed from step {start}")
    start = (start or 0)

    step_fn = jax.jit(partial(_train_step, cfg, opt_cfg))

    # --- loop with straggler watchdog ----------------------------------------
    ewma = None
    losses = []
    straggler_events = []
    doc_ids = []        # per-step sampled doc ids (poisson_join source)
    data_versions = []  # per-step snapshot version each batch was drawn at
    data_version = 0
    for step in range(start, tc.steps):
        batch = source.batch_at(step)
        batch.pop("sampled_k", None)
        step_docs = batch.pop("doc_ids", None)
        data_version = batch.pop("db_version", data_version)
        if step_docs is not None:
            doc_ids.append(np.asarray(step_docs))
        data_versions.append(data_version)
        t0 = time.time()
        with mesh:
            params, opt_state, metrics = step_fn(params, opt_state, batch,
                                                 jnp.asarray(step, jnp.int32))
        loss = float(metrics["loss"])
        dt = time.time() - t0
        if ewma is None:
            ewma = dt
        if dt > tc.straggler_factor * ewma and step > start + 3:
            straggler_events.append((step, dt, ewma))
            print(f"[train] STRAGGLER step {step}: {dt:.3f}s vs EWMA {ewma:.3f}s")
            if "on_straggler" in hooks:
                hooks["on_straggler"](step, dt, ewma)
        ewma = 0.9 * ewma + 0.1 * dt
        losses.append(loss)
        if step % tc.log_every == 0:
            print(f"[train] step {step} loss {loss:.4f} ({dt*1e3:.0f} ms)")
        if "on_step" in hooks:
            hooks["on_step"](step, loss)
        if (step + 1) % tc.ckpt_every == 0 or step + 1 == tc.steps:
            ckpt.save(step + 1, {"params": params, "opt": opt_state,
                                 "data_version": np.asarray(data_version,
                                                            np.int64)})
    ckpt.wait()
    return {"losses": losses, "params": params, "straggler_events": straggler_events,
            "doc_ids": doc_ids, "data_versions": data_versions,
            "final_step": tc.steps}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--data", default="poisson_join")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--full", action="store_true", help="full (non-reduced) config")
    args = ap.parse_args()
    out = train(TrainConfig(arch=args.arch, steps=args.steps, batch=args.batch,
                            seq_len=args.seq_len, data=args.data,
                            ckpt_dir=args.ckpt_dir, reduced=not args.full))
    print(f"[train] done. loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}")


if __name__ == "__main__":
    main()
