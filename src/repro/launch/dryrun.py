import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede every other import (jax locks the device count on first
# init). Do NOT replicate this anywhere global — tests/benches see 1 device.

"""Multi-pod dry-run (assignment deliverable e).

For every (architecture x input shape) cell and both production meshes,
lower + compile the correct step function (train_step / prefill /
serve_step), print memory_analysis() (proves it fits) and cost_analysis()
(FLOPs/bytes for §Roofline), and parse collective bytes from the compiled
HLO. Results land in experiments/dryrun/*.json for benchmarks/roofline.py.

Usage:
    python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod-only | --single-pod-only]
"""
import argparse
import json
import re
import sys
import time
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch.hlo_cost import HloCost
from repro.launch.mesh import batch_axes, make_production_mesh
from repro.models import config as mcfg
from repro.models import layers, transformer
from repro.optim import AdamWConfig, adamw_init, adamw_update

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# v5e hardware constants (per chip) — §Roofline
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s/link


# ---------------------------------------------------------------------------
# sharding construction
# ---------------------------------------------------------------------------

def _dp_spec(mesh, size: int):
    dp = batch_axes(mesh)
    total = int(np.prod([mesh.shape[a] for a in dp]))
    return P(dp) if dp and size % total == 0 else P(None)


def batch_shardings(mesh, specs):
    dpB = {k: v.shape[0] for k, v in specs.items() if v.ndim >= 1}

    def spec_for(k, v):
        if v.ndim == 0:
            return P()
        lead = _dp_spec(mesh, v.shape[0])
        return P(*(tuple(lead) + (None,) * (v.ndim - 1)))

    return {k: NamedSharding(mesh, spec_for(k, v)) for k, v in specs.items()}


def cache_shardings(mesh, cfg, cache_shapes):
    """Decode-cache sharding: batch on (pod, data); the model axis goes on
    KV heads when divisible, else on head_dim (DUS-safe; see DESIGN.md §6)."""
    m = mesh.shape["model"]

    def leaf(path, v):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        dims = [None] * v.ndim
        # leading dims: (repeats, batch, ...)
        if v.ndim >= 2:
            dp = _dp_spec(mesh, v.shape[1])
            dims[1] = tuple(dp)[0] if tuple(dp) != (None,) else None
        if name in ("k", "v", "ck", "cv") and v.ndim == 5:
            if v.shape[3] % m == 0:
                dims[3] = "model"            # KV heads
            elif v.shape[2] % m == 0 and v.shape[2] >= 4096:
                dims[2] = "model"            # cache sequence (flash-decoding
                # parallelism: per-shard partial softmax, scalar psums —
                # replaces the 537MB/layer hd-sharded score psums, §Perf H4)
            elif v.shape[4] % m == 0:
                dims[4] = "model"            # head_dim
        elif name == "state" and v.ndim >= 4:
            if v.shape[2] % m == 0:
                dims[2] = "model"            # state heads
        elif name in ("conv",) and v.ndim == 4 and v.shape[3] % m == 0:
            dims[3] = "model"
        elif name in ("shift_t", "shift_c") and v.ndim == 3 and v.shape[2] % m == 0:
            dims[2] = "model"
        return NamedSharding(mesh, P(*dims))

    return jax.tree_util.tree_map_with_path(leaf, cache_shapes)


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def make_train_step(cfg, opt_cfg):
    """Full training step; with cfg.grad_accum > 1 the global batch is split
    into sequential microbatches (activation memory / accum, the other half
    of what fits llama3-405b on a pod — see DESIGN.md §5/§6)."""
    accum = max(cfg.grad_accum, 1)

    def grad_of(params, batch):
        (loss, _), grads = jax.value_and_grad(
            transformer.loss_fn, has_aux=True)(params, cfg, batch)
        return loss, grads

    def train_step(params, opt_state, batch):
        pspecs = layers.param_specs(params)
        shard = lambda t: jax.lax.with_sharding_constraint(t, pspecs)
        if accum == 1:
            loss, grads = grad_of(params, batch)
            grads = shard(grads)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch)
            gdt = jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32

            def body(acc, mb):
                loss_acc, g_acc = acc
                loss, g = grad_of(params, mb)
                # keep the accumulator ZeRO-sharded: per-microbatch gradients
                # reduce-scatter into it instead of replicating over 'data'
                g_acc = shard(jax.tree.map(lambda a, b: a + b.astype(gdt),
                                           g_acc, shard(g)))
                return (loss_acc + loss, g_acc), None

            zeros = shard(jax.tree.map(lambda p: jnp.zeros(p.shape, gdt), params))
            (loss, grads), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), zeros),
                                            micro)
            loss = loss / accum
            grads = jax.tree.map(lambda g: g / accum, grads)
        params, opt_state, metrics = adamw_update(opt_cfg, params, grads, opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_prefill(cfg):
    def prefill_step(params, batch):
        memory = batch.get("memory")
        if cfg.has_encoder:
            memory = transformer.encode(params, cfg, batch["frames"])
        logits, _ = transformer.forward(params, cfg, batch["tokens"], memory)
        return logits[:, -1]

    return prefill_step


def make_serve_step(cfg):
    def serve_step(params, cache, tokens, cur):
        return transformer.decode_step(params, cfg, cache, tokens, cur)

    return serve_step


# ---------------------------------------------------------------------------
# HLO collective accounting
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[([\d,]*)\]")
_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4, "s16": 2,
          "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1}
_COLL = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
         "collective-permute")


def _shape_bytes(sig: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(sig):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _BYTES.get(dt[:4].rstrip("["), _BYTES.get(dt, 4))
    return total


def collective_bytes(hlo_text: str):
    """Sum output-shape bytes of every collective op, by type. all-reduce is
    counted 2x (reduce-scatter + all-gather equivalent ring traffic)."""
    out = {k: 0 for k in _COLL}
    counts = {k: 0 for k in _COLL}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"(?:ROOT )?[%\w.\-]+ = (.*?) (all-reduce|all-gather|"
                     r"reduce-scatter|all-to-all|collective-permute)", s)
        if not m:
            continue
        sig, op = m.group(1), m.group(2)
        b = _shape_bytes(sig)
        if op == "all-reduce":
            b *= 2
        out[op] += b
        counts[op] += 1
    return out, counts


# ---------------------------------------------------------------------------
# one cell
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape: str, multi_pod: bool, cache_mode: str = "auto",
             verbose: bool = True):
    cfg = configs.get_config(arch)
    skip = configs.shape_applicable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch}__{shape}__{mesh_name}"
    if skip:
        rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
               "skipped": skip}
        _write(tag, rec)
        if verbose:
            print(f"[dryrun] SKIP {tag}: {skip}")
        return rec

    sp = configs.SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    layers.set_batch_axes(batch_axes(mesh) if sp.batch >= 32 else ())
    layers.set_moe_ep(getattr(cfg, "moe_ep", False))
    n_chips = int(np.prod(list(mesh.shape.values())))
    specs = configs.input_specs(cfg, shape)
    # eval_shape of init to get the param ShapeDtypeStructs without allocating
    param_shapes = jax.eval_shape(partial(transformer.init_model, cfg),
                                  jax.random.key(0))
    pspecs = layers.sanitize_pspecs(layers.param_specs(param_shapes),
                                    param_shapes, mesh)
    param_shards = jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P))

    t0 = time.time()
    with mesh:
        if sp.kind == "train":
            opt_cfg = AdamWConfig(
                moment_dtype="bfloat16" if cfg.param_dtype == "bfloat16" else "float32",
                factored=cfg.opt_factored)
            opt_shapes = jax.eval_shape(partial(adamw_init, opt_cfg), param_shapes)

            def vshard(shape_struct, spec):
                if cfg.opt_factored and shape_struct.ndim >= 2:
                    sp = list(spec) + [None] * (shape_struct.ndim - len(spec))
                    return {"vr": NamedSharding(mesh, P(*sp[:-1])),
                            "vc": NamedSharding(mesh, P(*(sp[:-2] + sp[-1:])))}
                return NamedSharding(mesh, spec)

            opt_shards = {
                "step": NamedSharding(mesh, P()),
                "m": param_shards,
                "v": jax.tree.map(vshard, param_shapes, pspecs),
            }
            bshard = batch_shardings(mesh, specs)
            fn = jax.jit(make_train_step(cfg, opt_cfg),
                         in_shardings=(param_shards, opt_shards, bshard),
                         donate_argnums=(0, 1))
            lowered = fn.lower(param_shapes, opt_shapes, specs)
        elif sp.kind == "prefill":
            bshard = batch_shardings(mesh, specs)
            fn = jax.jit(make_prefill(cfg), in_shardings=(param_shards, bshard))
            lowered = fn.lower(param_shapes, specs)
        else:  # decode
            cache_shapes = jax.eval_shape(
                partial(transformer.init_cache, cfg, sp.batch, sp.seq,
                        cfg.n_memory_tokens))
            cshard = cache_shardings(mesh, cfg, cache_shapes)
            tshard = NamedSharding(mesh, P(*(tuple(_dp_spec(mesh, sp.batch)) + (None,))))
            fn = jax.jit(make_serve_step(cfg),
                         in_shardings=(param_shards, cshard, tshard,
                                       NamedSharding(mesh, P())),
                         donate_argnums=(1,))
            lowered = fn.lower(param_shapes, cache_shapes, specs["tokens"],
                               specs["cur"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()

    # trip-count-aware accounting (cost_analysis counts loop bodies once —
    # see hlo_cost.py); XLA's raw numbers are kept alongside for reference.
    hc = HloCost(hlo).entry_cost()
    flops = float(hc["flops"])
    bytes_acc = float(hc["bytes"])
    coll = {k: float(hc[k]) for k in
            ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")}
    coll_total = float(hc["collective_bytes"])

    mem_rec = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
    }

    # roofline terms (per §ROOFLINE): all quantities are per-partition
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_coll = coll_total / ICI_BW
    # assignment formula: MODEL_FLOPS = 6*N*D (N_active for MoE), D = tokens
    # this step processes. (For inference kinds 6ND overstates by ~3x vs the
    # 2ND forward cost — noted in EXPERIMENTS.md §Roofline.)
    ntok = sp.batch * (1 if sp.kind == "decode" else sp.seq)
    model_flops = 6 * cfg.active_param_count() * ntok

    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "chips": n_chips,
        "kind": sp.kind, "seq": sp.seq, "batch": sp.batch,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem_rec,
        "flops_per_device": flops,
        "bytes_per_device": bytes_acc,
        "xla_flops_per_device_bodies_once": float(cost.get("flops", 0.0)),
        "xla_bytes_per_device_bodies_once": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": coll,
        "collective_total_bytes": coll_total,
        "roofline": {
            "compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll,
            "dominant": max(
                [("compute", t_compute), ("memory", t_memory), ("collective", t_coll)],
                key=lambda kv: kv[1])[0],
        },
        "model_flops_total": model_flops,
        "model_flops_per_device": model_flops / n_chips,
        "useful_flops_ratio": (model_flops / n_chips) / flops if flops else None,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    _write(tag, rec)
    if verbose:
        print(f"[dryrun] {tag}: lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(f"  memory_analysis: {mem_rec}")
        print(f"  cost_analysis: flops/dev={flops:.3e} bytes/dev={bytes_acc:.3e}")
        print(f"  collectives: {coll}")
        r = rec["roofline"]
        print(f"  roofline: compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
              f"collective={r['collective_s']:.4f}s dominant={r['dominant']}")
    return rec


def _write(tag, rec):
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    with open(OUT_DIR / f"{tag}.json", "w") as f:
        json.dump(rec, f, indent=2)


def run_paper_cell(multi_pod: bool, scale: int = 200_000):
    """Dry-run the paper's own pipeline: the multi-pod sharded Poisson
    sampler (core/distributed.py) on the production mesh at EpiQL-like
    relative scale (Q_c star join; root block-partitioned on (pod, data))."""
    from repro.core import Atom, Database, JoinQuery
    from repro.core.distributed import ShardedPoissonSampler

    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    axes = ("pod", "data") if multi_pod else ("data",)
    rng = np.random.default_rng(0)
    npool, nage, npers = max(scale // 50, 4), 6, scale
    grid_n = npool * nage * nage
    db = Database.from_columns({
        "Person": {"pers": np.arange(npers),
                   "age": rng.integers(0, nage, npers),
                   "pool": rng.integers(0, npool, npers)},
        "ContactProb": {"pool": rng.integers(0, npool, grid_n),
                        "age1": rng.integers(0, nage, grid_n),
                        "age2": rng.integers(0, nage, grid_n),
                        "prob": rng.random(grid_n) * 0.05},
    })
    q = JoinQuery((
        Atom.of("ContactProb", "pool", "age1", "age2", "prob"),
        Atom.of("Person", "per1", "age1", "pool", alias="P1"),
        Atom.of("Person", "per2", "age2", "pool", alias="P2"),
    ), prob_var="prob")
    t0 = time.time()
    s = ShardedPoissonSampler(db, q, mesh, axes=axes)
    with mesh:
        compiled = s.lower_step().compile()
    hc = HloCost(compiled.as_text()).entry_cost()
    mem = compiled.memory_analysis()
    tc_, tm_, tl_ = (hc["flops"] / PEAK_FLOPS, hc["bytes"] / HBM_BW,
                     hc["collective_bytes"] / ICI_BW)
    rec = {
        "arch": "paper_qc_sampler", "shape": f"scale_{scale}", "mesh": mesh_name,
        "kind": "sample_step", "chips": int(np.prod(list(mesh.shape.values()))),
        "compile_s": round(time.time() - t0, 2),
        "memory": {"argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                   "temp_bytes": getattr(mem, "temp_size_in_bytes", None)},
        "flops_per_device": float(hc["flops"]),
        "bytes_per_device": float(hc["bytes"]),
        "collective_total_bytes": float(hc["collective_bytes"]),
        "roofline": {"compute_s": tc_, "memory_s": tm_, "collective_s": tl_,
                     "dominant": max([("compute", tc_), ("memory", tm_),
                                      ("collective", tl_)], key=lambda kv: kv[1])[0]},
        "per_shard_capacity": s.cap,
    }
    _write(f"paper_qc_sampler__scale{scale}__{mesh_name}", rec)
    print(f"[dryrun] paper sampler {mesh_name}: compile {rec['compile_s']}s "
          f"compute={tc_:.2e}s memory={tm_:.2e}s collective={tl_:.2e}s")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true", help="2x16x16 only")
    ap.add_argument("--single-pod", action="store_true", help="16x16 only")
    ap.add_argument("--both", action="store_true")
    ap.add_argument("--paper", action="store_true",
                    help="dry-run the paper's sharded Poisson sampler")
    args = ap.parse_args()

    if args.paper:
        run_paper_cell(multi_pod=False)
        run_paper_cell(multi_pod=True)
        if not (args.all or args.arch):
            return

    meshes = []
    if args.multi_pod:
        meshes = [True]
    elif args.single_pod:
        meshes = [False]
    else:
        meshes = [False, True]

    cells = []
    archs = list(configs.ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(configs.SHAPES) if (args.all or not args.shape) else [args.shape]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    failures = []
    for a, s, mp in cells:
        try:
            run_cell(a, s, mp)
        except Exception as e:  # noqa: BLE001 — report all failures at end
            failures.append((a, s, mp, repr(e)[:300]))
            print(f"[dryrun] FAIL {a} {s} multi_pod={mp}: {e}", file=sys.stderr)
    if failures:
        print(f"\n[dryrun] {len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        sys.exit(1)
    print("\n[dryrun] all cells passed")


if __name__ == "__main__":
    main()
