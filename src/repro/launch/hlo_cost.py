"""Trip-count-aware HLO cost accounting.

``compiled.cost_analysis()`` counts every while-loop body ONCE (verified
empirically — a 10-iteration scan reports 1x body FLOPs), which silently
undercounts any scan-over-layers model by ~n_layers x, for FLOPs *and*
collective bytes. XLA does annotate each while op with
``backend_config={"known_trip_count":{"n":...}}``, so this module parses the
optimized HLO text into computations, walks the call graph (while / fusion /
call / conditional), and multiplies per-op costs by the product of enclosing
trip counts.

Accounting model (documented, deliberately simple):
  * dot / convolution: 2 * prod(result_dims) * prod(contraction_dims) FLOPs
    (batch dims live in the result; contraction sizes read from operand 0's
    shape at the annotated dims);
  * every op: bytes = operand bytes + result bytes (an upper bound that
    ignores fusion reuse — applied uniformly, so *relative* comparisons
    between variants are meaningful; we also report XLA's own entry-level
    "bytes accessed" for reference);
  * elementwise/fusion root ops: 1 FLOP per output element (negligible next
    to the dots for these models, but keeps RWKV/Mamba scans honest);
  * collectives: result bytes, all-reduce counted 2x (ring = RS + AG).
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
          "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4,
          "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
          "s4": 1, "u4": 1}

_SHAPE = re.compile(r"(\w+)\[([\d,]*)\](?:\{[\d,]*\})?")
_DEF = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
_CALLQ = re.compile(r"(?:body|calls|to_apply)=(%[\w.\-]+)")
_COND_CALLS = re.compile(r"(?:true_computation|false_computation|branch_computations)=\(?([%\w.,\- ]+)\)?")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _parse_shapes(sig: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for m in _SHAPE.finditer(sig):
        dt, dims = m.group(1), m.group(2)
        if dt not in _BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",")) if dims else ()
        out.append((dt, shape))
    return out


def _nbytes(shapes) -> int:
    total = 0
    for dt, shape in shapes:
        n = 1
        for d in shape:
            n *= d
        total += n * _BYTES[dt]
    return total


def _nelems(shapes) -> int:
    total = 0
    for _, shape in shapes:
        n = 1
        for d in shape:
            n *= d
        total += n
    return total


class HloCost:
    def __init__(self, hlo_text: str):
        self.computations = self._split(hlo_text)
        self._memo: Dict[str, Dict[str, float]] = {}

    # -- parsing -------------------------------------------------------------
    @staticmethod
    def _split(text: str) -> Dict[str, List[str]]:
        comps: Dict[str, List[str]] = {}
        cur_name, cur_lines, depth = None, [], 0
        for line in text.splitlines():
            stripped = line.strip()
            m = re.match(r"^(?:ENTRY\s+)?(%?[\w.\-]+)\s*(?:\([^)]*\))?.*\{\s*$", stripped)
            if cur_name is None and m and ("->" in stripped or stripped.startswith("ENTRY")
                                           or re.match(r"^%[\w.\-]+", stripped)):
                cur_name = m.group(1)
                if not cur_name.startswith("%"):
                    cur_name = "%" + cur_name
                if stripped.startswith("ENTRY"):
                    comps["__entry_alias__"] = [cur_name]
                cur_lines = []
                depth = 1
                continue
            if cur_name is not None:
                depth += stripped.count("{") - stripped.count("}")
                if depth <= 0:
                    comps[cur_name] = cur_lines
                    cur_name, cur_lines = None, []
                    continue
                cur_lines.append(stripped)
        return comps

    @property
    def entry(self) -> str:
        return self.computations.get("__entry_alias__", ["%main"])[0]

    # -- per-computation op shapes -------------------------------------------
    def _op_shapes(self, comp: str) -> Dict[str, List[Tuple[str, Tuple[int, ...]]]]:
        shapes = {}
        for line in self.computations.get(comp, []):
            m = _DEF.match(line)
            if not m:
                continue
            name, rhs = m.group(1), m.group(2)
            sig = rhs.split(" ", 1)[0] if rhs.startswith(("(", "f", "s", "u", "b", "p", "c", "t")) else rhs
            # result type = text before the op name; take shapes up to the op call
            head = rhs.split("(")[0]
            shapes[name] = _parse_shapes(head)
        return shapes

    # -- cost of one computation (without multipliers) ------------------------
    def cost(self, comp: str, count_bytes: bool = True) -> Dict[str, float]:
        memo_key = (comp, count_bytes)
        if memo_key in self._memo:
            return self._memo[memo_key]
        self._memo[memo_key] = {"flops": 0.0, "bytes": 0.0,
                                **{c: 0.0 for c in COLLECTIVES}}  # break cycles
        res = {"flops": 0.0, "bytes": 0.0, **{c: 0.0 for c in COLLECTIVES}}
        op_shapes = self._op_shapes(comp)

        for line in self.computations.get(comp, []):
            m = _DEF.match(line)
            if not m:
                continue
            name, rhs = m.group(1), m.group(2)
            opm = re.search(r"\b([a-z][\w\-]*)\(", rhs)
            op = opm.group(1) if opm else ""
            result_shapes = op_shapes.get(name, [])
            rbytes = _nbytes(result_shapes)

            # operand bytes
            args = re.search(r"\b" + re.escape(op) + r"\(([^)]*)\)", rhs) if op else None
            obytes = 0
            operand_names = []
            if args:
                # operands may be typed ("f32[128,128]{1,0} %x") and shapes
                # contain commas, so extract names by pattern, not by split
                for a in re.findall(r"%[\w.\-]+", args.group(1)):
                    operand_names.append(a)
                    obytes += _nbytes(op_shapes.get(a, []))
            if count_bytes:
                # Fusion-subsumed HBM model: this CPU-backend HLO splits
                # elementwise chains into thousands of micro-"fusions" that a
                # TPU compile would fuse into the surrounding matmuls, so
                # counting every fusion boundary inflates traffic ~6x
                # (measured on llama3-405b: 84% of naive bytes were fusion
                # boundaries). We count the tensors that MUST move through
                # HBM: dot/conv operands+results, slice/gather regions,
                # update regions, copies, reductions, concats, collectives.
                if op in ("dot", "convolution", "reduce", "concatenate",
                          "sort", "select-and-scatter", "reduce-window",
                          *COLLECTIVES):
                    res["bytes"] += rbytes + obytes
                elif op in ("dynamic-slice", "slice", "gather"):
                    res["bytes"] += rbytes
                elif op in ("copy", "transpose"):
                    res["bytes"] += 2 * rbytes
                elif op in ("dynamic-update-slice", "scatter"):
                    upd = (_nbytes(op_shapes.get(operand_names[1], []))
                           if len(operand_names) > 1 else rbytes)
                    res["bytes"] += 2 * upd

            mult = 1.0
            sub = None
            sub_bytes = count_bytes
            if op == "while":
                tm = _TRIP.search(rhs)
                mult = float(tm.group(1)) if tm else 1.0
                cm = re.search(r"body=(%[\w.\-]+)", rhs)
                sub = cm.group(1) if cm else None
                # the while op's own operand/result bytes are not re-read per
                # iteration; the body's boundary traffic is what repeats
                if count_bytes:
                    res["bytes"] -= rbytes + obytes
            elif op in ("fusion", "call", "custom-call", "map", "reduce",
                        "reduce-window", "sort", "scatter", "select-and-scatter"):
                cm = _CALLQ.search(rhs)
                sub = cm.group(1) if cm else None
                # fusion internals stay on-chip: count only the fusion's own
                # boundary bytes (already added), not the sub-computation's
                sub_bytes = False
            elif op == "conditional":
                cm = _COND_CALLS.search(rhs)
                if cm:
                    for branch in cm.group(1).split(","):
                        b = branch.strip()
                        if b in self.computations:
                            bc = self.cost(b, count_bytes=False)
                            for k in res:
                                if k != "bytes":
                                    res[k] += bc[k]
                    sub = None

            if sub and sub in self.computations:
                sc = self.cost(sub, count_bytes=sub_bytes)
                for k in res:
                    res[k] += mult * sc[k]

            if op == "dot":
                cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
                lhs = op_shapes.get(operand_names[0], []) if operand_names else []
                contr = 1
                if cdims and lhs:
                    lshape = lhs[0][1]
                    for d in cdims.group(1).split(","):
                        if d:
                            contr *= lshape[int(d)]
                res["flops"] += 2.0 * _nelems(result_shapes) * contr
            elif op == "convolution":
                res["flops"] += 2.0 * _nelems(result_shapes) * 64  # coarse
            elif op in ("add", "multiply", "subtract", "divide", "exponential",
                        "tanh", "maximum", "minimum", "rsqrt", "log", "power",
                        "fusion", "select", "compare", "negate", "floor"):
                res["flops"] += float(_nelems(result_shapes))

            for c in COLLECTIVES:
                if op == c:
                    b = rbytes * (2 if c == "all-reduce" else 1)
                    res[c] += b

        self._memo[memo_key] = res
        return res

    def entry_cost(self) -> Dict[str, float]:
        c = dict(self.cost(self.entry))
        c["collective_bytes"] = sum(c[k] for k in COLLECTIVES)
        return c
