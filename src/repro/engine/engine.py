"""The unified query engine: plan once, index once, serve everything.

``QueryEngine`` is the paper's closing claim turned into an API surface
(DESIGN.md §7): one random-access shred index is a *uniform basis* for both
classical acyclic join processing (Yannakakis / SYA) and Poisson sampling
"without regret". The engine owns

  * a bound, immutable ``Database``;
  * a shred cache  — (query fingerprint, rep) -> built index;
  * a plan cache   — (query fingerprint, rep, method, project) -> jitted
    executors (``CompiledPlan``);
  * an explicit ``CapacityPolicy`` for static-shape buffer planning.

Repeated and batched queries with the same fingerprint skip GYO, index
construction, and XLA retracing entirely — the warm path is a dict lookup
plus one cached-trace dispatch. Both caches are LRU-bounded.

Sharded execution (DESIGN.md §8) is the same contract over a device mesh:
``sample(..., mesh=...)`` / ``full_join(..., mesh=...)`` route through a
shard planner to stacked per-shard indexes held in the *same* shred cache
(keyed by fingerprint x rep x mesh shape x shard count), so the warm
sharded path also performs zero index rebuilds.

The bound database is a *versioned snapshot* (DESIGN.md §11): cache keys
carry the snapshot version, and ``apply_delta`` advances the binding while
*upgrading* warm entries in place via incremental reshred — a small update
costs milliseconds of merge work, zero rebuilds, and (shapes permitting)
zero retraces, where ``rebind`` would throw everything away.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, Optional, Tuple, Union

import jax.numpy as jnp

from repro.core.database import Database
from repro.core.distributed import (
    StackedShred, build_stacked, reshard_incremental,
)
from repro.core.jointree import JoinQuery
from repro.core.poisson import JoinSample
from repro.core.shred import Shred, build_plan, build_shred, reshred_incremental
from repro.core import yannakakis

from .capacity import CapacityPolicy, DEFAULT_POLICY
from .fingerprint import (
    executor_key, mesh_fingerprint, plan_key, query_fingerprint,
    sharded_executor_key, sharded_plan_key,
)
from .plan import CompiledPlan
from .sharding import ShardedPlan, plan_shards
from .spec import DrawSpec, merge_spec

__all__ = ["QueryEngine", "CacheStats"]


@dataclasses.dataclass
class CacheStats:
    """Observable cache behavior (asserted in tests, reported by serve).

    Stacked (sharded) index builds and hits count in the same
    ``shred_builds`` / ``shred_hits`` — one index economy, two layouts.
    ``apply_delta`` reports its work separately: ``shred_upgrades`` /
    ``plan_upgrades`` count warm entries advanced incrementally (never
    through ``shred_builds`` — upgrading is precisely *not* rebuilding),
    and ``shards_reused`` / ``shards_rebuilt`` split the stacked-index
    treatment per shard (DESIGN.md §11).

    Stats are additive across engines: a replicated fleet (DESIGN.md §12)
    reports ``CacheStats.aggregate(r.engine.stats for r in replicas)`` —
    fingerprint-affine routing shows up there as exactly one ``plan_miss``
    per query shape per replica that ever saw it."""

    shred_builds: int = 0
    shred_hits: int = 0
    plan_hits: int = 0
    plan_misses: int = 0
    shred_upgrades: int = 0
    plan_upgrades: int = 0
    shards_reused: int = 0
    shards_rebuilt: int = 0

    def snapshot(self) -> "CacheStats":
        return dataclasses.replace(self)

    def __add__(self, other: "CacheStats") -> "CacheStats":
        if not isinstance(other, CacheStats):
            return NotImplemented
        return CacheStats(**{
            f.name: getattr(self, f.name) + getattr(other, f.name)
            for f in dataclasses.fields(CacheStats)})

    @classmethod
    def aggregate(cls, stats) -> "CacheStats":
        """Fleet-wide totals: the field-wise sum over an iterable of
        per-engine stats (empty iterable -> all-zero stats)."""
        total = cls()
        for s in stats:
            total = total + s
        return total


@dataclasses.dataclass
class _IndexEntry:
    """One shred-cache slot: the index plus what ``apply_delta`` needs to
    upgrade it — the query (for the join tree) and, for stacked indexes,
    the filtered base snapshot and shard count (DESIGN.md §11)."""

    index: Union[Shred, StackedShred]
    query: JoinQuery
    version: int
    base: Optional[Database] = None   # stacked entries: filtered base db
    num_shards: int = 0               # stacked entries only


class QueryEngine:
    """Plans, caches, and dispatches acyclic-join queries over one database.

    Usage::

        engine = QueryEngine(db)
        full   = engine.full_join(query)             # Yannakakis via index
        smp    = engine.poisson_sample(query, key)   # EXPRACE via same index

    Both entry points share the shred cache: the first call on a query
    fingerprint builds the index, every later call (either entry point,
    any number of sample draws) reuses it.
    """

    def __init__(self, db: Database, *, rep: str = "usr",
                 policy: Optional[CapacityPolicy] = None,
                 max_plans: int = 64):
        if rep not in ("csr", "usr", "both"):
            raise ValueError(f"rep must be csr|usr|both, got {rep!r}")
        self.db = db
        self.rep = rep
        self.policy = policy or DEFAULT_POLICY
        self.max_plans = max_plans
        self.stats = CacheStats()
        self._shreds: "collections.OrderedDict[Tuple, _IndexEntry]" = collections.OrderedDict()
        self._plans: "collections.OrderedDict[Tuple, CompiledPlan]" = collections.OrderedDict()
        # Shard-planner verdicts (tiny; root size + mesh shape + policy only
        # change when the bound snapshot moves — apply_delta drops verdicts
        # whose root relation was touched, rebind() drops them all). Values
        # are (ShardPlan, root relation name).
        self._shard_verdicts: "collections.OrderedDict[Tuple, object]" = collections.OrderedDict()

    # -- cache plumbing ------------------------------------------------------
    def _shred_for(self, query: JoinQuery, rep: str) -> Shred:
        key = plan_key(query, rep, self.db.version)
        hit = self._shreds.get(key)
        if hit is not None:
            self._shreds.move_to_end(key)
            self.stats.shred_hits += 1
            return hit.index
        self.stats.shred_builds += 1
        shred = build_shred(self.db, query, rep=rep)
        self._shreds[key] = _IndexEntry(shred, query, self.db.version)
        while len(self._shreds) > self.max_plans:
            self._shreds.popitem(last=False)
        return shred

    def _stacked_shred_for(self, query: JoinQuery, rep: str, mesh,
                           num_shards: int) -> StackedShred:
        """The stacked per-shard index for a sharded plan; lives in the same
        LRU as single-device shreds under a mesh-extended key."""
        key = sharded_plan_key(query, rep, mesh, num_shards, self.db.version)
        hit = self._shreds.get(key)
        if hit is not None:
            self._shreds.move_to_end(key)
            self.stats.shred_hits += 1
            return hit.index
        self.stats.shred_builds += 1
        stacked, base = build_stacked(self.db, query, num_shards, rep=rep)
        self._shreds[key] = _IndexEntry(stacked, query, self.db.version,
                                        base=base, num_shards=num_shards)
        while len(self._shreds) > self.max_plans:
            self._shreds.popitem(last=False)
        return stacked

    @staticmethod
    def _resolve_spec(spec: Optional[DrawSpec], **kw) -> DrawSpec:
        """The single normalization shim behind every entry point
        (DESIGN.md §13): start from ``spec`` (or an empty ``DrawSpec``)
        and overlay each legacy kwarg that was explicitly passed. Kwargs
        win over spec fields; ``None`` means "not passed"."""
        return merge_spec(spec, **kw)

    def compile(self, query: JoinQuery, spec: Optional[DrawSpec] = None, *,
                rep: Optional[str] = None,
                method: Optional[str] = None,
                project: Optional[tuple] = None,
                narrow: Optional[bool] = None,
                kernels: Optional[str] = None) -> CompiledPlan:
        """Plan + index + jit for a query; cached by fingerprint.

        ``spec`` (or the equivalent legacy kwargs — see ``DrawSpec``):
        ``project`` is the bag-based projection attributes A for queries of
        the paper's form beta_y(pi_A(Q^)) (eq. 2). Sampling first and
        projecting the sample is exact for bag projection; set-based
        free-connex projection is out of scope (DESIGN.md §9).
        """
        spec = self._resolve_spec(
            spec, rep=rep, method=method,
            project=tuple(project) if project else None, narrow=narrow,
            kernels=kernels)
        crep = spec.rep or self.rep
        if spec.project is not None and query.prob_var is not None \
                and query.prob_var not in spec.project:
            raise ValueError("prob_var (y) must be in the projection A")
        key = executor_key(query, crep, spec.method, spec.project,
                           self.db.version, spec.narrow, spec.kernels)
        hit = self._plans.get(key)
        if hit is not None:
            self._plans.move_to_end(key)
            self.stats.plan_hits += 1
            return hit
        self.stats.plan_misses += 1
        plan = CompiledPlan(
            query=query, spec=spec.plan_view(crep),
            shred=self._shred_for(query, crep), policy=self.policy,
        )
        self._plans[key] = plan
        while len(self._plans) > self.max_plans:
            self._plans.popitem(last=False)
        return plan

    def compile_sharded(self, query: JoinQuery, mesh,
                        spec: Optional[DrawSpec] = None, *,
                        axes: Optional[tuple] = None,
                        rep: Optional[str] = None,
                        method: Optional[str] = None,
                        project: Optional[tuple] = None,
                        narrow: Optional[bool] = None,
                        kernels: Optional[str] = None,
                        ) -> Union[CompiledPlan, ShardedPlan]:
        """Plan + stacked index + shard_map jit for a query over ``mesh``.

        The shard planner picks the partition axes/count from the mesh
        shape, the root relation size, and the engine's ``CapacityPolicy``
        (pass ``axes`` to pin them). Degenerate plans (one shard, no axes)
        transparently fall back to the single-device ``CompiledPlan`` — a
        1-device mesh costs nothing over not passing one (DESIGN.md §8).
        """
        spec = self._resolve_spec(
            spec, rep=rep, method=method,
            project=tuple(project) if project else None, narrow=narrow,
            kernels=kernels,
            axes=tuple(axes) if axes is not None else None)
        crep = spec.rep or self.rep
        fp = query_fingerprint(query)
        vkey = (fp, mesh_fingerprint(mesh), spec.axes)
        hit = self._shard_verdicts.get(vkey)
        if hit is None:  # GYO + planner only on the first sighting
            root_atom = build_plan(query).atom
            root_rows = self.db.relations[root_atom.relation].num_rows
            sp = plan_shards(mesh, root_rows, self.policy, axes=spec.axes)
            self._shard_verdicts[vkey] = (sp, root_atom.relation)
            while len(self._shard_verdicts) > self.max_plans:
                self._shard_verdicts.popitem(last=False)
        else:
            sp, _root = hit
        if not sp.axes:
            return self.compile(query, spec)
        key = sharded_executor_key(query, crep, spec.method, spec.project,
                                   mesh, sp.axes, self.db.version,
                                   spec.narrow, spec.kernels)
        hit = self._plans.get(key)
        if hit is not None:
            self._plans.move_to_end(key)
            self.stats.plan_hits += 1
            return hit
        self.stats.plan_misses += 1
        plan = ShardedPlan(
            query=query, spec=spec.plan_view(crep),
            mesh=mesh, axes=sp.axes,
            stacked=self._stacked_shred_for(query, crep, mesh, sp.num_shards),
            policy=self.policy,
        )
        self._plans[key] = plan
        while len(self._plans) > self.max_plans:
            self._plans.popitem(last=False)
        return plan

    def rebind(self, db: Database) -> "QueryEngine":
        """Bind a new database instance, dropping both caches. Always
        invalidates — even an identical schema fingerprint can carry
        different data values, and shreds depend on values (cheap
        correctness over cleverness; see DESIGN.md §7). For *derived*
        snapshots, ``apply_delta`` keeps the caches warm instead."""
        self.db = db
        self._shreds.clear()
        self._plans.clear()
        self._shard_verdicts.clear()  # root sizes may differ
        return self

    def apply_delta(self, delta) -> "QueryEngine":
        """Advance the bound snapshot to ``self.db.apply(delta)`` and
        *upgrade* every warm cache entry instead of dropping it
        (DESIGN.md §11).

        Single-device shreds touched by the delta are merged forward via
        ``reshred_incremental`` (bit-identical to a rebuild, at delta
        cost); stacked shreds are re-partitioned with per-shard reuse
        (``shards_reused``/``shards_rebuilt`` in ``CacheStats``); compiled
        plans keep their jitted executors, so a shape-preserving delta
        costs zero retraces on the next warm draw. Entries for queries the
        delta does not touch are re-keyed to the new version for free.
        ``rebind`` remains the full-invalidation escape hatch.
        """
        old_db = self.db
        new_db = old_db.apply(delta)
        new_v = new_db.version
        touched = set(delta.touched())

        upgraded: Dict[Tuple, object] = {}  # key sans version -> new index
        new_shreds: "collections.OrderedDict[Tuple, _IndexEntry]" = \
            collections.OrderedDict()
        for key, entry in self._shreds.items():
            qrels = {a.relation for a in entry.query.atoms}
            if not (touched & qrels):
                new_entry = dataclasses.replace(entry, version=new_v)
            elif isinstance(entry.index, StackedShred):
                stacked, base, reused, rebuilt = reshard_incremental(
                    entry.index, entry.base, new_db, entry.query,
                    entry.num_shards, rep=key[1])
                self.stats.shred_upgrades += 1
                self.stats.shards_reused += reused
                self.stats.shards_rebuilt += rebuilt
                new_entry = _IndexEntry(stacked, entry.query, new_v,
                                        base=base,
                                        num_shards=entry.num_shards)
            else:
                shred = reshred_incremental(entry.index, old_db,
                                            entry.query, delta)
                self.stats.shred_upgrades += 1
                new_entry = _IndexEntry(shred, entry.query, new_v)
            upgraded[key[:-1]] = new_entry.index
            new_shreds[key[:-1] + (new_v,)] = new_entry
        self._shreds = new_shreds

        new_plans: "collections.OrderedDict[Tuple, CompiledPlan]" = \
            collections.OrderedDict()
        for key, plan in self._plans.items():
            qrels = {a.relation for a in plan.query.atoms}
            if touched & qrels:
                if isinstance(plan, ShardedPlan):
                    skey = sharded_plan_key(plan.query, key[1], plan.mesh,
                                            plan.num_shards)[:-1]
                    stacked = upgraded.get(skey)
                    if stacked is None:
                        # Orphaned sharded plan (its stacked index fell out
                        # of the LRU): no base to diff against — drop it.
                        continue
                    plan.rebind_stacked(stacked)
                else:
                    skey = plan_key(plan.query, key[1])[:-1]
                    shred = upgraded.get(skey)
                    if shred is None:  # orphan: upgrade from its own index
                        shred = reshred_incremental(plan.shred, old_db,
                                                    plan.query, delta)
                        self.stats.shred_upgrades += 1
                    plan.rebind_shred(shred)
                self.stats.plan_upgrades += 1
            new_plans[key[:-1] + (new_v,)] = plan
        self._plans = new_plans

        # Shard-planner verdicts keyed off a touched root relation are
        # stale (the root row count may have moved); recompute lazily.
        for vkey in [k for k, (_, root) in self._shard_verdicts.items()
                     if root in touched]:
            del self._shard_verdicts[vkey]

        self.db = new_db
        return self

    # -- entry points --------------------------------------------------------
    def full_join(self, query: JoinQuery, spec: Optional[DrawSpec] = None, *,
                  rep: Optional[str] = None,
                  mesh=None, axes: Optional[tuple] = None,
                  ) -> Dict[str, jnp.ndarray]:
        """Yannakakis full join via the cached index (SYA; Prop 4.4/4.5).

        With a mesh (``spec.mesh`` or ``mesh=``), the root is
        block-partitioned over the mesh's data axes and each shard flattens
        its block through the stacked index; the gathered result is
        bit-identical to the single-device path, order included
        (DESIGN.md §8)."""
        spec = self._resolve_spec(spec, rep=rep, mesh=mesh,
                                  axes=tuple(axes) if axes is not None
                                  else None)
        if spec.mesh is not None:
            plan = self.compile_sharded(query, spec.mesh, spec)
            if isinstance(plan, ShardedPlan):
                return plan.full_join()
        else:
            plan = self.compile(query, spec)
        return plan.full_join(rep=spec.rep)

    def poisson_sample(self, query: JoinQuery, key,
                       spec: Optional[DrawSpec] = None, *,
                       cap: Optional[int] = None, acap: Optional[int] = None,
                       rep: Optional[str] = None,
                       method: Optional[str] = None,
                       project: Optional[tuple] = None,
                       narrow: Optional[bool] = None,
                       kernels: Optional[str] = None,
                       auto: bool = False, mesh=None,
                       axes: Optional[tuple] = None) -> JoinSample:
        """One independent Poisson sample of ``beta_y(Q)`` via the cached
        index. ``auto=True`` applies the policy's redraw-on-overflow loop.
        ``spec=`` carries the full draw configuration (``DrawSpec``); the
        legacy kwargs keep working and win field-by-field over the spec.

        With a mesh, per-shard trials run under device-folded keys and
        one psum reports the global count — distributionally identical to
        the global draw, and bit-reproducible against a host loop folding
        the shard index into the same base key (DESIGN.md §8). Degenerate
        meshes fall back to the single-device plan transparently."""
        spec = self._resolve_spec(
            spec, cap=cap, acap=acap, rep=rep, method=method,
            project=tuple(project) if project else None, narrow=narrow,
            kernels=kernels,
            mesh=mesh, axes=tuple(axes) if axes is not None else None)
        if query.prob_var is None:
            raise ValueError("Poisson sampling needs query.prob_var (beta_y)")
        if spec.mesh is not None:
            plan = self.compile_sharded(query, spec.mesh, spec)
            if isinstance(plan, ShardedPlan):
                if auto:
                    return plan.sample_auto(key, cap=spec.cap, acap=spec.acap)
                return plan.sample(key, cap=spec.cap, acap=spec.acap)
            # degenerate mesh: compile_sharded already fell back to the
            # single-device CompiledPlan — reuse it, don't compile twice
        else:
            plan = self.compile(query, spec)
        if auto:
            return plan.sample_auto(key, cap=spec.cap, acap=spec.acap)
        return plan.sample(key, cap=spec.cap, acap=spec.acap,
                           rep=spec.rep if spec.rep != "both" else None)

    # ``sample`` is the preferred name for the Poisson entry point; kwargs
    # (including ``spec=`` and ``mesh=``) are identical.
    sample = poisson_sample

    def sample_batch(self, query: JoinQuery, keys,
                     spec: Optional[DrawSpec] = None, *,
                     cap: Optional[int] = None, acap: Optional[int] = None,
                     rep: Optional[str] = None,
                     method: Optional[str] = None,
                     project: Optional[tuple] = None,
                     narrow: Optional[bool] = None,
                     kernels: Optional[str] = None, mesh=None,
                     axes: Optional[tuple] = None) -> JoinSample:
        """``B`` independent Poisson draws of ``beta_y(Q)`` in one dispatch
        (DESIGN.md §10). ``keys`` is a ``(B,)`` PRNG key vector — pass
        ``jax.random.split(key, B)`` for the canonical stream. The result's
        leaves carry a leading batch axis (columns/positions ``(B, cap)``,
        count/overflow ``(B,)``) and lane ``b`` is bit-identical to
        ``sample(query, keys[b])`` with the same spec/kwargs.

        The plan is the *same* cache entry the single-draw path uses (one
        fingerprint, one shred, one ``CompiledPlan``), so interleaving
        single and batched draws rebuilds nothing; batch sizes are bucketed
        to powers of two, so warm same-bucket batches never retrace. With
        a mesh, the sharded plan composes: shard_map outside, vmap
        inside, one psum for the ``(B,)`` global counts.
        """
        spec = self._resolve_spec(
            spec, cap=cap, acap=acap, rep=rep, method=method,
            project=tuple(project) if project else None, narrow=narrow,
            kernels=kernels,
            mesh=mesh, axes=tuple(axes) if axes is not None else None)
        if query.prob_var is None:
            raise ValueError("Poisson sampling needs query.prob_var (beta_y)")
        if spec.mesh is not None:
            plan = self.compile_sharded(query, spec.mesh, spec)
            if isinstance(plan, ShardedPlan):
                return plan.sample_batch(keys, cap=spec.cap, acap=spec.acap)
            # degenerate mesh: fall through to the single-device plan
        else:
            plan = self.compile(query, spec)
        return plan.sample_batch(keys, cap=spec.cap, acap=spec.acap,
                                 rep=spec.rep if spec.rep != "both" else None)

    def uniform_sample(self, query: JoinQuery, key, p: float, *,
                       spec: Optional[DrawSpec] = None,
                       cap: Optional[int] = None, method: str = "hybrid",
                       rep: Optional[str] = None) -> JoinSample:
        """beta_p with one fixed probability for every join tuple (§6.1).

        ``method`` here selects the *position* sampler (hybrid/bern/geo/
        binom) — it is unrelated to ``DrawSpec.method``, so a ``spec``
        contributes only ``rep``/``cap``/``narrow`` on this path."""
        spec = self._resolve_spec(spec, cap=cap, rep=rep)
        plan = self.compile(query, rep=spec.rep, narrow=spec.narrow)
        return plan.uniform_sample(key, p, cap=spec.cap, method=method)

    def join_size(self, query: JoinQuery) -> int:
        """|Q(db)| in O(1) from the cached index (never materialized)."""
        return self.compile(query).join_size

    def cache_info(self) -> Dict[str, object]:
        """Staleness-observable cache state (DESIGN.md §11): the bound
        snapshot version plus every cache entry's version. Serve's stats
        path reports this, and tests assert entries never trail the bound
        version after ``apply_delta``."""
        return {
            "db_version": self.db.version,
            "shreds": [
                {"fingerprint": k[0], "rep": k[1], "version": e.version,
                 "stacked": isinstance(e.index, StackedShred)}
                for k, e in self._shreds.items()
            ],
            "plans": [
                {"fingerprint": k[0], "rep": k[1], "version": k[-1],
                 "sharded": isinstance(p, ShardedPlan)}
                for k, p in self._plans.items()
            ],
        }

    def explain(self, query: JoinQuery, *, rep: Optional[str] = None) -> str:
        """Human-readable plan: the (rerooted) join tree + cache state,
        including the bound snapshot version and per-entry cache versions
        (staleness is observable, not inferred — DESIGN.md §11)."""
        plan = self.compile(query, rep=rep)
        tree = build_plan(query)  # the rerooted tree the plan executes
        lines = [
            f"QueryEngine plan  rep={plan.rep}  method={plan.method}",
            "  join tree (GYO):",
        ]
        lines += ["    " + l for l in tree.pretty().rstrip().split("\n")]
        info = self.cache_info()
        fp = query_fingerprint(query)
        entry_vs = sorted({e["version"] for e in
                           info["shreds"] + info["plans"]
                           if e["fingerprint"] == fp})
        lines += [
            f"  |Q(db)| = {plan.join_size}",
            f"  db version={info['db_version']}  "
            f"entry versions={entry_vs or [info['db_version']]}",
            f"  cached shreds={len(self._shreds)} plans={len(self._plans)} "
            f"(hits: shred={self.stats.shred_hits} plan={self.stats.plan_hits}"
            f"; upgrades: shred={self.stats.shred_upgrades} "
            f"plan={self.stats.plan_upgrades})",
        ]
        return "\n".join(lines)

    # -- baselines (kept for benchmarks; not cached) -------------------------
    def materialize_and_scan(self, key, query: JoinQuery,
                             uniform_p: Optional[float] = None):
        """The M&S baseline: end-to-end materialize-then-Bernoulli, which
        deliberately bypasses the engine caches — it rebuilds its index per
        call, exactly the naive cost the I&P plans are measured against."""
        return yannakakis.materialize_and_scan(
            key, self.db, query, uniform_p=uniform_p, rep=self.rep)
