"""repro.engine — the unified acyclic-join query engine (DESIGN.md §7).

One ``QueryEngine`` instance binds a ``Database`` and serves every workload
the paper derives from the shredded random-access index, from one build:

    engine = QueryEngine(db)
    full   = engine.full_join(query)              # Yannakakis (SYA)
    smp    = engine.poisson_sample(query, key)    # EXPRACE Poisson sample
    uni    = engine.uniform_sample(query, key, p) # uniform beta_p
    n      = engine.join_size(query)              # |Q(db)|, O(1)
    print(engine.explain(query))

Public API:
    QueryEngine       plan/cache/dispatch over one database
    CompiledPlan      a cached plan: shred index + jitted executors
    CapacityPolicy    explicit static-shape capacity & overflow policy
    CacheStats        observable shred/plan cache counters
    fingerprint.*     structure-only cache keys

The legacy entry points (``core.PoissonSampler``, ``core.yannakakis
.full_join``) are thin facades over this engine; new code should construct
a ``QueryEngine`` directly so repeated queries share its caches.
"""
from .capacity import CapacityPolicy, DEFAULT_POLICY
from .engine import CacheStats, QueryEngine
from .fingerprint import query_fingerprint, schema_fingerprint
from .plan import CompiledPlan

__all__ = [
    "QueryEngine", "CompiledPlan", "CapacityPolicy", "DEFAULT_POLICY",
    "CacheStats", "query_fingerprint", "schema_fingerprint",
]
