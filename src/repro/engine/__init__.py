"""repro.engine — the unified acyclic-join query engine (DESIGN.md §7).

One ``QueryEngine`` instance binds a ``Database`` and serves every workload
the paper derives from the shredded random-access index, from one build:

    engine = QueryEngine(db)
    full   = engine.full_join(query)              # Yannakakis (SYA)
    smp    = engine.poisson_sample(query, key)    # EXPRACE Poisson sample
    uni    = engine.uniform_sample(query, key, p) # uniform beta_p
    n      = engine.join_size(query)              # |Q(db)|, O(1)
    print(engine.explain(query))

Sharded execution is the same API over a device mesh (DESIGN.md §8), and
batched multi-draw execution is the same API over a key vector
(DESIGN.md §10) — the two compose:

    smp  = engine.sample(query, key, mesh=mesh)   # N-device Poisson trials
    full = engine.full_join(query, mesh=mesh)     # N-device flatten, gathered
    bat  = engine.sample_batch(query, jax.random.split(key, 64))
    bat  = engine.sample_batch(query, keys, mesh=mesh)  # shard_map ∘ vmap

Draw configuration is one frozen value object (DESIGN.md §13): every entry
point accepts ``spec=DrawSpec(...)`` consolidating rep/method/project/cap/
acap/narrow/mesh/axes; the legacy kwargs keep working and win
field-by-field over the spec:

    spec = DrawSpec(method="exprace", cap=4096, mesh=mesh)
    bat  = engine.sample_batch(query, keys, spec)

The bound database is a versioned snapshot (DESIGN.md §11):
``engine.apply_delta(delta)`` advances it while upgrading warm cache
entries in place (incremental reshred, plans keep their traces);
``engine.rebind(db)`` stays the full-invalidation escape hatch.

Public API:
    QueryEngine       plan/cache/dispatch over one database
    DrawSpec          frozen, hashable draw configuration (one value object)
    CompiledPlan      a cached plan: shred index + jitted executors
    ShardedPlan       a cached sharded plan: stacked index + shard_map jit
    plan_shards       the shard planner (mesh x root size x policy)
    CapacityPolicy    explicit static-shape capacity & overflow policy
    CacheStats        observable shred/plan cache counters
    fingerprint.*     structure-only cache keys (incl. mesh + spec shape)

The legacy entry points (``core.PoissonSampler``, ``core.yannakakis
.full_join``, ``core.distributed.ShardedPoissonSampler``) are thin facades
over this engine; new code should construct a ``QueryEngine`` directly so
repeated queries share its caches.
"""
from .capacity import CapacityPolicy, DEFAULT_POLICY
from .engine import CacheStats, QueryEngine
from .fingerprint import (
    draw_fingerprint, mesh_fingerprint, query_fingerprint,
    schema_fingerprint,
)
from .plan import CompiledPlan
from .sharding import ShardedPlan, ShardPlan, plan_shards
from .spec import DrawSpec, merge_spec

__all__ = [
    "QueryEngine", "DrawSpec", "merge_spec",
    "CompiledPlan", "ShardedPlan", "ShardPlan", "plan_shards",
    "CapacityPolicy", "DEFAULT_POLICY", "CacheStats",
    "query_fingerprint", "schema_fingerprint", "mesh_fingerprint",
    "draw_fingerprint",
]
