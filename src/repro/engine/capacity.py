"""Explicit capacity / overflow policy for static-shape sampling (DESIGN.md §7).

XLA requires static output shapes, so every sampler draws into a
fixed-capacity buffer and reports ``(count, overflow)``. This module owns
the policy that used to live implicitly inside ``core/poisson.py``:

  * how much headroom a buffer gets over the expected sample size
    (``sigmas`` standard deviations + ``slack`` lanes, rounded up to the
    TPU lane multiple);
  * how the EXPRACE arrival scratch is sized (its own mass estimate);
  * how overflow is handled (redraw with doubled capacity, bounded by
    ``max_doublings`` — overflow is always flagged, never silent).

The numeric defaults are unchanged from the pre-engine code paths, so
samples drawn under ``DEFAULT_POLICY`` are bit-identical to the historical
``PoissonSampler`` behavior.
"""
from __future__ import annotations

import dataclasses

from repro.core import estimate

__all__ = ["CapacityPolicy", "DEFAULT_POLICY"]


@dataclasses.dataclass(frozen=True)
class CapacityPolicy:
    """Capacity planning knobs for one engine instance.

    sigmas:         headroom in standard deviations (6 -> P(overflow) ~ 1e-9).
    slack:          additive lane slack on top of the sigma headroom.
    lane_multiple:  round capacities up to this multiple (TPU lane width).
    max_doublings:  redraw attempts in auto mode before giving up.
    min_shard_rows: the shard planner (DESIGN.md §8) never splits the root
                    relation below this many rows per shard — finer splits
                    are all padding and no work.
    """

    sigmas: float = 6.0
    slack: int = 64
    lane_multiple: int = 128
    max_doublings: int = 8
    min_shard_rows: int = 8

    def plan(self, mean: float, std: float) -> int:
        return estimate.plan_capacity(
            float(mean), float(std), sigmas=self.sigmas, slack=self.slack,
            multiple=self.lane_multiple,
        )

    def sample_capacity(self, w, p) -> int:
        """Output capacity for a Poisson sample with per-root (w, p)."""
        mean = estimate.expected_sample_size(w, p)
        std = estimate.sample_std(w, p)
        return self.plan(float(mean), float(std))

    def arrival_capacity(self, w, p) -> int:
        """Scratch capacity for EXPRACE's raw Poisson arrivals."""
        mass = float(estimate.exprace_arrival_mass(w, p))
        return self.plan(mass, mass**0.5)

    def uniform_capacity(self, n: int, p: float) -> int:
        """Capacity for a uniform beta_p sample over n positions."""
        mean = n * p
        return self.plan(mean, (mean * max(1.0 - p, 0.0)) ** 0.5)

    def flatten_capacity(self, max_shard_join: int) -> int:
        """Static per-shard probe capacity for the sharded full join: the
        largest shard's join size, lane-rounded (DESIGN.md §8)."""
        return estimate.round_up(max(int(max_shard_join), 1),
                                 self.lane_multiple)


DEFAULT_POLICY = CapacityPolicy()
