"""Sharded execution as a first-class engine path (DESIGN.md §8).

Two pieces:

  * ``plan_shards`` — the shard planner: picks the shard axes/count from
    the mesh shape, the root relation size, and the engine's
    ``CapacityPolicy`` (never shards over model-parallel axes; never splits
    the root below ``min_shard_rows`` rows per shard);
  * ``ShardedPlan`` — the sharded analogue of ``CompiledPlan``: a stacked
    per-shard index (built by ``core.distributed.build_stacked_shred``,
    held in the engine's shred cache) plus jitted shard_map executors for
    both entry points — per-shard Poisson trials with device-folded keys
    and a psum'd global count, and per-shard Yannakakis flatten whose
    gathered shards concatenate to exactly the single-device flatten.

Poisson sampling shards without coordination because trials are
independent per tuple; the device-folded key scheme
(``core.distributed.fold_shard_key``) makes the result distributionally
identical to a global draw and bit-reproducible against a host-side
emulation that folds the shard index into the same base key.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core import estimate, probe
from repro.core.distributed import StackedShred, fold_shard_key
from repro.core.jointree import JoinQuery
from repro.core.poisson import JoinSample

from . import executors
from .capacity import CapacityPolicy, DEFAULT_POLICY
from .plan import redraw_with_doubling
from .spec import DrawSpec

__all__ = ["ShardPlan", "ShardedPlan", "plan_shards", "BATCH_AXES"]

I64 = jnp.int64

# Data-like mesh axes the root may be partitioned over; model-parallel axes
# replicate the index (they shard the *model*, not the data).
BATCH_AXES = ("pod", "data")


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """The planner's verdict: which mesh axes shard the root, into how many
    blocks. ``axes == ()`` means "do not shard" (route to the single-device
    plan)."""

    axes: Tuple[str, ...]
    num_shards: int


def plan_shards(
    mesh: Mesh, root_rows: int,
    policy: CapacityPolicy = DEFAULT_POLICY,
    axes: Optional[Tuple[str, ...]] = None,
) -> ShardPlan:
    """Pick shard axes and count from the mesh, root size, and policy.

    Auto mode (``axes=None``) uses the mesh's data-like axes (``pod``,
    ``data`` — or the sole axis of a single-axis mesh), then drops trailing
    axes while a shard would fall under ``policy.min_shard_rows`` root rows
    — finer splits are all padding and no work. An explicit ``axes`` tuple
    is honored as-is (the dry-run and facade callers own their layout).
    """
    if axes is None:
        picked = tuple(a for a in BATCH_AXES if a in mesh.axis_names)
        if not picked and len(mesh.axis_names) == 1 \
                and mesh.axis_names[0] != "model":
            picked = tuple(mesh.axis_names)  # single-axis custom meshes

        def count(ax):
            return int(np.prod([mesh.shape[a] for a in ax])) if ax else 1

        while picked and count(picked) > 1 \
                and root_rows // count(picked) < policy.min_shard_rows:
            picked = picked[:-1]
        if count(picked) <= 1:
            return ShardPlan((), 1)
        return ShardPlan(picked, count(picked))
    axes = tuple(axes)
    n = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    return ShardPlan(axes, n)


class ShardedPlan:
    """One sharded entry of the plan cache: stacked index + shard_map
    executors, keyed by (query fingerprint, rep, method, project, mesh
    shape, axes) — the sharded analogue of ``CompiledPlan``.

    Everything data-dependent (the PRNG key, capacity overrides) stays a
    runtime argument; each distinct (cap, acap) pair is one cached
    shard_map trace, so warm sharded calls are a dict lookup plus one
    cached dispatch — zero shred rebuilds (asserted by ``CacheStats`` in
    ``tests/test_sharded_engine.py``).
    """

    def __init__(self, query: JoinQuery, spec: DrawSpec,
                 mesh: Mesh, axes: Tuple[str, ...],
                 stacked: StackedShred,
                 policy: CapacityPolicy = DEFAULT_POLICY):
        if spec.method != "exprace":
            # ptbern_flat needs a static per-shard flat count; shard join
            # sizes differ, so only the arrival-race sampler shards.
            raise ValueError(f"sharded sampling supports method='exprace', "
                             f"got {spec.method!r}")
        self.query = query
        self.spec = spec  # resolved plan-identity spec (DrawSpec.plan_view)
        self._base_rep = "usr" if spec.rep == "both" else spec.rep
        self.method = spec.method
        self.project = spec.project
        self.mesh = mesh
        self.axes = tuple(axes)
        self.policy = policy
        self._samplers: Dict[Tuple[int, int], callable] = {}
        self._batched_samplers: Dict[Tuple[int, int], callable] = {}
        self._flattener = None
        self._bind_stacked(stacked)

    def _bind_stacked(self, stacked: StackedShred) -> None:
        self.stacked = stacked
        # Executor rep + int32-narrowing selection (probe.select_rep — the
        # same policy as the single-device plan, over the stacked arena
        # with its leading shard dim; DESIGN.md §4). Both verdicts are
        # baked into the shard_map partials, so a rebind that flips either
        # invalidates the executor caches (a retrace, not a rebuild — same
        # economics as a capacity change). The spec's ``narrow`` override
        # wins over the auto verdict, exactly like the single-device plan.
        rep, narrow = probe.select_rep(stacked.shred, self._base_rep)
        if self.spec.narrow is not None:
            if (self.spec.narrow and stacked.shred.packed is None
                    and stacked.shred.paged is None):
                raise ValueError(
                    "DrawSpec(narrow=True) requires a packed int32 index; "
                    "this stacked shred has none")
            narrow = self.spec.narrow
        if (getattr(self, "rep", None), getattr(self, "_narrow", None)) \
                != (rep, narrow):
            self._samplers.clear()
            self._batched_samplers.clear()
            self._flattener = None
        self.rep = rep
        self._narrow = narrow
        self.num_shards = stacked.num_shards
        self.join_sizes = stacked.join_sizes
        # Global flat offset of each shard's position space: shard flattens
        # concatenate to the global flatten, so shard-local position + base
        # is the same coordinate the single-device plan reports.
        self._bases = np.concatenate(
            [[0], np.cumsum(self.join_sizes)])[:-1].astype(np.int64)

        w, p = stacked.w, stacked.p
        if p is not None:
            means = np.asarray(jax.vmap(estimate.expected_sample_size)(w, p))
            stds = np.asarray(jax.vmap(estimate.sample_std)(w, p))
            # One static capacity for every shard: plan for the heaviest.
            # Sticky across rebinds (DESIGN.md §11): a delta that lowers the
            # estimate keeps the already-traced capacity; growth retraces.
            self.cap = max(getattr(self, "cap", None) or 0, self.policy.plan(
                float(means.max(initial=0.0)), float(stds.max(initial=1.0))))
            mass = float(np.asarray(
                jax.vmap(estimate.exprace_arrival_mass)(w, p)).max(initial=0.0))
            self.acap = max(getattr(self, "acap", 0),
                            self.policy.plan(mass * 1.1 + 8, mass ** 0.5))
        else:
            self.cap = None
            self.acap = 0
        flat_cap = max(getattr(self, "flat_cap", 0),
                       self.policy.flatten_capacity(
                           max(self.join_sizes, default=0)))
        if getattr(self, "flat_cap", None) != flat_cap:
            self._flattener = None  # static cap changed: next flatten retraces
        self.flat_cap = flat_cap

    def rebind_stacked(self, stacked: StackedShred) -> "ShardedPlan":
        """Swap in an (incrementally resharded) stacked index for a newer
        snapshot, keeping the shard_map executor caches. A delta that
        preserves per-shard shapes and planned capacities costs zero
        retraces on the next warm draw (DESIGN.md §11)."""
        self._bind_stacked(stacked)
        return self

    # -- derived -------------------------------------------------------------
    @property
    def join_size(self) -> int:
        return self.stacked.join_size

    def expected_k(self) -> float:
        if self.stacked.p is None:
            raise ValueError("plan has no prob_var")
        return float(estimate.expected_sample_size(
            self.stacked.w.reshape(-1), self.stacked.p.reshape(-1)))

    # -- shard_map executors -------------------------------------------------
    @staticmethod
    def _local_sample(shred, w, p, prefE, key, *, cap, acap, rep, method,
                      project, axes, narrow=False):
        key = fold_shard_key(key, axes)
        # Drop the leading (stacked) singleton shard dim.
        shred, w, p, prefE = jax.tree.map(lambda x: x[0], (shred, w, p, prefE))
        s = executors._sample_jit(shred, w, p, prefE, key, cap=cap, rep=rep,
                                  method=method, acap=acap, project=project,
                                  narrow=narrow)
        total = jax.lax.psum(s.count, axes)
        # Re-add the shard dim so out_specs can concatenate across shards.
        return jax.tree.map(lambda x: x[None], s), total

    @staticmethod
    def _local_sample_batch(shred, w, p, prefE, keys, *, cap, acap, rep,
                            method, project, axes, narrow=False):
        """The batched shard body (DESIGN.md §10): shard_map outside, vmap
        inside. Each lane folds the same shard coordinate into its own base
        key, so lane ``b`` reproduces the single-draw sharded path under
        ``keys[b]`` bit-for-bit; one psum reports the (B,) global counts."""
        shred, w, p, prefE = jax.tree.map(lambda x: x[0], (shred, w, p, prefE))

        def one(k):
            return executors._sample_jit(
                shred, w, p, prefE, fold_shard_key(k, axes), cap=cap,
                rep=rep, method=method, acap=acap, project=project,
                narrow=narrow)

        s = jax.vmap(one)(keys)              # leaves: (B, ...)
        totals = jax.lax.psum(s.count, axes)  # (B,) global counts
        return jax.tree.map(lambda x: x[None], s), totals

    @staticmethod
    def _local_flatten(shred, prefE, *, cap, rep):
        shred, prefE = jax.tree.map(lambda x: x[0], (shred, prefE))
        n = prefE[-1]  # this shard's true join size (pads are weight-0)
        pos = jnp.minimum(jnp.arange(cap, dtype=I64), jnp.maximum(n - 1, 0))
        cols = probe.get(shred, pos, rep=rep)
        return jax.tree.map(lambda x: x[None], cols), n[None]

    def _sampler(self, cap: int, acap: int):
        fn = self._samplers.get((cap, acap))
        if fn is None:
            spec = P(self.axes)
            fn = jax.jit(shard_map(
                partial(self._local_sample, cap=cap, acap=acap, rep=self.rep,
                        method=self.method, project=self.project,
                        axes=self.axes, narrow=self._narrow),
                mesh=self.mesh,
                in_specs=(spec, spec, spec, spec, P()),
                out_specs=(spec, P()),
                check_vma=False,
            ))
            self._samplers[(cap, acap)] = fn
        return fn

    def _batched_sampler(self, cap: int, acap: int):
        fn = self._batched_samplers.get((cap, acap))
        if fn is None:
            spec = P(self.axes)
            fn = jax.jit(shard_map(
                partial(self._local_sample_batch, cap=cap, acap=acap,
                        rep=self.rep, method=self.method,
                        project=self.project, axes=self.axes,
                        narrow=self._narrow),
                mesh=self.mesh,
                in_specs=(spec, spec, spec, spec, P()),
                out_specs=(spec, P()),
                check_vma=False,
            ))
            self._batched_samplers[(cap, acap)] = fn
        return fn

    def _flatten_fn(self):
        if self._flattener is None:
            spec = P(self.axes)
            self._flattener = jax.jit(shard_map(
                partial(self._local_flatten, cap=self.flat_cap, rep=self.rep),
                mesh=self.mesh,
                in_specs=(spec, spec),
                out_specs=(spec, spec),
                check_vma=False,
            ))
        return self._flattener

    # -- execution -----------------------------------------------------------
    def sample_step(self, key, cap: Optional[int] = None,
                    acap: Optional[int] = None):
        """One independent global Poisson sample, left on device: the
        sharded JoinSample (leading dim = shards, shard-local positions)
        and the psum'd global count."""
        if self.stacked.p is None:
            raise ValueError("plan has no prob_var; use full_join")
        st = self.stacked
        return self._sampler(cap or self.cap, acap or self.acap)(
            st.shred, st.w, st.p, st.prefE, key)

    def _call_overrides(self, spec: Optional[DrawSpec], cap, acap):
        """Per-call ``DrawSpec`` under the explicit kwargs (kwargs win).
        Only the runtime fields apply — rep/narrow are baked into the
        shard_map executors at bind time."""
        if spec is not None:
            cap = cap or spec.cap
            acap = acap or spec.acap
        return cap, acap

    def sample(self, key, cap: Optional[int] = None,
               acap: Optional[int] = None,
               spec: Optional[DrawSpec] = None) -> JoinSample:
        """One independent Poisson sample, gathered to a flat JoinSample.

        Positions are rebased to *global* flat coordinates (shard base +
        local), so the result is drop-in comparable with the single-device
        plan's samples; ``count`` reflects the gathered tuples (on overflow
        the draw is invalid and flagged, exactly like the unsharded path).
        """
        cap, acap = self._call_overrides(spec, cap, acap)
        if self.stacked.p is None:
            raise ValueError("plan has no prob_var; use full_join")
        if self.join_size == 0:
            return executors.empty_sample(self.stacked.shred,
                                          cap or self.cap)
        smp, _total = self.sample_step(key, cap=cap, acap=acap)
        return self._gather(
            {v: np.asarray(a) for v, a in smp.columns.items()},
            np.asarray(smp.positions), np.asarray(smp.count),
            bool(np.asarray(smp.overflow).any()))

    def _gather(self, columns, positions, counts, overflow) -> JoinSample:
        """Compact one draw's per-shard (S, cap) buffers into a flat
        JoinSample, rebasing positions to global flat coordinates (shard
        base + local). Shared by the single-draw and batched paths, so
        their per-draw results are bit-identical."""
        lane_cap = positions.shape[1]
        counts = np.minimum(counts, lane_cap)
        rows = np.repeat(np.arange(self.num_shards), counts)
        lanes = np.concatenate(
            [np.arange(c) for c in counts]) if rows.size else \
            np.zeros((0,), np.int64)
        out_cap = lane_cap * self.num_shards
        cols = {}
        for v, a in columns.items():
            buf = np.zeros((out_cap,), a.dtype)
            buf[:rows.size] = a[rows, lanes]
            cols[v] = jnp.asarray(buf)
        posbuf = np.zeros((out_cap,), np.int64)
        posbuf[:rows.size] = positions[rows, lanes] + self._bases[rows]
        return JoinSample(
            cols, jnp.asarray(posbuf),
            jnp.asarray(np.int64(rows.size)),
            jnp.asarray(bool(overflow)),
        )

    def sample_batch(self, keys, cap: Optional[int] = None,
                     acap: Optional[int] = None,
                     spec: Optional[DrawSpec] = None) -> JoinSample:
        """``B`` independent global Poisson draws in one shard_map dispatch
        (DESIGN.md §10): vmap over split keys inside each shard, one psum
        for the global counts. The gathered result carries a leading batch
        axis and lane ``b`` is bit-identical to ``self.sample(keys[b])``
        (same per-shard draws, same gather). Keys are bucketed to powers of
        two exactly like the single-device batched path.
        """
        cap, acap = self._call_overrides(spec, cap, acap)
        if self.stacked.p is None:
            raise ValueError("plan has no prob_var; use full_join")
        batch = int(keys.shape[0])
        if self.join_size == 0:
            return executors.empty_sample_batch(self.stacked.shred,
                                                cap or self.cap, batch)
        kpad, _ = executors.pad_batch_keys(keys)
        st = self.stacked
        smp, _totals = self._batched_sampler(cap or self.cap,
                                             acap or self.acap)(
            st.shred, st.w, st.p, st.prefE, kpad)
        # Host gather per lane (padding lanes never gathered), then stack.
        columns = {v: np.asarray(a) for v, a in smp.columns.items()}
        positions = np.asarray(smp.positions)   # (S, Bp, cap)
        counts = np.asarray(smp.count)          # (S, Bp)
        overflow = np.asarray(smp.overflow)     # (S, Bp)
        lanes = [self._gather({v: a[:, b] for v, a in columns.items()},
                              positions[:, b], counts[:, b],
                              overflow[:, b].any())
                 for b in range(batch)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *lanes)

    def sample_auto(self, key, max_doublings: Optional[int] = None,
                    cap: Optional[int] = None,
                    acap: Optional[int] = None,
                    spec: Optional[DrawSpec] = None) -> JoinSample:
        """Redraw with doubled per-shard capacity until no shard overflows."""
        cap, acap = self._call_overrides(spec, cap, acap)
        return redraw_with_doubling(
            lambda c, a: self.sample(key, cap=c, acap=a),
            cap or self.cap, acap or self.acap,
            max_doublings if max_doublings is not None
            else self.policy.max_doublings)

    def full_join(self) -> Dict[str, jnp.ndarray]:
        """Yannakakis via the stacked index: per-shard flatten, gathered.

        Shard s's flatten is the global flatten restricted to root block s,
        so concatenating the valid prefixes reproduces the single-device
        ``flatten`` bit-for-bit, order included.
        """
        if self.join_size == 0:
            return {v: node.data.column(v)[0, :0]
                    for node in self.stacked.shred.root.nodes()
                    for v in node.owned}
        st = self.stacked
        cols, _ns = self._flatten_fn()(st.shred, st.prefE)
        out = {}
        for v, arr in cols.items():
            a = np.asarray(arr)
            out[v] = jnp.asarray(np.concatenate(
                [a[s, :self.join_sizes[s]] for s in range(self.num_shards)]))
        return out

    # -- dry-run support -----------------------------------------------------
    def lower_step(self):
        st = self.stacked
        key = jax.ShapeDtypeStruct((), jax.random.key(0).dtype)
        args = jax.eval_shape(lambda: (st.shred, st.w, st.p, st.prefE))
        return self._sampler(self.cap, self.acap).lower(*args, key)
