"""Compiled query plans: shred index + jitted executors + capacity metadata.

A ``CompiledPlan`` is the engine's unit of caching (DESIGN.md §7): the GYO
join tree has been run, the shred index built, and the sample executor
jitted. Everything data-dependent (the PRNG key, per-call capacity
overrides) stays a runtime argument, so one plan serves an unbounded stream
of independent sample draws and full-join flattens without rebuilding or
retracing.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import estimate, probe, sampling
from repro.core.jointree import JoinQuery
from repro.core.poisson import JoinSample
from repro.core.shred import Shred
from repro.core.yannakakis import flatten

from . import executors
from .capacity import CapacityPolicy, DEFAULT_POLICY
from .spec import DrawSpec

__all__ = ["CompiledPlan", "redraw_with_doubling"]


def redraw_with_doubling(draw, cap: int, acap: int, max_doublings: int):
    """The shared auto-capacity loop (host-side; DESIGN.md §7): call
    ``draw(cap, acap)`` until the returned sample reports no overflow,
    doubling both capacities between attempts. Used by the single-device
    ``CompiledPlan`` and the sharded ``ShardedPlan`` alike — overflow is
    always flagged, never silent."""
    for _ in range(max_doublings):
        s = draw(cap, acap)
        if not bool(s.overflow):
            return s
        cap *= 2
        acap *= 2
    raise RuntimeError("sample capacity still overflowing after doublings")


@dataclasses.dataclass
class CompiledPlan:
    """One (query fingerprint, spec identity) entry of the plan cache.

    ``spec`` is the *resolved* plan-identity ``DrawSpec``: concrete ``rep``
    (the representation the shred was built with), ``method``, ``project``
    and the ``narrow`` override — runtime fields (cap/acap) and routing
    fields (mesh/axes) are stripped by ``DrawSpec.plan_view`` before a plan
    is constructed. ``rep``/``method``/``project`` remain readable as
    properties for legacy callers.

    w / p / prefE are the root-level weight, probability, and exclusive
    prefix vectors (p is None for queries without ``prob_var`` — such plans
    serve full joins and uniform sampling only).
    """

    query: JoinQuery
    spec: DrawSpec
    shred: Shred
    policy: CapacityPolicy = DEFAULT_POLICY
    # ``rep_default`` (the concrete rep used when a call passes None) and
    # ``_narrow`` are derived per bound shred in ``_bind_shred`` — see
    # probe.select_rep (DESIGN.md §4).

    @property
    def rep(self) -> str:
        return self.spec.rep

    @property
    def method(self) -> str:
        return self.spec.method

    @property
    def project(self) -> Optional[Tuple[str, ...]]:
        return self.spec.project

    def __post_init__(self):
        self._default_cap = None
        self._arrival_cap = None
        self._bind_shred(self.shred)
        self._jit = executors.sample_executor(self.method, self.project)
        self._batched_jit = executors.batched_sample_executor(
            self.method, self.project)

    def _resolve_narrow(self, shred: Shred, auto_narrow: bool) -> bool:
        """Apply the spec's narrowing override to the auto verdict.
        Forcing ``narrow=True`` needs a packed (int32-safe) index — the
        arena's existence is the exactness proof (DESIGN.md §4)."""
        if self.spec.narrow is None:
            return auto_narrow
        if self.spec.narrow and shred.packed is None and shred.paged is None:
            raise ValueError(
                "DrawSpec(narrow=True) requires a packed int32 index "
                "(join < 2^31, no empty node); this shred has none")
        return self.spec.narrow

    def _bind_shred(self, shred: Shred) -> None:
        root = shred.root
        self.shred = shred
        self.w = root.weight
        self.prefE = shred.root_prefE
        # Host-cached once per bind: join_size is read on every draw's
        # capacity path, and int(device_scalar) is a blocking sync — per
        # dispatch it would stall the async prefetch ring (DESIGN.md §13).
        self._join_size = int(shred.join_size)
        # Executor rep + int32-narrowing selection (probe.select_rep,
        # DESIGN.md §4). Recomputed on every (re)bind: an upgraded index
        # may gain or lose its arena (int32 narrowing is per-snapshot).
        # Explicit per-call rep overrides still win in sample()/full_join().
        self.rep_default, auto_narrow = probe.select_rep(
            shred, "usr" if self.rep == "both" else self.rep)
        self._narrow = self._resolve_narrow(shred, auto_narrow)
        if self.query.prob_var is not None:
            if self.query.prob_var not in root.variables:
                raise AssertionError("build_plan must reroot prob_var to the root")
            self.p = root.data.column(self.query.prob_var)
            # Sticky capacities (DESIGN.md §11): recomputed from the new
            # (w, p) but never shrunk below a capacity already traced —
            # a delta that lowers E[k] keeps the cached trace instead of
            # recompiling for a marginally smaller buffer. Growth retraces
            # once, which is the price of not overflowing.
            self._default_cap = max(self._default_cap or 0,
                                    self.policy.sample_capacity(self.w, self.p))
            self._arrival_cap = max(self._arrival_cap or 0,
                                    self.policy.arrival_capacity(self.w, self.p))
            # Draw-kernel route (probe.select_draw, DESIGN.md §14), decided
            # once per bind like rep/narrow: the one-launch fused draw needs
            # its plan-bound operand vectors (eager — concrete arrays) and a
            # capable shred; recomputed on rebind because a delta can gain
            # or lose the packed arena.
            dparams = sampling.fused_draw_params(self.w, self.p, self.prefE)
            self._route = probe.select_draw(
                shred, dparams, method=self.method,
                n=self._join_size if self.method == "ptbern_flat" else 0,
                kernels=self.spec.kernels)
            self._dparams = dparams if self._route != "pernode" else None
        else:
            self.p = None
            self._route = "pernode"
            self._dparams = None

    def rebind_shred(self, shred: Shred) -> "CompiledPlan":
        """Swap in an (incrementally upgraded) index for a newer snapshot,
        keeping the jitted executors — and with them every cached trace.
        A delta that preserves array shapes therefore costs zero retraces
        on the next warm draw (DESIGN.md §11)."""
        self._bind_shred(shred)
        return self

    # -- capacity planning ---------------------------------------------------
    @property
    def join_size(self) -> int:
        return self._join_size

    def expected_k(self) -> float:
        return float(estimate.expected_sample_size(self.w, self.p))

    def default_capacity(self) -> int:
        return (self._default_cap if self._default_cap is not None
                else self.policy.sample_capacity(self.w, self.p))

    def arrival_capacity(self) -> int:
        return (self._arrival_cap if self._arrival_cap is not None
                else self.policy.arrival_capacity(self.w, self.p))

    # -- execution -----------------------------------------------------------
    def _call_overrides(self, spec: Optional[DrawSpec], cap, rep, acap):
        """Merge a per-call ``DrawSpec`` under the explicit kwargs (kwargs
        win — the same precedence as the engine's normalization shim)."""
        if spec is not None:
            cap = cap or spec.cap
            acap = acap or spec.acap
            rep = rep or (spec.rep if spec.rep != "both" else None)
        return cap, rep, acap

    def sample(self, key, cap: Optional[int] = None, rep: Optional[str] = None,
               acap: Optional[int] = None,
               spec: Optional[DrawSpec] = None) -> JoinSample:
        """One independent Poisson sample draw (fresh randomness per key)."""
        cap, rep, acap = self._call_overrides(spec, cap, rep, acap)
        if self.p is None:
            raise ValueError("plan has no prob_var; use uniform_sample/full_join")
        cap = cap or self.default_capacity()
        if self.join_size == 0:
            return executors.empty_sample(self.shred, cap)
        acap = acap or (self.arrival_capacity() if self.method == "exprace" else 0)
        n = self.join_size if self.method == "ptbern_flat" else 0
        # An explicit per-call rep pins the multi-launch per-node path: the
        # fused route has no rep (its kernel walks the packed arena) and
        # draws from its own stream, so honoring the rep request means
        # honoring the per-node sampler with it.
        route = "pernode" if rep else self._route
        return self._jit(self.shred, self.w, self.p, self.prefE, key, cap=cap,
                         rep=rep or self.rep_default, n=n, acap=acap,
                         narrow=self._narrow, route=route,
                         dparams=self._dparams if route != "pernode" else None)

    def sample_batch(self, keys, cap: Optional[int] = None,
                     rep: Optional[str] = None,
                     acap: Optional[int] = None,
                     spec: Optional[DrawSpec] = None) -> JoinSample:
        """``B`` independent Poisson draws in one dispatch (DESIGN.md §10).

        ``keys`` is a ``(B,)`` PRNG key vector (e.g. ``jax.random.split``);
        the result is a ``JoinSample`` whose leaves carry a leading batch
        axis — columns/positions ``(B, cap)``, count/overflow ``(B,)`` —
        and lane ``b`` is bit-identical to ``self.sample(keys[b])``. The
        key vector is padded to its power-of-two bucket before the
        dispatch, so warm batches of any size within a bucket never
        retrace; padding lanes are sliced off the result.
        """
        cap, rep, acap = self._call_overrides(spec, cap, rep, acap)
        if self.p is None:
            raise ValueError("plan has no prob_var; use uniform_sample/full_join")
        batch = int(keys.shape[0])
        cap = cap or self.default_capacity()
        if self.join_size == 0:
            return executors.empty_sample_batch(self.shred, cap, batch)
        acap = acap or (self.arrival_capacity() if self.method == "exprace" else 0)
        n = self.join_size if self.method == "ptbern_flat" else 0
        kpad, _ = executors.pad_batch_keys(keys)
        route = "pernode" if rep else self._route  # explicit rep pins pernode
        smp = self._batched_jit(self.shred, self.w, self.p, self.prefE, kpad,
                                cap=cap, rep=rep or self.rep_default, n=n,
                                acap=acap, narrow=self._narrow, route=route,
                                dparams=(self._dparams
                                         if route != "pernode" else None))
        if int(kpad.shape[0]) != batch:
            smp = jax.tree.map(lambda x: x[:batch], smp)
        return smp

    def sample_auto(self, key, max_doublings: Optional[int] = None,
                    cap: Optional[int] = None,
                    acap: Optional[int] = None,
                    spec: Optional[DrawSpec] = None) -> JoinSample:
        """Redraw with doubled capacity until no overflow (host loop).
        ``cap``/``acap`` override the policy-derived starting capacities."""
        cap, _, acap = self._call_overrides(spec, cap, None, acap)
        if max_doublings is None:
            max_doublings = self.policy.max_doublings
        cap = cap or self.default_capacity()
        acap = acap or (self.arrival_capacity() if self.method == "exprace"
                        else 0)
        return redraw_with_doubling(
            lambda c, a: self.sample(key, cap=c, acap=a),
            cap, acap, max_doublings)

    def uniform_sample(self, key, p: float, cap: Optional[int] = None,
                       method: str = "hybrid") -> JoinSample:
        """beta_p with a fixed uniform probability (paper §6.1)."""
        n = self.join_size
        if cap is None:
            cap = self.policy.uniform_capacity(n, p)
        ps = executors.uniform_positions_fn(method)(key, p, n, cap)
        pos = jnp.minimum(ps.positions, max(n - 1, 0))
        cols = probe.get(self.shred, pos, rep=self.rep_default)
        return JoinSample(cols, ps.positions, ps.count, ps.overflow)

    def full_join(self, rep: Optional[str] = None,
                  spec: Optional[DrawSpec] = None) -> Dict[str, jnp.ndarray]:
        """Yannakakis via the cached index: flatten mu* by bulk probe."""
        _, rep, _ = self._call_overrides(spec, None, rep, None)
        return flatten(self.shred, rep=rep or self.rep_default)
