"""Stable fingerprints for queries and database schemas (DESIGN.md §7).

The compiled-plan cache is keyed by *structure*, never by data values:

  * a query fingerprint covers the atoms (relation, alias, variables),
    ``prob_var``, and nothing else — two queries with the same shape share
    a join tree and therefore a plan;
  * a schema fingerprint covers relation names, column names, dtypes, and
    row counts — everything that determines traced array shapes/dtypes and
    hence whether a cached shred + jitted executor is reusable.

A ``QueryEngine`` binds a lineage of immutable ``Database`` *snapshots*
(DESIGN.md §11): cache keys carry the bound snapshot's ``version``, so an
``apply_delta`` step re-keys upgraded entries under the new version and
stale-version entries can never serve a newer snapshot. ``rebind()`` still
drops both caches wholesale — a rebound database is a new lineage, not a
new version. The schema fingerprint is exposed for callers keying *across*
engines (e.g. external plan registries, diagnostics). Mutating relation
*values* in place while keeping shapes is outside the contract (relations
are immutable pytrees — see DESIGN.md §7 for the cache-coherence policy).
"""
from __future__ import annotations

import hashlib
from typing import Optional, Tuple

from repro.core.database import Database
from repro.core.jointree import JoinQuery

__all__ = [
    "query_fingerprint", "schema_fingerprint", "mesh_fingerprint",
    "plan_key", "executor_key", "sharded_plan_key", "sharded_executor_key",
    "draw_fingerprint",
]


def _digest(payload: str) -> str:
    return hashlib.sha1(payload.encode()).hexdigest()[:16]


def query_fingerprint(query: JoinQuery) -> str:
    """Structure-only fingerprint of a join query (atom order matters: it is
    the GYO input order and fixes the canonical flatten order)."""
    atoms = tuple(
        (a.relation, a.alias or "", a.variables) for a in query.atoms
    )
    return _digest(repr((atoms, query.prob_var)))


def schema_fingerprint(db: Database) -> str:
    """Shape/dtype fingerprint of the database instance (no data values)."""
    rels = []
    for name in sorted(db.relations):
        rel = db.relations[name]
        cols = tuple(
            (c, str(rel.columns[c].dtype), int(rel.columns[c].shape[0]))
            for c in sorted(rel.columns)
        )
        rels.append((name, db.schemas.get(name, ()), cols))
    return _digest(repr(tuple(rels)))


def plan_key(query: JoinQuery, rep: str, version: int = 0) -> Tuple[str, str, int]:
    """Cache key of a shred index: query structure x representation x the
    bound snapshot version (DESIGN.md §11)."""
    return (query_fingerprint(query), rep, version)


def executor_key(
    query: JoinQuery, rep: str, method: str,
    project: Optional[Tuple[str, ...]], version: int = 0,
    narrow: Optional[bool] = None, kernels: str = "auto",
) -> Tuple:
    """Cache key of a compiled plan: the shred key plus everything baked
    statically into the jitted executor. ``narrow`` is the DrawSpec's
    int32-narrowing override (None = auto) and ``kernels`` its draw-kernel
    route request — both change the traced executors, so they are plan
    identity like rep/method/project. The bound snapshot version stays the
    LAST element (``apply_delta`` re-keys entries by slicing it off)."""
    return (query_fingerprint(query), rep, method, project, narrow, kernels,
            version)


def mesh_fingerprint(mesh) -> Tuple[Tuple[str, int], ...]:
    """Shape-only fingerprint of a device mesh: ordered (axis, size) pairs.

    Two meshes with the same axis names and sizes share stacked shreds and
    sharded plans (DESIGN.md §8). Device *identity* is deliberately not
    keyed — a same-shape mesh over different devices revalidates nothing
    (the cached shard_map dispatches on its original mesh), matching the
    structure-only philosophy of the other fingerprints.
    """
    return tuple((a, int(mesh.shape[a])) for a in mesh.axis_names)


def sharded_plan_key(query: JoinQuery, rep: str, mesh,
                     num_shards: int, version: int = 0) -> Tuple:
    """Cache key of a *stacked* shred index: the single-device shred key
    extended with the mesh shape and shard count."""
    return (query_fingerprint(query), rep, mesh_fingerprint(mesh),
            num_shards, version)


def sharded_executor_key(
    query: JoinQuery, rep: str, method: str,
    project: Optional[Tuple[str, ...]], mesh, axes: Tuple[str, ...],
    version: int = 0, narrow: Optional[bool] = None, kernels: str = "auto",
) -> Tuple:
    """Cache key of a sharded compiled plan: everything static in the
    shard_map executors, including the partition axes and the DrawSpec's
    narrowing and kernel-route overrides (version last, as in
    ``executor_key``)."""
    return (query_fingerprint(query), rep, method, project, narrow, kernels,
            mesh_fingerprint(mesh), tuple(axes), version)


def draw_fingerprint(spec) -> Tuple:
    """Structure-only fingerprint of a ``DrawSpec``: hashable, stable, and
    mesh-identity-free (the mesh contributes its shape via
    ``mesh_fingerprint``, matching the philosophy of the other keys).
    Used by callers keying draw configurations across engines."""
    return (spec.rep, spec.method, spec.project, spec.narrow, spec.kernels,
            spec.cap, spec.acap,
            mesh_fingerprint(spec.mesh) if spec.mesh is not None else None,
            spec.axes)
