"""``DrawSpec`` — one frozen description of how a query executes.

Before the API consolidation (DESIGN.md §13) the engine's entry points
scattered the same knobs across keyword arguments: ``compile`` took
``rep/method/project``, ``sample`` added ``cap/acap/mesh/axes``,
``sample_batch`` repeated all of them, and the plan layers
(``CompiledPlan``, ``ShardedPlan``) re-declared the subset they bake into
executors. ``DrawSpec`` is the single value object for all of it:

  * **frozen + hashable** — a spec can key dictionaries, land in plan-cache
    keys, and be shared across threads;
  * **structure vs runtime** — ``rep``/``method``/``project``/``narrow``
    are *plan identity* (baked into jitted executors, part of the plan
    cache key via ``fingerprint.executor_key``); ``cap``/``acap`` are
    *runtime statics* (each distinct value is one cached trace inside a
    plan, never a new plan); ``mesh``/``axes`` select the sharded path
    (part of the *sharded* plan key via ``mesh_fingerprint``);
  * **None = inherit** — every field defaults to "use the engine/plan
    default", so ``DrawSpec()`` is exactly the legacy no-kwargs call.

Every engine entry point accepts ``spec=``; the legacy kwargs keep working
through one normalization shim (``QueryEngine._resolve_spec``), where an
explicitly passed kwarg overrides the corresponding spec field.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["DrawSpec", "merge_spec"]

_REPS = (None, "csr", "usr", "both")
_METHODS = ("exprace", "ptbern_flat")
_KERNELS = ("auto", "fused", "paged", "pernode", "reference")


@dataclasses.dataclass(frozen=True)
class DrawSpec:
    """How a draw (or full join) executes. All fields optional; ``None``
    means "inherit the engine/plan default".

    rep      index representation (``csr``/``usr``/``both``); None lets the
             plan pick (engine default, upgraded to the fused kernel when
             available — an explicit rep always wins, DESIGN.md §4).
    method   position-sampling method for Poisson draws (``exprace`` or
             ``ptbern_flat``; default exprace).
    project  bag-projection attributes A for beta_y(pi_A(Q^)) queries.
    cap      sample capacity override (static shape; one cached trace per
             value inside a plan — never a new plan).
    acap     EXPRACE arrival-scratch capacity override.
    narrow   int32-narrowed sampler searches: None = auto (on iff the index
             packed an int32 arena and the backend prefers Pallas), True =
             force on (requires a packed index), False = force off.
    kernels  draw-kernel route (DESIGN.md §14/§15): ``auto`` = the
             one-launch fused draw iff capable and the active
             ``KernelPolicy`` prefers it, degrading to the *paged* route
             (sample launch + page-streamed walk) when only the index's
             pages fit the VMEM budget, else the multi-launch per-node
             path; ``fused`` = require the fused kernel (raises at bind if
             unavailable); ``paged`` = require the paged route (raises if
             the index is not in the paged regime); ``reference`` = the
             fused pipeline as plain traced jnp (the bit-identity oracle);
             ``pernode`` = always the F64 multi-launch path (the precision
             arbiter).
    mesh     device mesh: route through the sharded plan (DESIGN.md §8).
    axes     mesh axes to partition the root over (None = shard planner).
    """

    rep: Optional[str] = None
    method: str = "exprace"
    project: Optional[Tuple[str, ...]] = None
    cap: Optional[int] = None
    acap: Optional[int] = None
    narrow: Optional[bool] = None
    kernels: str = "auto"
    mesh: Optional[object] = None
    axes: Optional[Tuple[str, ...]] = None

    def __post_init__(self):
        # Normalize sequence-typed fields so equal specs hash equal.
        if self.project is not None and not isinstance(self.project, tuple):
            object.__setattr__(self, "project", tuple(self.project))
        if self.axes is not None and not isinstance(self.axes, tuple):
            object.__setattr__(self, "axes", tuple(self.axes))
        if self.rep not in _REPS:
            raise ValueError(f"rep must be csr|usr|both|None, got {self.rep!r}")
        if self.method not in _METHODS:
            raise ValueError(
                f"method must be one of {_METHODS}, got {self.method!r}")
        if self.kernels not in _KERNELS:
            raise ValueError(
                f"kernels must be one of {_KERNELS}, got {self.kernels!r}")

    # -- derived views -------------------------------------------------------
    def plan_view(self, rep: str) -> "DrawSpec":
        """The spec a ``CompiledPlan`` stores: plan-identity fields only,
        with ``rep`` pinned to the concrete representation the index was
        built with. Runtime fields (cap/acap) and routing fields
        (mesh/axes) are stripped — they never define plan identity."""
        return DrawSpec(rep=rep, method=self.method, project=self.project,
                        narrow=self.narrow, kernels=self.kernels)

    def with_overrides(self, **kw) -> "DrawSpec":
        """``dataclasses.replace`` restricted to non-None overrides —
        the merge rule of the legacy-kwargs shim."""
        return merge_spec(self, **kw)


def merge_spec(spec: Optional[DrawSpec], **kw) -> DrawSpec:
    """The one normalization rule behind every entry point's legacy
    kwargs: start from ``spec`` (or an empty ``DrawSpec``) and overlay
    every kwarg that was explicitly passed (i.e. is not None)."""
    base = spec if spec is not None else DrawSpec()
    over = {k: v for k, v in kw.items() if v is not None}
    return dataclasses.replace(base, **over) if over else base
