"""Jitted executors behind the engine's entry points (DESIGN.md §7).

One executor = one trace unit. The Poisson-sample executor is the former
``core/poisson.py`` ``_sample_jit`` moved here unchanged, so samples drawn
through the engine are bit-identical to the pre-engine ``PoissonSampler``
under the same PRNG key. ``jax.jit`` caches traces per static
``(cap, rep, n, acap, narrow)`` tuple; the engine's plan cache keeps the jitted
callable (and thus its trace cache) alive across queries with the same
fingerprint, which is what makes warm calls retrace-free.

The batched executor (DESIGN.md §10) is ``jax.vmap`` of the same trace
unit over the PRNG key only — index, weights, and prefix vectors are
broadcast. Because every sampler derives its randomness solely from its
key, lane ``b`` of the batched draw is bit-identical to a single draw
under ``keys[b]`` (asserted in ``tests/test_batched_engine.py``). Batch
size is a *shape*, not a static argument: callers bucket the key vector
to a power of two (``pad_batch_keys``) so warm batches of any size within
a bucket reuse one cached trace.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import probe, sampling
from repro.core.poisson import JoinSample
from repro.core.shred import Shred

__all__ = [
    "sample_executor", "batched_sample_executor", "empty_sample",
    "empty_sample_batch", "uniform_positions_fn", "bucket_size",
    "pad_batch_keys",
]


def _sample_jit(
    shred: Shred, w, p, prefE, key, cap: int, rep: str, method: str, n: int = 0,
    acap: int = 0, project=None, narrow: bool = False,
    route: str = "pernode", dparams=None,
) -> JoinSample:
    if route in ("fused", "reference"):
        # One-launch draw (kernels/fused_draw.py, DESIGN.md §14): positions
        # AND per-node rows come out of a single kernel (or its traced-jnp
        # reference twin); only the column gather remains outside.
        node_rows, ps = probe.draw_fused(
            shred, dparams, key, method=method, cap=cap, acap=acap, n=n,
            reference=(route == "reference"))
        cols = probe.gather_columns(shred, node_rows)
    elif route == "paged":
        # Paged rung (DESIGN.md §15): one sampling launch, then the walk
        # streamed page by page — same draw_core stream as the fused route.
        node_rows, ps = probe.draw_paged(
            shred, dparams, key, method=method, cap=cap, acap=acap, n=n)
        cols = probe.gather_columns(shred, node_rows)
    elif method == "exprace":
        ps = sampling.exprace_positions(key, w, p, prefE, cap,
                                        arrival_cap=acap, narrow=narrow)
    elif method == "ptbern_flat":  # n is the static, concrete join size
        ps = sampling.pt_bern_flat_positions(key, p, prefE, n, cap)
    else:
        raise ValueError(f"unknown jit sampling method {method!r}")
    if route not in ("fused", "reference", "paged"):
        pos = jnp.minimum(ps.positions, jnp.maximum(prefE[-1] - 1, 0))  # clamp
        cols = probe.get(shred, pos, rep=rep)
    if project is not None:
        cols = {v: c for v, c in cols.items() if v in project}
    return JoinSample(cols, ps.positions, ps.count, ps.overflow)


def sample_executor(method: str, project: Optional[tuple]):
    """The jitted Poisson-sample executor with (method, project) baked in.

    ``cap``/``rep``/``n``/``acap``/``route`` are static: each distinct
    combination is one cached trace on the returned callable. ``dparams``
    (the plan-bound fused-draw operand vectors) is a pytree operand —
    ``None`` on the per-node route.
    """
    return jax.jit(
        partial(_sample_jit, method=method, project=project),
        static_argnames=("cap", "rep", "n", "acap", "narrow", "route"),
    )


def _batched_sample_jit(
    shred: Shred, w, p, prefE, keys, cap: int, rep: str, method: str,
    n: int = 0, acap: int = 0, project=None, narrow: bool = False,
    route: str = "pernode", dparams=None,
) -> JoinSample:
    one = partial(_sample_jit, shred, w, p, prefE, cap=cap, rep=rep,
                  method=method, n=n, acap=acap, project=project,
                  narrow=narrow, route=route, dparams=dparams)
    return jax.vmap(one)(keys)


def batched_sample_executor(method: str, project: Optional[tuple]):
    """The jitted multi-draw executor: one dispatch serves ``B`` independent
    Poisson draws into ``(B, cap)`` buffers with per-draw counts/overflow.

    Statics are identical to ``sample_executor``; the batch size enters only
    through ``keys.shape[0]``, so each key-bucket size is one cached trace.
    Only ``keys`` is vmapped — the index, parameter vectors, and fused-draw
    operands are closed over and broadcast, so the fused route batches as a
    vmapped single-kernel launch.
    """
    return jax.jit(
        partial(_batched_sample_jit, method=method, project=project),
        static_argnames=("cap", "rep", "n", "acap", "narrow", "route"),
    )


def bucket_size(b: int) -> int:
    """The power-of-two batch bucket ``b`` lands in (DESIGN.md §10)."""
    if b < 1:
        raise ValueError(f"batch size must be >= 1, got {b}")
    return 1 if b <= 1 else 1 << (b - 1).bit_length()


def pad_batch_keys(keys) -> Tuple[jnp.ndarray, int]:
    """Pad a ``(B,)`` key vector to its power-of-two bucket by repeating the
    last key; returns ``(padded_keys, B)``. Padding lanes are discarded by
    the caller after the dispatch — they never reach the result."""
    b = int(keys.shape[0])
    bp = bucket_size(b)
    if bp == b:
        return keys, b
    return keys[jnp.minimum(jnp.arange(bp), b - 1)], b


def empty_sample(shred: Shred, cap: int) -> JoinSample:
    """An all-padding sample (used when |Q(db)| == 0: nothing to probe)."""
    cols = {v: jnp.zeros((cap,), node.data.column(v).dtype)
            for node in shred.root.nodes() for v in node.owned}
    return JoinSample(cols, jnp.zeros((cap,), jnp.int64),
                      jnp.zeros((), jnp.int64), jnp.zeros((), jnp.bool_))


def empty_sample_batch(shred: Shred, cap: int, batch: int) -> JoinSample:
    """The batched all-padding sample: ``empty_sample`` broadcast to B lanes."""
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (batch,) + x.shape),
                        empty_sample(shred, cap))


def uniform_positions_fn(method: str):
    """Position sampler for uniform beta_p (paper §6.1 BERN/GEO/BINOM/HYBRID)."""
    return {
        "bern": sampling.bern_positions,
        "geo": sampling.geo_positions,
        "binom": sampling.binom_positions,
        "hybrid": sampling.hybrid_positions,
    }[method]
