"""Jitted executors behind the engine's entry points (DESIGN.md §7).

One executor = one trace unit. The Poisson-sample executor is the former
``core/poisson.py`` ``_sample_jit`` moved here unchanged, so samples drawn
through the engine are bit-identical to the pre-engine ``PoissonSampler``
under the same PRNG key. ``jax.jit`` caches traces per static
``(cap, rep, n, acap)`` tuple; the engine's plan cache keeps the jitted
callable (and thus its trace cache) alive across queries with the same
fingerprint, which is what makes warm calls retrace-free.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import probe, sampling
from repro.core.poisson import JoinSample
from repro.core.shred import Shred

__all__ = ["sample_executor", "empty_sample", "uniform_positions_fn"]


def _sample_jit(
    shred: Shred, w, p, prefE, key, cap: int, rep: str, method: str, n: int = 0,
    acap: int = 0, project=None,
) -> JoinSample:
    if method == "exprace":
        ps = sampling.exprace_positions(key, w, p, prefE, cap, arrival_cap=acap)
    elif method == "ptbern_flat":  # n is the static, concrete join size
        ps = sampling.pt_bern_flat_positions(key, p, prefE, n, cap)
    else:
        raise ValueError(f"unknown jit sampling method {method!r}")
    pos = jnp.minimum(ps.positions, jnp.maximum(prefE[-1] - 1, 0))  # clamp pads
    cols = probe.get(shred, pos, rep=rep)
    if project is not None:
        cols = {v: c for v, c in cols.items() if v in project}
    return JoinSample(cols, ps.positions, ps.count, ps.overflow)


def sample_executor(method: str, project: Optional[tuple]):
    """The jitted Poisson-sample executor with (method, project) baked in.

    ``cap``/``rep``/``n``/``acap`` are static: each distinct combination is
    one cached trace on the returned callable.
    """
    return jax.jit(
        partial(_sample_jit, method=method, project=project),
        static_argnames=("cap", "rep", "n", "acap"),
    )


def empty_sample(shred: Shred, cap: int) -> JoinSample:
    """An all-padding sample (used when |Q(db)| == 0: nothing to probe)."""
    cols = {v: jnp.zeros((cap,), node.data.column(v).dtype)
            for node in shred.root.nodes() for v in node.owned}
    return JoinSample(cols, jnp.zeros((cap,), jnp.int64),
                      jnp.zeros((), jnp.int64), jnp.zeros((), jnp.bool_))


def uniform_positions_fn(method: str):
    """Position sampler for uniform beta_p (paper §6.1 BERN/GEO/BINOM/HYBRID)."""
    return {
        "bern": sampling.bern_positions,
        "geo": sampling.geo_positions,
        "binom": sampling.binom_positions,
        "hybrid": sampling.hybrid_positions,
    }[method]
