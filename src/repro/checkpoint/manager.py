"""Checkpoint manager: the restart half of fault tolerance.

Guarantees:
  * atomicity — writes go to ``<dir>/tmp.<step>/`` and are renamed into
    place only after the manifest (with per-file sha256) is fsynced; a crash
    mid-save can never corrupt the latest checkpoint;
  * integrity — restore verifies checksums and falls back to the previous
    step on mismatch (torn disk, partial copy);
  * bounded disk — keep_n older checkpoints are GC'd after a successful save;
  * async — save() can hand off to a writer thread so the train loop only
    blocks on jax.device_get (double-buffered host copy);
  * multi-host discipline — each process writes only its own shard files
    (``shard<process_index>``), so saves scale with hosts and restore maps
    shard files back to local devices. (Single-process in this container.)

Storage is plain ``np.savez`` of the flattened pytree (keypath -> array) —
no external checkpoint dependency.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten(template, flat: dict):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        arr = flat[key]
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_n = keep_n
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save -----------------------------------------------------------------
    def save(self, step: int, tree: Any, block: bool = False):
        self.wait()  # one outstanding save at a time; surfaces prior errors
        host_tree = jax.device_get(tree)  # snapshot before training continues

        def work():
            try:
                self._write(step, host_tree)
            except BaseException as e:  # noqa: BLE001
                self._error = e

        if self.async_save and not block:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
            if self._error:
                raise self._error

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            err, self._error = self._error, None
            raise err

    def _write(self, step: int, host_tree):
        pid = jax.process_index()
        tmp = self.dir / f"tmp.{step}.{pid}"
        final = self.dir / f"step_{step:010d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat = _flatten(host_tree)
        shard_file = tmp / f"shard{pid}.npz"
        np.savez(shard_file, **flat)
        digest = hashlib.sha256(shard_file.read_bytes()).hexdigest()
        manifest = {
            "step": step,
            "time": time.time(),
            "process": pid,
            "files": {shard_file.name: digest},
            "keys": sorted(flat.keys()),
        }
        mpath = tmp / f"manifest{pid}.json"
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        final.mkdir(exist_ok=True)
        for item in tmp.iterdir():
            os.replace(item, final / item.name)  # atomic within a filesystem
        shutil.rmtree(tmp, ignore_errors=True)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: max(len(steps) - self.keep_n, 0)]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    def _verify(self, step: int) -> bool:
        d = self.dir / f"step_{step:010d}"
        pid = jax.process_index()
        mpath = d / f"manifest{pid}.json"
        if not mpath.exists():
            return False
        manifest = json.loads(mpath.read_text())
        for fname, digest in manifest["files"].items():
            f = d / fname
            if not f.exists() or hashlib.sha256(f.read_bytes()).hexdigest() != digest:
                return False
        return True

    def restore(self, template: Any, step: Optional[int] = None
                ) -> Tuple[Optional[int], Any]:
        """Restore the given (or latest valid) step; (None, template) if none.
        Corrupt checkpoints are skipped with a warning — the crash-recovery
        path."""
        steps = [step] if step is not None else list(reversed(self.all_steps()))
        pid = jax.process_index()
        for s in steps:
            if not self._verify(s):
                print(f"[checkpoint] step {s} failed integrity check; skipping")
                continue
            d = self.dir / f"step_{s:010d}"
            with np.load(d / f"shard{pid}.npz") as z:
                flat = {k: z[k] for k in z.files}
            return s, _unflatten(template, flat)
        return None, template
