"""Fault-tolerant checkpointing (atomic, content-checked, keep-N, async)."""
from .manager import CheckpointManager  # noqa: F401
