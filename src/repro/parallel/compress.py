"""Int8 gradient compression with error feedback for the DP all-reduce.

At 1000+ nodes the inter-pod (DCN) gradient all-reduce is the scaling wall;
8-bit quantization cuts that traffic 4x vs f32 (2x vs bf16). Per-tensor
symmetric scaling; the quantization residual is carried in an error-feedback
buffer so the *accumulated* update stays unbiased (Seide et al. / EF-SGD) —
plain quantized SGD diverges, EF provably recovers full-precision rates.

Usage inside a shard_map'd train step:
    g_q, scale = compress_int8(g + err)
    g_sum = jax.lax.psum(g_q.astype(jnp.int32), axis)   # int32 ring sum
    g_hat = g_sum.astype(jnp.float32) * scale / n_shards
    err   = (g + err) - decompress_int8(g_q, scale)
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.compat import axis_size


def compress_int8(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum_grads(grads, err, axis: str):
    """EF-compressed gradient psum over a mesh axis (use under shard_map).

    grads/err: pytrees of equal structure. Returns (mean_grads, new_err).
    Scales are psum-maxed so every shard dequantizes consistently.
    """
    n = axis_size(axis)

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(corrected)), 1e-12) / 127.0
        scale = jax.lax.pmax(scale, axis)  # shared scale across shards
        q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
        new_e = corrected - q.astype(jnp.float32) * scale
        s = jax.lax.psum(q.astype(jnp.int32), axis)
        mean = s.astype(jnp.float32) * scale / n
        return mean.astype(g.dtype), new_e

    flat_g, tree = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tree.unflatten([o[0] for o in out]),
            tree.unflatten([o[1] for o in out]))
