"""Pipeline parallelism: GPipe-style microbatch schedule under shard_map.

Layers are split into ``n_stages`` contiguous stages, one per device along a
"stage" mesh axis. Microbatches march through the pipeline with
``collective_permute`` handing activations to the next stage each tick; a
tick runs every stage in parallel on its resident microbatch (SPMD), so a
forward pass takes ``n_micro + n_stages - 1`` ticks with the classic GPipe
bubble fraction (S-1)/(M+S-1).

Scope: forward pipeline (inference / evaluation, or as the building block
for fwd+bwd interleaving). The assigned dry-run cells are covered by
DP×TP×FSDP×SP (DESIGN.md §6); this module is the >2-pod extension path and
is correctness-tested on real multi-device meshes (tests/test_pipeline.py).

Mechanics: every stage holds ONLY its own stage's parameters
(stage-sharded pytree, leading axis = stage). At tick t, stage s computes on
the microbatch that entered the pipe at t-s; a stage is "warming" or
"draining" otherwise — handled by masking (compute runs, results ignored),
the standard SPMD-uniform formulation.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map


def pipeline_forward(
    stage_fn: Callable,     # (stage_params, x) -> y, applied by every stage
    stage_params,           # pytree, leaves (n_stages, ...) — stage-sharded
    batch: jnp.ndarray,     # (n_micro, micro, ...) microbatched input
    mesh: Mesh,
    axis: str = "stage",
):
    """Run the GPipe forward schedule. Returns (n_micro, micro, ...) outputs."""
    n_stages = mesh.shape[axis]
    n_micro = batch.shape[0]
    ticks = n_micro + n_stages - 1

    def local(params, batch):
        params = jax.tree.map(lambda x: x[0], params)   # this stage's slice
        s = jax.lax.axis_index(axis)

        feats = batch.shape[2:]
        buf_in = jnp.zeros(batch.shape[1:], batch.dtype)     # resident input
        outs = jnp.zeros_like(batch)                          # stage-0-homed

        def tick(carry, t):
            buf_in, outs = carry
            # stage 0 ingests microbatch t (if any remain)
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            fresh = batch[mb_idx]
            x = jnp.where(s == 0, jnp.where(t < n_micro, fresh, 0 * fresh), buf_in)
            y = stage_fn(params, x)
            # hand activation to the next stage; the last stage's output
            # rings back to stage 0, which records it into ``outs``.
            y_next = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            done_idx = t - (n_stages - 1)
            record = jnp.logical_and(s == 0, done_idx >= 0)
            outs = jnp.where(
                record,
                outs.at[jnp.clip(done_idx, 0, n_micro - 1)].set(y_next),
                outs)
            return (y_next, outs), None

        (_, outs), _ = jax.lax.scan(tick, (buf_in, outs), jnp.arange(ticks))
        # broadcast stage 0's recorded outputs to every stage (uniform out)
        outs = jax.lax.psum(jnp.where(s == 0, outs, jnp.zeros_like(outs)), axis)
        return outs[None]  # re-add stage dim for out_specs

    spec_p = jax.tree.map(lambda _: P(axis), stage_params)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(spec_p, P()),          # batch replicated across stages
        out_specs=P(axis),
        check_vma=False,
    )
    return fn(stage_params, batch)[0]


def reference_forward(stage_fn, stage_params, batch):
    """Oracle: apply all stages sequentially (no pipeline)."""
    n_stages = jax.tree.leaves(stage_params)[0].shape[0]

    def apply_all(x):
        for s in range(n_stages):
            p = jax.tree.map(lambda a: a[s], stage_params)
            x = stage_fn(p, x)
        return x

    return jax.vmap(apply_all)(batch)
