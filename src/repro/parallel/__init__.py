"""Distributed-optimization utilities: gradient compression, pipeline stages."""
from .compress import compress_int8, decompress_int8, compressed_psum_grads  # noqa: F401
