"""Training-data pipeline built on Poisson sampling over acyclic joins.

This is where the paper becomes a *training-framework feature* (DESIGN.md
§2): the corpus is a relational database — e.g.

    Doc(doc, clust)                 one row per document
    ClusterQuality(clust, p)        data-curation probability per cluster

and each training step draws an independent Poisson sample of the join
``beta_p(Doc |><| ClusterQuality)`` — quality-weighted data selection with
*fresh randomness every step* (the Monte-Carlo pattern of the paper's EpiQL
engine), without materializing the joined corpus. The shredded index is
built once; a step costs O(k log |db|).

Determinism/resume: batch(step) depends only on (seed, step), so restarts
resume mid-epoch exactly (checkpoint stores just the step counter), and
elastic re-sharding cannot skew the sampling distribution.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Atom, Database, JoinQuery, PoissonSampler


def make_corpus_db(
    n_docs: int,
    n_clusters: int,
    seq_len: int,
    vocab: int,
    seed: int = 0,
    mean_quality: float = 0.3,
) -> Database:
    """A synthetic relational corpus: documents with cluster-level quality
    scores (stand-in for dedup/quality pipelines)."""
    rng = np.random.default_rng(seed)
    return Database.from_columns({
        "Doc": {
            "doc": np.arange(n_docs),
            "clust": rng.integers(0, n_clusters, n_docs),
        },
        "ClusterQuality": {
            "clust": np.arange(n_clusters),
            "p": np.clip(rng.beta(2, 2 / mean_quality - 2, n_clusters), 0, 1),
        },
        # token payloads live beside the relations (column-store style)
        "_tokens": {"flat": rng.integers(0, vocab, n_docs * seq_len)},
    })


class PoissonJoinSource:
    """Batches of token sequences selected by Poisson sampling over a join.

    Each step: sample doc ids via Index-and-Probe, take the first
    ``batch`` valid ids (wrapping deterministically if the sample is small),
    gather their token rows.
    """

    def __init__(self, db: Database, seq_len: int, batch: int, seed: int = 0,
                 query: Optional[JoinQuery] = None, doc_var: str = "doc"):
        self.query = query or JoinQuery(
            (Atom.of("ClusterQuality", "clust", "p"),
             Atom.of("Doc", "doc", "clust")),
            prob_var="p")
        self.sampler = PoissonSampler(db, self.query)
        n_docs = db.relations["Doc"].num_rows
        self.tokens = db.relations["_tokens"].column("flat").reshape(n_docs, seq_len)
        self.seq_len = seq_len
        self.batch = batch
        self.doc_var = doc_var
        self.seed = seed
        self.key = jax.random.key(seed)
        cap = self.sampler.default_capacity()
        self.cap = max(cap, ((batch + 127) // 128) * 128)

    def batch_at(self, step: int) -> Dict[str, jnp.ndarray]:
        """Deterministic in (seed, step) — the resume/elasticity contract."""
        key = jax.random.fold_in(self.key, step)
        sample = self.sampler.sample(key, cap=self.cap)
        docs = sample.columns[self.doc_var]
        count = jnp.maximum(sample.count, 1)
        idx = jnp.arange(self.batch) % count          # wrap if sample < batch
        chosen = jnp.take(docs, idx)
        toks = jnp.take(self.tokens, chosen, axis=0).astype(jnp.int32)
        return {
            "tokens": toks[:, :-1],
            "targets": toks[:, 1:],
            "sampled_k": sample.count,
        }

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class SyntheticLMSource:
    """Pure-random token batches (model-only benchmarking), deterministic in
    (seed, step)."""

    def __init__(self, vocab: int, seq_len: int, batch: int, seed: int = 0,
                 extra_specs: Optional[Dict] = None):
        self.vocab, self.seq_len, self.batch = vocab, seq_len, batch
        self.key = jax.random.key(seed)
        self.extra_specs = extra_specs or {}

    def batch_at(self, step: int) -> Dict[str, jnp.ndarray]:
        key = jax.random.fold_in(self.key, step)
        toks = jax.random.randint(key, (self.batch, self.seq_len + 1), 0,
                                  self.vocab, jnp.int32)
        out = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
        for name, spec in self.extra_specs.items():
            out[name] = jax.random.normal(jax.random.fold_in(key, 1),
                                          spec.shape, spec.dtype)
        return out


class Prefetcher:
    """Background-thread prefetch (double buffering) over a step-indexed
    source; safe to restart from any step."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        s = self.step
        while not self._stop.is_set():
            try:
                self.q.put((s, self.source.batch_at(s)), timeout=0.5)
                s += 1
            except queue.Full:
                continue

    def __next__(self):
        s, b = self.q.get()
        return s, b

    def stop(self):
        self._stop.set()
