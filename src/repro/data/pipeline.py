"""Training-data pipeline built on Poisson sampling over acyclic joins.

This is where the paper becomes a *training-framework feature* (DESIGN.md
§2, §13): the corpus is a relational database — e.g.

    Doc(doc, clust)                 one row per document
    ClusterQuality(clust, p)        data-curation probability per cluster

and each training step draws an independent Poisson sample of the join
``beta_p(Doc |><| ClusterQuality)`` — quality-weighted data selection with
*fresh randomness every step* (the Monte-Carlo pattern of the paper's EpiQL
engine), without materializing the joined corpus. The shredded index is
built once; a step costs O(k log |db|).

The source is engine-native (DESIGN.md §13): draws go through
``QueryEngine.sample_batch`` — a *window* of W consecutive steps is one
jitted dispatch filling a device-resident ring of ``(W, cap)`` buffers, and
token rows are gathered on device, so the steady path performs no host
round-trip per step. The corpus is *live*: ``DeltaBatch`` events scheduled
at step barriers advance the engine via ``apply_delta`` (warm caches
upgraded in place, DESIGN.md §11), prefetch windows are clipped at the
barriers so no window straddles two snapshots, and every batch records the
``db_version`` it was drawn at.

Determinism/resume: batch(step) depends only on (seed, step, schedule) —
per-step keys are ``fold_in(key(seed), step)``, window boundaries are a
pure function of the step and the (static) delta schedule, and lane ``b``
of a batched draw is bit-identical to the single draw under ``keys[b]`` —
so restarts resume mid-epoch exactly (checkpoint stores the step counter
and the data version), and elastic re-sharding cannot skew the sampling
distribution.
"""
from __future__ import annotations

import bisect
import dataclasses
import queue
import threading
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Atom, Database, DeltaBatch, JoinQuery
from repro.engine import DrawSpec, QueryEngine

__all__ = [
    "make_corpus_db", "corpus_delta", "PoissonJoinSource",
    "SyntheticLMSource", "Prefetcher",
]


def make_corpus_db(
    n_docs: int,
    n_clusters: int,
    seq_len: int,
    vocab: int,
    seed: int = 0,
    mean_quality: float = 0.3,
) -> Database:
    """A synthetic relational corpus: documents with cluster-level quality
    scores (stand-in for dedup/quality pipelines)."""
    rng = np.random.default_rng(seed)
    return Database.from_columns({
        "Doc": {
            "doc": np.arange(n_docs),
            "clust": rng.integers(0, n_clusters, n_docs),
        },
        "ClusterQuality": {
            "clust": np.arange(n_clusters),
            "p": np.clip(rng.beta(2, 2 / mean_quality - 2, n_clusters), 0, 1),
        },
        # token payloads live beside the relations (column-store style)
        "_tokens": {"flat": rng.integers(0, vocab, n_docs * seq_len)},
    })


def corpus_delta(db: Database, seq_len: int, vocab: int, *,
                 insert: int = 0, retire: Sequence[int] = (),
                 seed: int = 0) -> DeltaBatch:
    """A live-corpus change set against ``db``: ``insert`` fresh documents
    and/or ``retire`` existing ``Doc`` rows (row indices into the current
    snapshot).

    The doc-id = token-row invariant is preserved the cheap way: retiring a
    document deletes its ``Doc`` row only (its token row is orphaned, never
    re-indexed — surviving doc ids stay valid), while inserts append to
    both ``Doc`` and ``_tokens`` with ids continuing the token-row count.
    """
    if not insert and not len(retire):
        raise ValueError("corpus_delta: nothing to insert or retire")
    rng = np.random.default_rng(seed)
    n_tok_rows = db.relations["_tokens"].column("flat").shape[0] // seq_len
    n_clusters = db.relations["ClusterQuality"].num_rows
    per_rel: Dict[str, dict] = {}
    doc_spec: Dict[str, object] = {}
    if len(retire):
        doc_spec["delete"] = np.asarray(retire, np.int64)
    if insert:
        doc_spec["insert"] = {
            "doc": n_tok_rows + np.arange(insert),
            "clust": rng.integers(0, n_clusters, insert),
        }
        per_rel["_tokens"] = {
            "insert": {"flat": rng.integers(0, vocab, insert * seq_len)},
        }
    per_rel["Doc"] = doc_spec
    return DeltaBatch.of(**per_rel)


@dataclasses.dataclass
class _Window:
    """One prefetched dispatch: W consecutive steps of one snapshot, resident
    on device. ``lanes[step - start]`` serves ``batch_at(step)``: the gather
    jit unstacks per-lane outputs (tokens, targets, doc_ids, count), so a
    served step is a python tuple lookup — no per-step device dispatch."""

    start: int
    end: int
    version: int
    lanes: Tuple            # W x (tokens, targets, doc_ids, count)
    wrapped: jnp.ndarray    # (W,) bool: draw undershot the batch size


class PoissonJoinSource:
    """Batches of token sequences selected by Poisson sampling over a join,
    drawn through ``QueryEngine.sample_batch`` (DESIGN.md §13).

    Each step: take lane ``step - start`` of the step's prefetch window —
    one batched engine dispatch per ``window`` steps — wrap the sampled doc
    ids deterministically if the draw undershot ``batch`` (counted in
    ``wrapped``, never silent), and gather token rows on device.

    ``deltas`` is a step-aligned schedule of ``(step, DeltaBatch)`` events:
    the batch at ``step`` (and every later one) is drawn at the post-delta
    snapshot, applied via ``engine.apply_delta`` so warm caches upgrade in
    place. Windows are clipped at the barriers — no window straddles two
    versions — and every batch carries the ``db_version`` it was drawn at.
    Steps must be consumed in non-decreasing version order (the engine
    moves forward); a fresh source replays the schedule from the base
    snapshot, which is what makes kill/resume bit-exact.
    """

    def __init__(self, db: Optional[Database], seq_len: int, batch: int,
                 seed: int = 0, query: Optional[JoinQuery] = None,
                 doc_var: str = "doc", engine: Optional[QueryEngine] = None,
                 window: int = 8, depth: int = 2,
                 deltas: Sequence[Tuple[int, DeltaBatch]] = (),
                 spec: Optional[DrawSpec] = None):
        if engine is None:
            if db is None:
                raise ValueError("pass a Database or a QueryEngine")
            engine = QueryEngine(db)
        self.engine = engine
        self.query = query or JoinQuery(
            (Atom.of("ClusterQuality", "clust", "p"),
             Atom.of("Doc", "doc", "clust")),
            prob_var="p")
        self.seq_len = seq_len
        self.batch = batch
        self.doc_var = doc_var
        self.seed = seed
        self.key = jax.random.key(seed)
        # jitted once: a bare vmap would retrace the fold_in every window
        # (~1ms of host work per dispatch on CPU)
        self._fold_keys = jax.jit(
            jax.vmap(lambda s: jax.random.fold_in(self.key, s)))
        self.window = int(window)
        if self.window < 1:
            raise ValueError("window must be >= 1")
        self._depth = max(int(depth), 1)
        self._ring: Dict[int, _Window] = {}

        # Delta schedule: sorted events; version_at(step) = base + #{e <= step}.
        self._events: List[Tuple[int, DeltaBatch]] = sorted(
            ((int(s), d) for s, d in deltas), key=lambda e: e[0])
        self._event_steps = [s for s, _ in self._events]
        self._applied = 0
        self.base_version = self.engine.db.version

        # Capacity is resolved ONCE at construction and frozen into the spec:
        # cap is a traced static shape, so a resumed source re-deriving it
        # from a later snapshot would silently change batch contents. The
        # 128-row rounding keeps the gather lane-aligned; a draw that still
        # undershoots ``batch`` wraps deterministically and increments
        # ``wrapped`` (DESIGN.md §13) rather than wrapping silently.
        plan = self.engine.compile(self.query, spec)
        base = spec or DrawSpec()
        cap = base.cap or plan.default_capacity()
        self.cap = max(cap, ((batch + 127) // 128) * 128)
        self._spec = base.with_overrides(cap=self.cap)
        self._bind_tokens()

        # Telemetry without steady-path syncs: overflow accumulates on
        # device once per window; wrap flags are recorded per served lane
        # as (device array, lane) refs — zero dispatches per step — and
        # drained when the ``wrapped`` property is read.
        self._wrapped_host = 0
        self._served_wrapped: List[Tuple[jnp.ndarray, int]] = []
        self._overflow_dev = jnp.zeros((), jnp.int32)

        def _gather(tokens, docs, counts):
            # docs: (W, cap), counts: (W,) -> per-lane wrap + token gather.
            cnt = jnp.clip(counts, 1, docs.shape[1])[:, None]
            idx = jnp.arange(batch)[None, :] % cnt            # (W, batch)
            chosen = jnp.take_along_axis(docs, idx, axis=1)   # (W, batch)
            toks = jnp.take(tokens, chosen, axis=0).astype(jnp.int32)
            wrapped = counts < batch
            # Unstack inside the jit: 4W output leaves, ONE dispatch —
            # batch_at never pays a per-step slice dispatch.
            lanes = tuple(
                (toks[i, :, :-1], toks[i, :, 1:], chosen[i], counts[i])
                for i in range(counts.shape[0]))
            return lanes, wrapped
        self._gather = jax.jit(_gather)

    # -- live-corpus schedule ------------------------------------------------
    def _bind_tokens(self) -> None:
        n_rows = self.engine.db.relations["_tokens"].column("flat").shape[0]
        if n_rows % self.seq_len:
            raise ValueError("_tokens length is not a multiple of seq_len")
        self.tokens = self.engine.db.relations["_tokens"].column(
            "flat").reshape(-1, self.seq_len)

    def version_at(self, step: int) -> int:
        """The snapshot version the batch at ``step`` is drawn at — a pure
        function of the schedule (the resume contract's second half)."""
        return self.base_version + bisect.bisect_right(self._event_steps, step)

    def _advance_to(self, step: int) -> None:
        """Apply every scheduled delta with event step <= ``step``."""
        want = bisect.bisect_right(self._event_steps, step)
        if want < self._applied:
            raise ValueError(
                f"source already advanced past step {step} (version "
                f"{self.base_version + self._applied} > "
                f"{self.version_at(step)}); build a fresh source to rewind")
        while self._applied < want:
            _, delta = self._events[self._applied]
            self.engine.apply_delta(delta)
            self._applied += 1
            self._bind_tokens()

    def _window_bounds(self, step: int) -> Tuple[int, int]:
        """The prefetch window containing ``step``: the aligned ``window``
        grid, clipped at delta barriers so one window = one snapshot."""
        s0 = (step // self.window) * self.window
        end = s0 + self.window
        i = bisect.bisect_right(self._event_steps, step)
        if i > 0:
            s0 = max(s0, self._event_steps[i - 1])
        if i < len(self._event_steps):
            end = min(end, self._event_steps[i])
        return s0, end

    # -- draw path -----------------------------------------------------------
    def _dispatch(self, s0: int, end: int) -> _Window:
        self._advance_to(s0)
        keys = self._fold_keys(jnp.arange(s0, end))
        smp = self.engine.sample_batch(self.query, keys, self._spec)
        lanes, wrapped = self._gather(
            self.tokens, smp.columns[self.doc_var], smp.count)
        self._overflow_dev = self._overflow_dev + jnp.sum(
            smp.overflow.astype(jnp.int32))
        win = _Window(s0, end, self.engine.db.version, lanes, wrapped)
        self._ring[s0] = win
        return win

    def _window_for(self, step: int) -> _Window:
        s0, end = self._window_bounds(step)
        for k in [k for k, w in self._ring.items() if w.end <= step]:
            del self._ring[k]
        win = self._ring.get(s0)
        if win is None:
            win = self._dispatch(s0, end)
        # Eagerly dispatch the next window: JAX's async dispatch makes this
        # the ring's second slot — the device fills it while the host trains
        # on the current one, with no prefetch thread required.
        if self._depth > 1 and len(self._ring) < self._depth:
            n0, nend = self._window_bounds(end)
            if n0 not in self._ring:
                self._dispatch(n0, nend)
        return win

    def batch_at(self, step: int) -> Dict[str, jnp.ndarray]:
        """Deterministic in (seed, step, schedule) — the resume/elasticity
        contract. ``db_version`` is a host int (checkpoint metadata);
        everything else stays on device, and the steady path issues no
        per-step device dispatch at all (lanes were unstacked at window
        dispatch)."""
        win = self._window_for(step)
        toks, targets, docs, count = win.lanes[step - win.start]
        self._served_wrapped.append((win.wrapped, step - win.start))
        return {
            "tokens": toks,
            "targets": targets,
            "sampled_k": count,
            "doc_ids": docs,
            "db_version": win.version,
        }

    # -- telemetry -----------------------------------------------------------
    @property
    def wrapped(self) -> int:
        """Served batches whose draw undershot ``batch`` (doc ids repeated
        by deterministic wrap). Reading drains the per-lane records (the
        only device sync on this counter's path)."""
        if self._served_wrapped:
            for flags, i in self._served_wrapped:
                self._wrapped_host += int(np.asarray(flags)[i])
            self._served_wrapped.clear()
        return self._wrapped_host

    @property
    def overflows(self) -> int:
        """Draw lanes that overflowed ``cap`` across all dispatches."""
        return int(self._overflow_dev)

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class SyntheticLMSource:
    """Pure-random token batches (model-only benchmarking), deterministic in
    (seed, step)."""

    def __init__(self, vocab: int, seq_len: int, batch: int, seed: int = 0,
                 extra_specs: Optional[Dict] = None):
        self.vocab, self.seq_len, self.batch = vocab, seq_len, batch
        self.key = jax.random.key(seed)
        self.extra_specs = extra_specs or {}

    def batch_at(self, step: int) -> Dict[str, jnp.ndarray]:
        key = jax.random.fold_in(self.key, step)
        toks = jax.random.randint(key, (self.batch, self.seq_len + 1), 0,
                                  self.vocab, jnp.int32)
        out = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
        for name, spec in self.extra_specs.items():
            out[name] = jax.random.normal(jax.random.fold_in(key, 1),
                                          spec.shape, spec.dtype)
        return out


class Prefetcher:
    """Background-thread prefetch (double buffering) over a step-indexed
    source; safe to restart from any step."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        s = self.step
        while not self._stop.is_set():
            try:
                self.q.put((s, self.source.batch_at(s)), timeout=0.5)
                s += 1
            except queue.Full:
                continue

    def __next__(self):
        s, b = self.q.get()
        return s, b

    def stop(self):
        self._stop.set()
