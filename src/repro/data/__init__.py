"""Data pipeline: deterministic, resumable, with the paper's Poisson-join
sampler as a first-class, engine-native batch source (DESIGN.md §13)."""
from .pipeline import (  # noqa: F401
    PoissonJoinSource, Prefetcher, SyntheticLMSource, corpus_delta,
    make_corpus_db,
)

__all__ = [
    "PoissonJoinSource", "Prefetcher", "SyntheticLMSource", "corpus_delta",
    "make_corpus_db",
]
