"""Data pipeline: deterministic, resumable, with the paper's Poisson-join
sampler as a first-class batch source."""
from .pipeline import PoissonJoinSource, SyntheticLMSource, make_corpus_db  # noqa: F401
