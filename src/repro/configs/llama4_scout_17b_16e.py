"""llama4-scout-17b-a16e [moe]: 48L d=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 + shared expert
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    vocab=202_048, d_model=5_120, n_layers=48, n_heads=40, n_kv_heads=8,
    d_ff=8_192, head_dim=128, pattern=("moe",),
    n_experts=16, topk=1, moe_dff=8_192, shared_expert_dff=8_192,
    rope_theta=500_000.0, param_dtype="bfloat16",
    remat="segments", grad_accum=8, opt_factored=True,
    attn_seq_shard=True, attn_probs_bf16=True,  # G=5, kv=8 (§Perf H2 fleet-wide)
    moe_ep=True,  # §Perf H3b: E=16 == model width, 1 expert/shard
)
