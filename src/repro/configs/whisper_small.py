"""whisper-small [audio]: enc-dec, 12L+12L d=768 12H d_ff=3072 vocab=51865
[arXiv:2212.04356; unverified].
The conv/audio frontend is a STUB: input_specs() supplies precomputed frame
embeddings (B, 1500, 768) — the output shape of whisper's conv stack."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    vocab=51_865, d_model=768, n_layers=12, n_heads=12, n_kv_heads=12,
    d_ff=3_072, head_dim=64, pattern=("cross",),
    enc_layers=12, enc_d_model=768, enc_heads=12, enc_d_ff=3_072,
    n_memory_tokens=1_500,
    mlp_gated=False,
    # attn_seq_shard measured a small net regression here (train 0.90->1.17s,
    # prefill 0.36->0.40s: S and d too small to amortize reshards) — left off
)
