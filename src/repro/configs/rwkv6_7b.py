"""rwkv6-7b [ssm]: 32L d=4096 attention-free, d_ff=14336 vocab=65536 —
Finch, data-dependent decay [arXiv:2404.05892; hf].
Channel mix is RWKV's 3.5x (= 14336 = 7*4096/2, matching the assigned d_ff
exactly). Sub-quadratic: long_500k RUNS for this arch."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    vocab=65_536, d_model=4_096, n_layers=32, n_heads=64, n_kv_heads=64,
    d_ff=14_336, head_dim=64, pattern=("rwkv",), rwkv_head_dim=64,
    subquadratic=True,
)
