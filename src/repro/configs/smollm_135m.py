"""smollm-135m [dense]: 30L d=576 9H (GQA kv=3) d_ff=1536 vocab=49152.
Llama-architecture small model [hf:HuggingFaceTB/SmolLM-135M; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    vocab=49_152, d_model=576, n_layers=30, n_heads=9, n_kv_heads=3,
    d_ff=1_536, head_dim=64, pattern=("dense",), tie_embeddings=True,
    rope_theta=10_000.0,
)
