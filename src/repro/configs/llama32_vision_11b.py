"""llama-3.2-vision-11b [vlm]: 40L d=4096 32H (GQA kv=8) d_ff=14336
vocab=128256, cross-attn image layers every 5th [hf:meta-llama/
Llama-3.2-11B-Vision; unverified].
The vision frontend is a STUB: input_specs() supplies precomputed patch
embeddings (B, 6400, d) = 4 tiles x 1600 patches, already projected."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    vocab=128_256, d_model=4_096, n_layers=40, n_heads=32, n_kv_heads=8,
    d_ff=14_336, head_dim=128,
    pattern=("dense", "dense", "dense", "dense", "cross"),
    n_memory_tokens=6_400, rope_theta=500_000.0,
    # attn_seq_shard measured counterproductive here (train_4k 8.0->8.9s:
    # batch-heavy shape, H1-attempt-1 lesson) — left off; see EXPERIMENTS §Perf
)
