"""starcoder2-7b [dense]: 32L d=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.
GQA + RoPE [arXiv:2402.19173; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    vocab=49_152, d_model=4_608, n_layers=32, n_heads=36, n_kv_heads=4,
    d_ff=18_432, head_dim=128, pattern=("dense",),
    rope_theta=1_000_000.0,
    mlp_gated=False,
    attn_seq_shard=True,  # §Perf H2: kv=4 < 16-way TP => seq-parallel attention
)
