"""gemma3-1b [dense]: 26L d=1152 4H (GQA kv=1) d_ff=6912 vocab=262144.
5:1 local:global attention, 128k rope [hf:google/gemma-3-1b-pt; unverified].
head_dim=256 (gemma3 uses wide heads: H*hd != d_model, handled natively).
The 26-layer 5:1 schedule is expressed as a single repeat of the full-depth
pattern (4 x [5 local + 1 global] + [local, global])."""
from repro.models.config import ModelConfig

_GROUP = ("local", "local", "local", "local", "local", "dense")

CONFIG = ModelConfig(
    name="gemma3-1b",
    vocab=262_144, d_model=1_152, n_layers=26, n_heads=4, n_kv_heads=1,
    d_ff=6_912, head_dim=256, tie_embeddings=True,
    pattern=_GROUP * 4 + ("local", "dense"),
    window=512, rope_theta=1_000_000.0,
    attn_seq_shard=True,  # kv=1 < TP width: seq-parallel attention (§Perf H2 fleet-wide)
)
