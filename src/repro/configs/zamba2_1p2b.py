"""zamba2-1.2b [hybrid]: 38L d=2048 32H (kv=32) d_ff=8192 ssm_state=64 —
Mamba2 backbone + SHARED attention block [arXiv:2411.15242; hf].
Pattern: 18 mamba blocks + 1 shared-attn per repeat, 2 repeats = 38 layers;
the attention params are tied across repeats (zamba's defining trick).
Sub-quadratic: long_500k RUNS (shared attn uses a 4096 sliding window at
500k — deviation noted in DESIGN.md §9)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    vocab=32_000, d_model=2_048, n_layers=38, n_heads=32, n_kv_heads=32,
    d_ff=8_192, head_dim=64,
    pattern=("mamba",) * 18 + ("shared_attn",),
    ssm_state=64, ssm_heads=32, ssm_expand=2,
    window=4_096, subquadratic=True, mamba_mlp=False,
)
