"""llama3-405b [dense]: 126L d=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.
GQA, 128k vocab [arXiv:2407.21783; unverified]. bf16 params + full remat so
train_4k fits a single 256-chip v5e pod (DESIGN.md §5)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    vocab=128_256, d_model=16_384, n_layers=126, n_heads=128, n_kv_heads=8,
    d_ff=53_248, head_dim=128, pattern=("dense",),
    rope_theta=500_000.0, param_dtype="bfloat16",
    remat="segments", grad_accum=4, opt_factored=True,
    attn_head_shard=True, attn_probs_bf16=True,  # §Perf H1: G=16==TP width
)
