"""olmoe-1b-7b [moe]: 16L d=2048 16H (kv=16) d_ff=1024 vocab=50304,
MoE 64 experts top-8 [arXiv:2409.02060; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    vocab=50_304, d_model=2_048, n_layers=16, n_heads=16, n_kv_heads=16,
    d_ff=1_024, head_dim=128, pattern=("moe",),
    n_experts=64, topk=8, moe_dff=1_024,
    rope_theta=10_000.0, moe_ep=True,  # §Perf H3b experiment
)
