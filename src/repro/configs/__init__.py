"""Architecture registry: the 10 assigned configs + input-shape sets.

``get_config(name)`` returns the ModelConfig; ``input_specs(cfg, shape)``
returns ShapeDtypeStruct stand-ins for every model input of that
(arch x shape) cell — weak-type-correct, shardable, no device allocation.

Shapes (assignment):
    train_4k     seq 4,096   global_batch 256   -> train_step
    prefill_32k  seq 32,768  global_batch 32    -> prefill (forward)
    decode_32k   seq 32,768  global_batch 128   -> serve_step (1 new token)
    long_500k    seq 524,288 global_batch 1     -> serve_step; SSM/hybrid only
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

ARCHS = (
    "smollm_135m",
    "starcoder2_7b",
    "gemma3_1b",
    "llama3_405b",
    "llama32_vision_11b",
    "llama4_scout_17b_16e",
    "olmoe_1b_7b",
    "whisper_small",
    "rwkv6_7b",
    "zamba2_1p2b",
)

# assignment ids -> module names
ALIASES = {
    "smollm-135m": "smollm_135m",
    "starcoder2-7b": "starcoder2_7b",
    "gemma3-1b": "gemma3_1b",
    "llama3-405b": "llama3_405b",
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_16e",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "whisper-small": "whisper_small",
    "rwkv6-7b": "rwkv6_7b",
    "zamba2-1.2b": "zamba2_1p2b",
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str       # train | prefill | decode
    seq: int
    batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{ALIASES.get(name, name)}")
    return mod.CONFIG


def reduced(cfg: ModelConfig) -> ModelConfig:
    """A tiny config of the same family (identical pattern block types, GQA
    grouping preserved) for CPU smoke tests — the assignment's reduced-config
    rule; the FULL config is exercised only via the dry-run."""
    # compress the pattern: keep one instance of each distinct block type,
    # in first-appearance order, to preserve the family structure.
    seen, pat = set(), []
    for bt in cfg.pattern:
        if bt not in seen:
            seen.add(bt)
            pat.append(bt)
    pattern = tuple(pat)
    group = max(cfg.n_heads // max(cfg.n_kv_heads, 1), 1)
    n_heads = min(cfg.n_heads, 4) * 1
    n_kv = max(n_heads // group, 1)
    n_heads = n_kv * group
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        vocab=256,
        d_model=32 * max(n_heads // 4, 1),
        n_layers=2 * len(pattern),
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=16,
        d_ff=64,
        pattern=pattern,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        topk=min(cfg.topk, 2) if cfg.topk else 0,
        moe_dff=32 if cfg.moe_dff else 0,
        shared_expert_dff=32 if cfg.shared_expert_dff else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_heads=4 if cfg.ssm_state else 0,
        rwkv_head_dim=16,
        enc_layers=2 if cfg.enc_layers else 0,
        enc_d_model=32 * max(n_heads // 4, 1) if cfg.enc_layers else 0,
        enc_heads=n_heads if cfg.enc_layers else 0,
        enc_d_ff=64 if cfg.enc_layers else 0,
        n_memory_tokens=8 if cfg.n_memory_tokens else 0,
        window=min(cfg.window, 8) if cfg.window else 0,
        attn_chunk=16,
        attn_seq_shard=False,
        attn_head_shard=False,
        attn_probs_bf16=False,
        residual_seq_shard=False,
        grad_accum=1,
        remat="none",
        param_dtype="float32",
        compute_dtype="float32",
    )


def shape_applicable(cfg: ModelConfig, shape: str) -> Optional[str]:
    """None if the (arch x shape) cell runs; else the documented skip reason."""
    if shape == "long_500k" and not cfg.subquadratic:
        return ("full-attention architecture: 500k dense KV/O(S^2) attention "
                "out of assignment scope (DESIGN.md §Arch-applicability)")
    return None


def input_specs(cfg: ModelConfig, shape: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for every model input of the cell (no allocation)."""
    sp = SHAPES[shape]
    B, S = sp.batch, sp.seq
    i32 = jnp.int32
    f32 = jnp.float32
    if sp.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "targets": jax.ShapeDtypeStruct((B, S), i32),
        }
        if cfg.n_memory_tokens and not cfg.has_encoder:
            specs["memory"] = jax.ShapeDtypeStruct((B, cfg.n_memory_tokens, cfg.d_model), f32)
        if cfg.has_encoder:
            specs["frames"] = jax.ShapeDtypeStruct((B, cfg.n_memory_tokens, cfg.enc_d_model), f32)
        return specs
    if sp.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.n_memory_tokens and not cfg.has_encoder:
            specs["memory"] = jax.ShapeDtypeStruct((B, cfg.n_memory_tokens, cfg.d_model), f32)
        if cfg.has_encoder:
            specs["frames"] = jax.ShapeDtypeStruct((B, cfg.n_memory_tokens, cfg.enc_d_model), f32)
        return specs
    # decode: one new token against a seq-long cache (built via eval_shape)
    return {
        "tokens": jax.ShapeDtypeStruct((B, 1), i32),
        "cur": jax.ShapeDtypeStruct((), i32),
    }
