"""Kernel tile autotuner (DESIGN.md §15): resolution ladder, sweep winner
selection (deterministic injected timer — no wall clocks in the unit leg),
table write/round-trip, and the ``--check`` schema gate CI runs as the
tune-smoke step."""
import json

import pytest

from repro import config
from repro.kernels import autotune
from repro.kernels.autotune import (
    KERNELS, TABLE_VERSION, bucket_of, check_table, default_entry,
    load_table, sweep, tile_for,
)


def test_bucket_of_power_of_two_edges():
    assert bucket_of(0) == "p0"
    assert bucket_of(1) == "p0"
    assert bucket_of(2) == "p1"
    assert bucket_of(512) == "p9"
    assert bucket_of(513) == "p10"
    assert bucket_of(1 << 14) == "p14"


def test_sweep_picks_fastest_candidate():
    """A 2-candidate sweep with an injected deterministic timer: the sweep
    must pick the candidate the timer reports fastest, never measure more
    thunks than candidates, and bucket the winner by problem size."""
    times = iter([250.0, 100.0])  # second candidate wins
    calls = []

    def timer(fn):
        calls.append(fn)
        return next(times)

    winners = sweep(["bsearch_probe"], timer=timer,
                    candidates={"bsearch_probe": (4, 8)},
                    sizes={"bsearch_probe": (128,)},
                    out=lambda s: None)
    assert winners == {"bsearch_probe": {"p7": 8}}
    assert len(calls) == 2


def test_sweep_write_roundtrip_and_check(tmp_path):
    path = tmp_path / "TUNE_TABLE.json"
    seq = iter([50.0, 75.0])
    sweep(["bsearch_probe"], timer=lambda fn: next(seq),
          candidates={"bsearch_probe": (4, 8)},
          sizes={"bsearch_probe": (128,)},
          entry_key="faux/devkind", write=True, path=path,
          out=lambda s: None)
    table = load_table(path)
    assert table["version"] == TABLE_VERSION
    assert table["entries"]["faux/devkind"]["bsearch_probe"] == {"p7": 4}
    # The mandatory default entry rides along on first write and covers
    # every registered kernel, so the schema gate passes.
    assert set(table["entries"]["default"]) == set(KERNELS)
    assert check_table(path, out=lambda s: None) == 0


class TestTileForLadder:
    @pytest.fixture()
    def table(self, tmp_path, monkeypatch):
        path = tmp_path / "TUNE_TABLE.json"
        path.write_text(json.dumps({
            "version": TABLE_VERSION,
            "entries": {
                "default": default_entry(),
                config.backend_key(): {
                    "tree_probe": {"p7": 32},
                    "flash_prefill": {"*": [128, 256]},
                },
            },
        }))
        monkeypatch.setattr(autotune, "TABLE_PATH", path)
        return path

    def test_backend_bucket_row_wins(self, table):
        assert tile_for("tree_probe", 100) == 32  # p7 row

    def test_falls_to_default_entry_outside_bucket(self, table):
        # No p20 row and no '*' under the backend entry: the default
        # entry's any-size row (the builtin constant) resolves.
        assert tile_for("tree_probe", 1 << 20) == KERNELS["tree_probe"].default

    def test_tuple_values_fold_back_from_json(self, table):
        assert tile_for("flash_prefill", 1024) == (128, 256)

    def test_policy_override_wins(self, table):
        pol = config.KernelPolicy(tile_overrides=(("tree_probe", 4),))
        assert tile_for("tree_probe", 100, pol) == 4

    def test_tuned_false_skips_table(self, table):
        pol = config.KernelPolicy(tuned=False)
        assert tile_for("tree_probe", 100, pol) == KERNELS["tree_probe"].default

    def test_missing_table_resolves_builtin(self, tmp_path, monkeypatch):
        monkeypatch.setattr(autotune, "TABLE_PATH", tmp_path / "absent.json")
        for name, spec in KERNELS.items():
            assert tile_for(name, 1000) == spec.default


class TestCheckTable:
    def _write(self, tmp_path, obj):
        path = tmp_path / "TUNE_TABLE.json"
        path.write_text(json.dumps(obj) if not isinstance(obj, str) else obj)
        return path

    def _ok_table(self):
        return {"version": TABLE_VERSION, "entries": {"default": default_entry()}}

    def test_committed_table_passes(self):
        # The real checked-in table is what CI gates (tune-smoke step).
        assert check_table(out=lambda s: None) == 0

    def test_missing_file_fails(self, tmp_path):
        assert check_table(tmp_path / "absent.json", out=lambda s: None) == 1

    def test_invalid_json_fails(self, tmp_path):
        assert check_table(self._write(tmp_path, "{nope"),
                           out=lambda s: None) == 1

    def test_version_drift_fails(self, tmp_path):
        t = self._ok_table()
        t["version"] = TABLE_VERSION + 1
        assert check_table(self._write(tmp_path, t), out=lambda s: None) == 1

    def test_stale_kernel_name_fails(self, tmp_path):
        t = self._ok_table()
        t["entries"]["cpu/cpu"] = {"renamed_kernel": {"*": 8}}
        assert check_table(self._write(tmp_path, t), out=lambda s: None) == 1

    def test_missing_default_row_fails(self, tmp_path):
        t = self._ok_table()
        del t["entries"]["default"]["tree_probe"]
        assert check_table(self._write(tmp_path, t), out=lambda s: None) == 1

    def test_bad_bucket_fails(self, tmp_path):
        t = self._ok_table()
        t["entries"]["cpu/cpu"] = {"tree_probe": {"page7": 8}}
        assert check_table(self._write(tmp_path, t), out=lambda s: None) == 1

    def test_unparseable_value_fails(self, tmp_path):
        t = self._ok_table()
        t["entries"]["cpu/cpu"] = {"flash_prefill": {"*": "wide"}}
        assert check_table(self._write(tmp_path, t), out=lambda s: None) == 1
