"""The serve-loop micro-batcher (DESIGN.md §10): flush triggers and
per-request result routing.

(a) flush-on-max-batch: the arrival that fills the batch triggers the
    flush; earlier arrivals stay queued;
(b) flush-on-deadline: ``poll()`` flushes iff the oldest pending request
    has waited ``max_wait_ms`` (driven by an injected fake clock — no
    sleeps, no wall-clock flakiness);
(c) routing: a mixed-shape queue is served as one batched dispatch per
    query fingerprint, every request gets exactly its own draw (equal to
    the single-draw engine under the same seed), and the shapes share
    one engine plan cache across flushes.
"""
import numpy as np
import jax
import pytest

from repro.core import Atom, Database, JoinQuery
from repro.engine import QueryEngine
from repro.launch.serve import (
    JoinSampleRequest, MicroBatcher, serve_join_samples,
)


@pytest.fixture(scope="module")
def db():
    rng = np.random.default_rng(11)
    return Database.from_columns({
        "R": {"x": rng.integers(0, 12, 90), "p": rng.random(90) * 0.5},
        "S": {"x": rng.integers(0, 12, 140), "y": rng.integers(0, 9, 140)},
        "T": {"y": rng.integers(0, 9, 60), "z": np.arange(60)},
    })


@pytest.fixture(scope="module")
def q3(db):
    return JoinQuery((Atom.of("R", "x", "p"), Atom.of("S", "x", "y"),
                      Atom.of("T", "y", "z")), prob_var="p")


@pytest.fixture(scope="module")
def q2(db):
    return JoinQuery((Atom.of("R", "x", "p"), Atom.of("S", "x", "y")),
                     prob_var="p")


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# -- (a) flush on max_batch --------------------------------------------------

def test_flush_on_max_batch(db, q3):
    clock = FakeClock()
    mb = MicroBatcher(QueryEngine(db), max_batch=4, max_wait_ms=1e9,
                      clock=clock)
    done = []
    for i in range(3):
        assert mb.submit(JoinSampleRequest(query=q3, seed=i)) == []
    assert len(mb.pending) == 3 and mb.flushes == 0
    done = mb.submit(JoinSampleRequest(query=q3, seed=3))  # fills the batch
    assert len(done) == 4 and mb.pending == [] and mb.flushes == 1
    assert all(r.count is not None and r.latency_s is not None for r in done)
    # next arrival starts a fresh batch
    assert mb.submit(JoinSampleRequest(query=q3, seed=4)) == []
    assert len(mb.pending) == 1


# -- (b) flush on deadline ---------------------------------------------------

def test_flush_on_deadline(db, q3):
    clock = FakeClock()
    mb = MicroBatcher(QueryEngine(db), max_batch=100, max_wait_ms=5.0,
                      clock=clock)
    mb.submit(JoinSampleRequest(query=q3, seed=0))
    clock.t = 0.004  # 4ms < 5ms deadline
    assert mb.poll() == [] and len(mb.pending) == 1
    mb.submit(JoinSampleRequest(query=q3, seed=1))  # younger request
    clock.t = 0.0051  # oldest has now waited past the deadline
    done = mb.poll()
    assert len(done) == 2 and mb.pending == []  # deadline drains everything
    assert mb.flushes == 1
    # deadline is measured from the OLDEST pending request
    assert done[0].latency_s == pytest.approx(0.0051)
    assert mb.poll() == []  # empty queue: poll is a no-op


# -- (c) routing: mixed shapes, one plan cache -------------------------------

def test_mixed_shapes_one_dispatch_each_and_exact_routing(db, q3, q2):
    engine = QueryEngine(db)
    mb = MicroBatcher(engine, max_batch=8, max_wait_ms=1e9, clock=FakeClock())
    reqs = [JoinSampleRequest(query=q3 if i % 2 == 0 else q2, seed=10 + i)
            for i in range(8)]
    done = []
    for r in reqs:
        done += mb.submit(r)
    assert len(done) == 8 and mb.flushes == 1
    assert mb.dispatches == 2  # one batched dispatch per query shape
    # Every request got exactly its own independent draw.
    ref_engine = QueryEngine(db)
    for r in reqs:
        want = ref_engine.sample(r.query, jax.random.key(r.seed))
        assert r.count == int(want.count), (r.seed, r.count, int(want.count))
        assert r.overflow == bool(want.overflow)
    # Both shapes live in ONE shared plan cache: two plans, two shreds.
    assert engine.stats.plan_misses == 2
    assert engine.stats.shred_builds == 2
    # A second mixed flush is fully warm — zero rebuilds, plans hit.
    st0 = engine.stats.snapshot()
    for i in range(8):
        mb.submit(JoinSampleRequest(query=q3 if i % 2 else q2, seed=50 + i))
    assert mb.flushes == 2
    assert engine.stats.plan_misses == st0.plan_misses
    assert engine.stats.shred_builds == st0.shred_builds
    assert engine.stats.plan_hits >= st0.plan_hits + 2


def test_serve_join_samples_drains_everything(db, q3, q2):
    engine = QueryEngine(db)
    reqs = [JoinSampleRequest(query=q3 if i % 3 else q2, seed=i)
            for i in range(11)]
    done = serve_join_samples(engine, reqs, max_batch=4)
    assert sorted(id(r) for r in done) == sorted(id(r) for r in reqs)
    assert all(r.count is not None for r in reqs)


def test_max_batch_validation(db):
    with pytest.raises(ValueError, match="max_batch"):
        MicroBatcher(QueryEngine(db), max_batch=0)
