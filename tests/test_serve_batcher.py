"""The serve-loop micro-batcher (DESIGN.md §10): flush triggers and
per-request result routing.

(a) flush-on-max-batch: the arrival that fills the batch triggers the
    flush; earlier arrivals stay queued;
(b) flush-on-deadline: ``poll()`` flushes iff the oldest pending request
    has waited ``max_wait_ms`` (driven by an injected fake clock — no
    sleeps, no wall-clock flakiness);
(c) routing: a mixed-shape queue is served as one batched dispatch per
    query fingerprint, every request gets exactly its own draw (equal to
    the single-draw engine under the same seed), and the shapes share
    one engine plan cache across flushes.
"""
import numpy as np
import jax
import pytest

from repro.core import Atom, Database, JoinQuery
from repro.core.delta import DeltaBatch
from repro.engine import QueryEngine
from repro.launch.serve import (
    JoinSampleRequest, MicroBatcher, UpdateRequest, serve_join_samples,
)


@pytest.fixture(scope="module")
def db():
    rng = np.random.default_rng(11)
    return Database.from_columns({
        "R": {"x": rng.integers(0, 12, 90), "p": rng.random(90) * 0.5},
        "S": {"x": rng.integers(0, 12, 140), "y": rng.integers(0, 9, 140)},
        "T": {"y": rng.integers(0, 9, 60), "z": np.arange(60)},
    })


@pytest.fixture(scope="module")
def q3(db):
    return JoinQuery((Atom.of("R", "x", "p"), Atom.of("S", "x", "y"),
                      Atom.of("T", "y", "z")), prob_var="p")


@pytest.fixture(scope="module")
def q2(db):
    return JoinQuery((Atom.of("R", "x", "p"), Atom.of("S", "x", "y")),
                     prob_var="p")


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# -- (a) flush on max_batch --------------------------------------------------

def test_flush_on_max_batch(db, q3):
    clock = FakeClock()
    mb = MicroBatcher(QueryEngine(db), max_batch=4, max_wait_ms=1e9,
                      clock=clock)
    done = []
    for i in range(3):
        assert mb.submit(JoinSampleRequest(query=q3, seed=i)) == []
    assert len(mb.pending) == 3 and mb.flushes == 0
    done = mb.submit(JoinSampleRequest(query=q3, seed=3))  # fills the batch
    assert len(done) == 4 and mb.pending == [] and mb.flushes == 1
    assert all(r.count is not None and r.latency_s is not None for r in done)
    # next arrival starts a fresh batch
    assert mb.submit(JoinSampleRequest(query=q3, seed=4)) == []
    assert len(mb.pending) == 1


# -- (b) flush on deadline ---------------------------------------------------

def test_flush_on_deadline(db, q3):
    clock = FakeClock()
    mb = MicroBatcher(QueryEngine(db), max_batch=100, max_wait_ms=5.0,
                      clock=clock)
    mb.submit(JoinSampleRequest(query=q3, seed=0))
    clock.t = 0.004  # 4ms < 5ms deadline
    assert mb.poll() == [] and len(mb.pending) == 1
    mb.submit(JoinSampleRequest(query=q3, seed=1))  # younger request
    clock.t = 0.0051  # oldest has now waited past the deadline
    done = mb.poll()
    assert len(done) == 2 and mb.pending == []  # deadline drains everything
    assert mb.flushes == 1
    # deadline is measured from the OLDEST pending request
    assert done[0].latency_s == pytest.approx(0.0051)
    assert mb.poll() == []  # empty queue: poll is a no-op


# -- (c) routing: mixed shapes, one plan cache -------------------------------

def test_mixed_shapes_one_dispatch_each_and_exact_routing(db, q3, q2):
    engine = QueryEngine(db)
    mb = MicroBatcher(engine, max_batch=8, max_wait_ms=1e9, clock=FakeClock())
    reqs = [JoinSampleRequest(query=q3 if i % 2 == 0 else q2, seed=10 + i)
            for i in range(8)]
    done = []
    for r in reqs:
        done += mb.submit(r)
    assert len(done) == 8 and mb.flushes == 1
    assert mb.dispatches == 2  # one batched dispatch per query shape
    # Every request got exactly its own independent draw.
    ref_engine = QueryEngine(db)
    for r in reqs:
        want = ref_engine.sample(r.query, jax.random.key(r.seed))
        assert r.count == int(want.count), (r.seed, r.count, int(want.count))
        assert r.overflow == bool(want.overflow)
    # Both shapes live in ONE shared plan cache: two plans, two shreds.
    assert engine.stats.plan_misses == 2
    assert engine.stats.shred_builds == 2
    # A second mixed flush is fully warm — zero rebuilds, plans hit.
    st0 = engine.stats.snapshot()
    for i in range(8):
        mb.submit(JoinSampleRequest(query=q3 if i % 2 else q2, seed=50 + i))
    assert mb.flushes == 2
    assert engine.stats.plan_misses == st0.plan_misses
    assert engine.stats.shred_builds == st0.shred_builds
    assert engine.stats.plan_hits >= st0.plan_hits + 2


def test_serve_join_samples_drains_everything(db, q3, q2):
    engine = QueryEngine(db)
    reqs = [JoinSampleRequest(query=q3 if i % 3 else q2, seed=i)
            for i in range(11)]
    done = serve_join_samples(engine, reqs, max_batch=4)
    assert sorted(id(r) for r in done) == sorted(id(r) for r in reqs)
    assert all(r.count is not None for r in reqs)


def test_max_batch_validation(db):
    with pytest.raises(ValueError, match="max_batch"):
        MicroBatcher(QueryEngine(db), max_batch=0)


# -- (d) update requests interleaved with draws (DESIGN.md §11) --------------

def _delta():
    return DeltaBatch.of(S={"insert": {"x": [3, 7], "y": [1, 2]},
                            "delete": [0, 1]})


def test_update_barrier_flushes_pending_draws_on_old_snapshot(db, q3):
    """An update drains the pending batch against the pre-delta snapshot
    first: in-flight draws never mix versions."""
    engine = QueryEngine(db)
    mb = MicroBatcher(engine, max_batch=100, max_wait_ms=1e9,
                      clock=FakeClock())
    r_before = [JoinSampleRequest(query=q3, seed=i) for i in range(3)]
    for r in r_before:
        mb.submit(r)
    done = mb.submit(UpdateRequest(_delta()))
    # barrier: the 3 pending draws completed BEFORE the delta applied...
    assert [id(x) for x in done[:3]] == [id(r) for r in r_before]
    assert all(r.db_version == 0 for r in r_before)
    # ...and the update itself is reported completed with the new version
    assert isinstance(done[3], UpdateRequest)
    assert done[3].applied_version == 1 and engine.db.version == 1
    # draws submitted after the update read the new snapshot
    r_after = JoinSampleRequest(query=q3, seed=50)
    mb.submit(r_after)
    mb.flush()
    assert r_after.db_version == 1
    assert mb.updates_applied == 1


def test_update_between_flushes_zero_rebuilds(db, q3):
    """Warm flushes around an update: the upgraded plan serves the next
    batch with zero shred rebuilds and zero recompiles."""
    engine = QueryEngine(db)
    mb = MicroBatcher(engine, max_batch=4, max_wait_ms=1e9, clock=FakeClock())
    for i in range(4):
        mb.submit(JoinSampleRequest(query=q3, seed=i))  # cold flush
    st0 = engine.stats.snapshot()
    mb.submit(UpdateRequest(_delta()))
    for i in range(4):
        mb.submit(JoinSampleRequest(query=q3, seed=10 + i))  # warm flush
    st1 = engine.stats
    assert st1.shred_builds == st0.shred_builds
    assert st1.plan_misses == st0.plan_misses
    assert st1.shred_upgrades >= 1 and st1.plan_upgrades >= 1


def test_update_results_match_engine_on_applied_snapshot(db, q3):
    """Draws after the barrier equal a cold engine bound to db.apply(delta)
    under the same seeds (the batch really reads the new snapshot)."""
    engine = QueryEngine(db)
    mb = MicroBatcher(engine, max_batch=100, max_wait_ms=1e9,
                      clock=FakeClock())
    mb.submit(JoinSampleRequest(query=q3, seed=0))
    mb.submit(UpdateRequest(_delta()))
    reqs = [JoinSampleRequest(query=q3, seed=20 + i) for i in range(3)]
    for r in reqs:
        mb.submit(r)
    mb.flush()
    ref = QueryEngine(db.apply(_delta()))
    for r in reqs:
        want = ref.sample(q3, jax.random.key(r.seed))
        assert r.count == int(want.count)
        assert r.overflow == bool(want.overflow)


def test_serve_join_samples_with_interleaved_updates(db, q3, q2):
    """The closed-loop entry point serves a mixed draw/update stream in
    arrival order without corrupting any batch."""
    engine = QueryEngine(db)
    stream = []
    for i in range(9):
        stream.append(JoinSampleRequest(query=q3 if i % 2 else q2, seed=i))
        if i % 4 == 3:
            stream.append(UpdateRequest(_delta()))
    done = serve_join_samples(engine, stream, max_batch=4)
    assert sorted(id(r) for r in done) == sorted(id(r) for r in stream)
    draws = [r for r in stream if isinstance(r, JoinSampleRequest)]
    assert all(r.count is not None and r.db_version is not None
               for r in draws)
    assert engine.db.version == 2
    # versions are monotone in arrival order
    versions = [r.db_version for r in draws]
    assert versions == sorted(versions)
