"""Docs integrity: DESIGN.md exists and no in-code citation dangles.

Runs tools/check_docs.py inside the tier-1 suite so a PR that adds a
section citation of DESIGN.md without the matching section fails fast.
"""
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "tools"))

import check_docs  # noqa: E402


def test_design_md_exists():
    assert (ROOT / "DESIGN.md").is_file()


def test_no_dangling_design_citations(capsys):
    rc = check_docs.main(str(ROOT))
    assert rc == 0, capsys.readouterr().err


def test_citations_are_found():
    """The scanner actually sees the known citations (guards against a
    regex regression silently turning the lint into a no-op)."""
    cites = check_docs.collect_citations(ROOT)
    tokens = {t for _, _, t in cites}
    assert {"3", "4", "7", "8", "Arch-applicability"} <= tokens
    assert len(cites) >= 20
