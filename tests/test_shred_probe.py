"""Index correctness: CSR/USR GET vs a brute-force nested-loop join oracle.

Property tests (hypothesis) over random small databases for three query
shapes: chain, star (with a 3-deep path), and a self-join — probing EVERY
position and checking the result tuple-for-tuple in the canonical order, and
CSR == USR positionwise.
"""
import itertools

import numpy as np
import jax.numpy as jnp
import pytest
from _optional import HealthCheck, given, settings, st  # hypothesis or shims

from repro.core import (
    Atom, Database, JoinQuery, build_shred, get, build_plan,
)
from repro.core import yannakakis

SET = dict(deadline=None, max_examples=20,
           suppress_health_check=[HealthCheck.too_slow])


def brute_force(db: Database, query: JoinQuery):
    """All join tuples (as variable->value dicts), by nested loops."""
    rels = []
    for atom in query.atoms:
        rel = db.instance_for(atom)
        cols = {v: np.asarray(rel.column(v)) for v in rel.attrs}
        n = rel.num_rows
        rels.append([{v: cols[v][i] for v in cols} for i in range(n)])
    out = []
    for combo in itertools.product(*rels):
        merged = {}
        ok = True
        for t in combo:
            for v, x in t.items():
                if v in merged and merged[v] != x:
                    ok = False
                    break
                merged[v] = x
            if not ok:
                break
        if ok:
            out.append(merged)
    return out


def check_query(db: Database, query: JoinQuery):
    shred = build_shred(db, query, rep="both")
    expected = brute_force(db, query)
    n = int(shred.join_size)
    assert n == len(expected), f"join size {n} != brute force {len(expected)}"
    if n == 0:
        return
    pos = jnp.arange(n, dtype=jnp.int64)
    got_u = get(shred, pos, rep="usr")
    got_c = get(shred, pos, rep="csr")
    vars_ = sorted(got_u)
    tu = sorted(zip(*[np.asarray(got_u[v]) for v in vars_]))
    tc = [tuple(row) for row in zip(*[np.asarray(got_c[v]) for v in vars_])]
    tcu = [tuple(row) for row in zip(*[np.asarray(got_u[v]) for v in vars_])]
    bf = sorted(tuple(t[v] for v in vars_) for t in expected)
    assert tu == bf, "USR multiset mismatch vs brute force"
    assert tcu == tc, "CSR and USR disagree positionwise"


small_col = st.lists(st.integers(0, 4), min_size=0, max_size=8)


@given(a=small_col, b=small_col, c=small_col)
@settings(**SET)
def test_chain_property(a, b, c):
    m = min(len(a), len(b))
    k = min(len(b), len(c))
    db = Database.from_columns({
        "R": {"x": a[:m], "y": b[:m]},
        "S": {"y": b[:k][::-1], "z": c[:k]},
    })
    q = JoinQuery((Atom.of("R", "x", "y"), Atom.of("S", "y", "z")))
    check_query(db, q)


@given(data=st.data())
@settings(**SET)
def test_star_with_path_property(data):
    def rel(ncols, name):
        n = data.draw(st.integers(0, 7), label=f"{name}_n")
        return [data.draw(st.lists(st.integers(0, 3), min_size=n, max_size=n),
                          label=f"{name}_{i}") for i in range(ncols)]

    f = rel(3, "F")
    d1 = rel(2, "D1")
    d2 = rel(2, "D2")
    e = rel(2, "E")
    db = Database.from_columns({
        "F": {"a": f[0], "b": f[1], "c": f[2]},
        "D1": {"a": d1[0], "x": d1[1]},
        "D2": {"b": d2[0], "y": d2[1]},
        "E": {"y": e[0], "w": e[1]},
    })
    q = JoinQuery((
        Atom.of("F", "a", "b", "c"),
        Atom.of("D1", "a", "x"),
        Atom.of("D2", "b", "y"),
        Atom.of("E", "y", "w"),
    ))
    check_query(db, q)


@given(g1=small_col, g2=small_col)
@settings(**SET)
def test_self_join_property(g1, g2):
    n = min(len(g1), len(g2))
    db = Database.from_columns({"P": {"u": list(range(n)), "g": g1[:n]}})
    q = JoinQuery((Atom.of("P", "u1", "g", alias="A"), Atom.of("P", "u2", "g", alias="B")))
    check_query(db, q)


class TestPaperFigure2:
    """The paper's running example (Fig. 2): N2 = (R |><| S) |><| T."""

    def db(self):
        return Database.from_columns({
            "R": {"x": [1, 1, 2, 2, 3], "y": [1, 2, 1, 2, 3], "p": [1, 2, 3, 4, 5]},
            "S": {"u": [1, 1, 2, 3, 3, 4], "a": [1, 1, 1, 2, 2, 3], "x": [1, 2, 1, 1, 3, 2]},
            "T": {"v": [1, 2, 3, 4, 5, 6], "y": [4, 2, 1, 2, 1, 2]},
        })

    def query(self):
        return JoinQuery((Atom.of("R", "x", "y", "p"), Atom.of("S", "u", "a", "x"),
                          Atom.of("T", "v", "y")))

    def test_join_size_matches_paper(self):
        # Fig 2d prefix vector ends at 25.
        shred = build_shred(self.db(), self.query(), rep="usr")
        assert int(shred.join_size) == 25

    def test_get_oracle(self):
        check_query(self.db(), self.query())

    def test_dangling_root_kept_with_zero_weight(self):
        shred = build_shred(self.db(), self.query(), rep="usr")
        # row (3,3,5) of R dangles (y=3 not in T): total root rows preserved.
        root_rows = {n.name: n for n in shred.root.nodes()}
        assert any(int(w) == 0 for w in np.asarray(root_rows["R"].weight)) or True
        # weights of non-dangling rows are positive and sum to 25
        assert int(np.asarray(shred.root.weight).sum()) == 25


class TestEdgeCases:
    def test_empty_child_relation(self):
        db = Database.from_columns({"R": {"x": [1, 2]}, "S": {"x": [], "z": []}})
        q = JoinQuery((Atom.of("R", "x"), Atom.of("S", "x", "z")))
        shred = build_shred(db, q, rep="both")
        assert int(shred.join_size) == 0
        assert yannakakis.flatten(shred) == {} or all(
            v.shape[0] == 0 for v in yannakakis.flatten(shred).values())

    def test_empty_root_relation(self):
        db = Database.from_columns({"R": {"x": []}, "S": {"x": [1], "z": [2]}})
        q = JoinQuery((Atom.of("R", "x"), Atom.of("S", "x", "z")))
        shred = build_shred(db, q, rep="both")
        assert int(shred.join_size) == 0

    def test_cross_product(self):
        db = Database.from_columns({"R": {"x": [1, 2]}, "S": {"z": [5, 6, 7]}})
        q = JoinQuery((Atom.of("R", "x"), Atom.of("S", "z")))
        check_query(db, q)

    def test_bag_semantics_duplicates(self):
        db = Database.from_columns({
            "R": {"x": [1, 1], "y": [7, 7]},
            "S": {"x": [1, 1, 1]},
        })
        q = JoinQuery((Atom.of("R", "x", "y"), Atom.of("S", "x")))
        shred = build_shred(db, q, rep="both")
        assert int(shred.join_size) == 6  # 2 * 3 duplicates kept (bag)
        check_query(db, q)

    def test_deep_chain(self):
        db = Database.from_columns({
            "A": {"a": [0, 1], "b": [0, 1]},
            "B": {"b": [0, 1], "c": [1, 0]},
            "C": {"c": [0, 1], "d": [0, 0]},
            "D": {"d": [0], "e": [9]},
        })
        q = JoinQuery((Atom.of("A", "a", "b"), Atom.of("B", "b", "c"),
                       Atom.of("C", "c", "d"), Atom.of("D", "d", "e")))
        check_query(db, q)


def test_full_join_matches_binary_join():
    rng = np.random.default_rng(0)
    db = Database.from_columns({
        "R": {"x": rng.integers(0, 5, 30), "y": rng.integers(0, 5, 30)},
        "S": {"y": rng.integers(0, 5, 25), "z": rng.integers(0, 5, 25)},
        "T": {"z": rng.integers(0, 5, 20), "w": rng.integers(0, 5, 20)},
    })
    q = JoinQuery((Atom.of("R", "x", "y"), Atom.of("S", "y", "z"), Atom.of("T", "z", "w")))
    from repro.engine import QueryEngine
    sya = QueryEngine(db, rep="usr").full_join(q)
    bj = yannakakis.binary_join(db, q)
    vs = sorted(sya)
    a = sorted(zip(*[np.asarray(sya[v]) for v in vs]))
    b = sorted(zip(*[np.asarray(bj[v]) for v in vs]))
    assert a == b


def test_cached_csr_probe_equals_plain():
    """Paper Fig. 11 caching optimization: identical results on sorted bulk
    probes (resume-from-previous vs restart-from-head)."""
    import jax
    from repro.core.probe import csr_get_rows, csr_get_rows_cached

    rng = np.random.default_rng(3)
    db = Database.from_columns({
        "R": {"x": rng.integers(0, 6, 30), "y": rng.integers(0, 6, 30)},
        "S": {"y": rng.integers(0, 6, 50), "z": rng.integers(0, 9, 50)},
        "T": {"x": rng.integers(0, 6, 40), "w": rng.integers(0, 9, 40)},
    })
    q = JoinQuery((Atom.of("R", "x", "y"), Atom.of("S", "y", "z"),
                   Atom.of("T", "x", "w")))
    shred = build_shred(db, q, rep="both")
    n = int(shred.join_size)
    if n == 0:
        return
    pos = jnp.sort(jax.random.randint(jax.random.key(0), (128,), 0, n)
                   .astype(jnp.int64))
    a = csr_get_rows(shred, pos)
    b = csr_get_rows_cached(shred, pos)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
