"""Fused-GET correctness: the single-pass Pallas tree-probe kernel vs the
per-node int64 USR-GET reference (DESIGN.md §4 "Fused GET").

Property tests (hypothesis, optional via tests/_optional.py) over random
acyclic queries — chains, stars, cross-product (keyless) edges, dangling
tuples — assert the int32-narrowed fused path is *bit-identical* to
``usr_get_rows`` on every probed position, including on shreds produced by
``reshred_incremental`` (post-``apply_delta``). Plus deterministic tests of
the fallback ladder (no arena / VMEM budget / Pallas disabled) and the
engine's fused-rep selection.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _optional import HealthCheck, given, settings, st  # hypothesis or shims

from repro.core import (
    Atom, Database, DeltaBatch, JoinQuery, build_shred, get, pack_arena,
    pack_index, reshred_incremental, usr_get_rows, usr_get_rows_fused,
    usr_get_rows_paged,
)
from repro import config
from repro.core import probe
from repro.engine import QueryEngine

SET = dict(deadline=None, max_examples=20,
           suppress_health_check=[HealthCheck.too_slow])


def _shrunken(shred):
    """A policy whose VMEM budget is one word short of the shred's arena —
    the smallest budget that forces the paged rung (DESIGN.md §15)."""
    return dataclasses.replace(config.current_policy(),
                               vmem_limit=shred.packed.layout.size - 1)


def assert_fused_matches(shred, extra_random: int = 64):
    """Fused GET == per-node USR GET, bit for bit, on every position (and a
    few out-of-order random probes). When the arena can page (more than one
    page fits a one-word-short VMEM budget), the paged rung must be
    bit-identical too — same walk, streamed page by page."""
    n = int(shred.join_size)
    if n == 0 or shred.packed is None:
        return
    pos = jnp.arange(n, dtype=jnp.int64)
    rnd = jax.random.randint(jax.random.key(7), (extra_random,), 0, n
                             ).astype(jnp.int64)
    for p in (pos, rnd):
        want = usr_get_rows(shred, p)
        got = usr_get_rows_fused(shred, p)
        assert set(want) == set(got)
        for name in want:
            assert got[name].dtype == want[name].dtype, name
            np.testing.assert_array_equal(
                np.asarray(want[name]), np.asarray(got[name]), err_msg=name)
        with config.override(_shrunken(shred)):
            if not probe.paged_available(shred):
                continue  # one-page arena: no budget pages it
            paged = usr_get_rows_paged(shred, p)
        assert set(want) == set(paged)
        for name in want:
            np.testing.assert_array_equal(
                np.asarray(want[name]), np.asarray(paged[name]),
                err_msg=f"paged:{name}")


small_col = st.lists(st.integers(0, 4), min_size=0, max_size=8)


@given(a=small_col, b=small_col, c=small_col)
@settings(**SET)
def test_chain_property(a, b, c):
    m = min(len(a), len(b))
    k = min(len(b), len(c))
    db = Database.from_columns({
        "R": {"x": a[:m], "y": b[:m]},
        "S": {"y": b[:k][::-1], "z": c[:k]},  # dangling rows arise naturally
    })
    q = JoinQuery((Atom.of("R", "x", "y"), Atom.of("S", "y", "z")))
    assert_fused_matches(build_shred(db, q, rep="both"))


@given(data=st.data())
@settings(**SET)
def test_star_with_cross_product_property(data):
    """Star query with a keyless (cross-product) edge riding along."""
    def rel(ncols, name):
        n = data.draw(st.integers(1, 6), label=f"{name}_n")
        return [data.draw(st.lists(st.integers(0, 3), min_size=n, max_size=n),
                          label=f"{name}_{i}") for i in range(ncols)]

    f = rel(2, "F")
    d1 = rel(2, "D1")
    e = rel(1, "E")  # disjoint atom: joins F only via the cross product
    db = Database.from_columns({
        "F": {"a": f[0], "b": f[1]},
        "D1": {"a": d1[0], "x": d1[1]},
        "E": {"w": e[0]},
    })
    q = JoinQuery((Atom.of("F", "a", "b"), Atom.of("D1", "a", "x"),
                   Atom.of("E", "w")))
    assert_fused_matches(build_shred(db, q, rep="both"))


@given(data=st.data())
@settings(**SET)
def test_post_delta_shred_property(data):
    """Fused GET stays bit-identical on incrementally reshredded indexes."""
    def col(name, n):
        return data.draw(st.lists(st.integers(0, 3), min_size=n, max_size=n),
                         label=name)

    nr = data.draw(st.integers(1, 6), label="nr")
    ns = data.draw(st.integers(1, 6), label="ns")
    db = Database.from_columns({
        "R": {"x": col("rx", nr), "y": col("ry", nr)},
        "S": {"y": col("sy", ns), "z": col("sz", ns)},
    })
    q = JoinQuery((Atom.of("R", "x", "y"), Atom.of("S", "y", "z")))
    base = build_shred(db, q, rep="both")
    ins = data.draw(st.integers(1, 3), label="ins")
    dele = data.draw(st.integers(0, ns - 1), label="del")
    spec = {"insert": {"y": col("iy", ins), "z": col("iz", ins)}}
    if dele:
        spec["delete"] = list(range(dele))
    delta = DeltaBatch.of(S=spec)
    new = reshred_incremental(base, db, q, delta)
    scratch = build_shred(db.apply(delta), q, rep="both")
    # arena coherence: incremental == from-scratch, arena included
    assert (new.packed is None) == (scratch.packed is None)
    if new.packed is not None:
        assert new.packed.layout == scratch.packed.layout
        np.testing.assert_array_equal(np.asarray(new.packed.arena),
                                      np.asarray(scratch.packed.arena))
    assert_fused_matches(new)


class TestFallbackLadder:
    def _shred(self):
        rng = np.random.default_rng(1)
        db = Database.from_columns({
            "R": {"x": rng.integers(0, 4, 12), "y": rng.integers(0, 4, 12)},
            "S": {"y": rng.integers(0, 4, 9), "z": rng.integers(0, 4, 9)},
        })
        q = JoinQuery((Atom.of("R", "x", "y"), Atom.of("S", "y", "z")))
        return build_shred(db, q, rep="usr")

    def test_vmem_budget_falls_back(self):
        shred = self._shred()
        assert probe.fused_available(shred)
        with config.override(config.KernelPolicy(vmem_limit=1)):
            assert not probe.fused_available(shred)
            n = int(shred.join_size)
            pos = jnp.arange(n, dtype=jnp.int64)
            a = usr_get_rows(shred, pos)
            b = usr_get_rows_fused(shred, pos)  # silently takes per-node path
        for k in a:
            np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))

    def test_pallas_disable_env_falls_back(self, monkeypatch):
        shred = self._shred()
        monkeypatch.setenv("REPRO_PALLAS_DISABLE", "1")
        assert not probe.fused_available(shred)
        n = int(shred.join_size)
        pos = jnp.arange(n, dtype=jnp.int64)
        a = usr_get_rows(shred, pos)
        b = usr_get_rows_fused(shred, pos)
        for k in a:
            np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))

    def test_int32_narrowing_refused_on_overflow(self):
        """Values beyond int32 keep the int64 per-node path (DESIGN.md §9)."""
        shred = self._shred()
        root = shred.root
        big = dataclasses.replace(
            root, children=tuple(
                dataclasses.replace(
                    c, cumw_excl=c.cumw_excl + jnp.int64(2) ** 33)
                for c in root.children))
        assert pack_arena(big, shred.root_prefE) is None

    def test_empty_node_refused(self):
        db = Database.from_columns({"R": {"x": [1, 2]}, "S": {"x": [], "z": []}})
        q = JoinQuery((Atom.of("R", "x"), Atom.of("S", "x", "z")))
        shred = build_shred(db, q, rep="usr")
        assert shred.packed is None
        assert not probe.fused_available(shred)
        # (probing an empty join is out of contract for every GET path —
        # callers guard join_size == 0 before dispatching.)


class TestEngineIntegration:
    @pytest.fixture(autouse=True)
    def _prefer_pallas(self, monkeypatch):
        # The engine prefers the fused kernel by default only in compiled
        # mode (real TPU); pin the preference so the interpret-mode CI
        # exercises the fused executor path (ops.pallas_preferred).
        monkeypatch.setenv("REPRO_PALLAS_PREFER", "1")

    def _db_q(self):
        rng = np.random.default_rng(2)
        db = Database.from_columns({
            "R": {"x": rng.integers(0, 5, 20), "y": rng.integers(0, 5, 20),
                  "p": rng.random(20)},
            "S": {"y": rng.integers(0, 5, 15), "z": rng.integers(0, 5, 15)},
        })
        q = JoinQuery((Atom.of("R", "x", "y", "p"), Atom.of("S", "y", "z")),
                      prob_var="p")
        return db, q

    def test_fused_is_default_and_bit_identical(self):
        db, q = self._db_q()
        eng = QueryEngine(db)
        # Pin the multi-launch sampler: this test compares the fused GET
        # *rep* against per-node USR under one position stream (the fused
        # one-launch *draw* has its own stream — tests/test_fused_draw.py).
        plan = eng.compile(q, kernels="pernode")
        assert plan.rep_default == "usr_fused"
        key = jax.random.key(3)
        sf = plan.sample(key)
        su = plan.sample(key, rep="usr")
        np.testing.assert_array_equal(np.asarray(sf.positions),
                                      np.asarray(su.positions))
        for v in sf.columns:
            np.testing.assert_array_equal(np.asarray(sf.columns[v]),
                                          np.asarray(su.columns[v]))
        assert int(sf.count) == int(su.count)

    def test_csr_engine_keeps_csr(self):
        db, q = self._db_q()
        plan = QueryEngine(db, rep="csr").compile(q)
        assert plan.rep_default == "csr"

    def test_batched_fused_lanes_match_single(self):
        db, q = self._db_q()
        plan = QueryEngine(db).compile(q)
        keys = jax.random.split(jax.random.key(4), 3)
        sb = plan.sample_batch(keys)
        for i in range(3):
            si = plan.sample(keys[i])
            np.testing.assert_array_equal(np.asarray(sb.positions[i]),
                                          np.asarray(si.positions))

    def test_full_join_fused_matches_usr(self):
        db, q = self._db_q()
        eng = QueryEngine(db)
        plan = eng.compile(q)
        fj_f = plan.full_join()                 # rep_default == usr_fused
        fj_u = plan.full_join(rep="usr")
        for v in fj_u:
            np.testing.assert_array_equal(np.asarray(fj_f[v]),
                                          np.asarray(fj_u[v]))

    def test_apply_delta_keeps_fused_coherent(self):
        db, q = self._db_q()
        eng = QueryEngine(db)
        # kernels="pernode" keeps one position stream across the rep
        # comparison below (the fused *draw* has its own stream and its
        # delta coherence is covered by tests/test_fused_draw.py).
        plan = eng.compile(q, kernels="pernode")
        key = jax.random.key(5)
        plan.sample(key)  # warm
        eng.apply_delta(DeltaBatch.of(
            S={"insert": {"y": [1, 2], "z": [3, 0]}}))
        plan2 = eng.compile(q, kernels="pernode")
        assert plan2.rep_default == "usr_fused"
        sf = plan2.sample(key)
        su = plan2.sample(key, rep="usr")
        np.testing.assert_array_equal(np.asarray(sf.positions),
                                      np.asarray(su.positions))
        # coherence vs a cold engine on the post-delta snapshot
        cold = QueryEngine(eng.db).compile(q, kernels="pernode")
        sc = cold.sample(key)
        np.testing.assert_array_equal(np.asarray(sf.positions),
                                      np.asarray(sc.positions))


def test_reshard_reuse_restores_dropped_arena():
    """A stacked index whose arenas were dropped (mixed per-shard narrowing
    verdict in an earlier epoch) must not propagate packed=None through the
    shard-reuse path forever: reused shards re-pack, matching a
    from-scratch ``build_stacked`` of the same snapshot."""
    from repro.core.distributed import build_stacked, reshard_incremental

    rng = np.random.default_rng(11)
    db = Database.from_columns({
        "R": {"x": rng.integers(0, 5, 16), "y": rng.integers(0, 5, 16),
              "p": rng.random(16)},
        "S": {"y": rng.integers(0, 5, 10), "z": rng.integers(0, 5, 10)},
    })
    q = JoinQuery((Atom.of("R", "x", "y", "p"), Atom.of("S", "y", "z")),
                  prob_var="p")
    stacked, base = build_stacked(db, q, 2)
    assert stacked.shred.packed is not None
    stripped = dataclasses.replace(
        stacked, shred=dataclasses.replace(stacked.shred, packed=None))
    restacked, _, reused, rebuilt = reshard_incremental(
        stripped, base, db, q, 2)
    assert (reused, rebuilt) == (2, 0)  # identical snapshot: all reused
    assert restacked.shred.packed is not None
    np.testing.assert_array_equal(
        np.asarray(restacked.shred.packed.arena),
        np.asarray(stacked.shred.packed.arena))


def test_self_join_aliases():
    db = Database.from_columns({"P": {"u": list(range(6)),
                                      "g": [0, 1, 0, 2, 1, 0]}})
    q = JoinQuery((Atom.of("P", "u1", "g", alias="A"),
                   Atom.of("P", "u2", "g", alias="B")))
    assert_fused_matches(build_shred(db, q, rep="both"))


def test_deep_multi_child_tree():
    """Depth-4 tree with a 3-child interior node: exercises the per-parent
    mixed-radix peel order across interleaved pre-order edges."""
    rng = np.random.default_rng(9)
    db = Database.from_columns({
        "A": {"a": rng.integers(0, 3, 8), "b": rng.integers(0, 3, 8)},
        "B": {"b": rng.integers(0, 3, 7), "c": rng.integers(0, 3, 7),
              "d": rng.integers(0, 3, 7)},
        "C": {"c": rng.integers(0, 3, 6), "e": rng.integers(0, 3, 6)},
        "D": {"d": rng.integers(0, 3, 5), "f": rng.integers(0, 3, 5)},
        "E": {"f": rng.integers(0, 3, 4), "g": rng.integers(0, 3, 4)},
    })
    q = JoinQuery((Atom.of("A", "a", "b"), Atom.of("B", "b", "c", "d"),
                   Atom.of("C", "c", "e"), Atom.of("D", "d", "f"),
                   Atom.of("E", "f", "g")))
    assert_fused_matches(build_shred(db, q, rep="both"))


class TestPagedRung:
    """The paged rung of the kernel ladder (DESIGN.md §15): selection across
    the VMEM-budget boundaries, build-time mutual exclusivity, and the
    paged draw's bit-identity to the reference pipeline."""

    def _db_q(self):
        rng = np.random.default_rng(0)
        m = 120
        db = Database.from_columns({
            "R": {"x": rng.integers(0, 20, m), "y": rng.integers(0, 20, m),
                  "p": rng.uniform(0.05, 0.3, m)},
            "S": {"y": rng.integers(0, 20, m), "z": rng.integers(0, 20, m)},
            "T": {"z": rng.integers(0, 20, m), "u": rng.integers(0, 20, m)},
        })
        q = JoinQuery((Atom.of("R", "x", "y", "p"), Atom.of("S", "y", "z"),
                       Atom.of("T", "z", "u")), prob_var="p")
        return db, q

    def _dparams(self, shred):
        from repro.core import sampling
        return sampling.fused_draw_params(
            shred.root.weight, shred.root.data.column("p"), shred.root_prefE)

    def test_rung_selection_across_vmem_boundaries(self):
        db, q = self._db_q()
        shred = build_shred(db, q, rep="usr")
        size = shred.packed.layout.size
        max_page = shred.packed.layout.max_page
        assert max_page < size  # multi-page arena: all three rungs reachable
        dp = self._dparams(shred)
        base = dataclasses.replace(config.current_policy(), prefer=True)
        ladder = []
        for limit in (size, size - 1, max_page, max_page - 1):
            pol = dataclasses.replace(base, vmem_limit=limit)
            with config.override(pol):
                sh = build_shred(db, q, rep="usr")
                rep, narrow = probe.select_rep(sh, "usr")
                route = probe.select_draw(sh, self._dparams(sh),
                                          method="exprace")
            ladder.append((limit, rep, narrow, route))
        assert ladder == [
            (size, "usr_fused", True, "fused"),
            (size - 1, "usr_paged", True, "paged"),
            (max_page, "usr_paged", True, "paged"),
            (max_page - 1, "usr", False, "pernode"),
        ]
        # Call-time shrink (no rebuild): an already-packed index pages too.
        with config.override(dataclasses.replace(base, vmem_limit=size - 1)):
            assert probe.select_rep(shred, "usr")[0] == "usr_paged"
            assert probe.select_draw(shred, dp, method="exprace") == "paged"

    def test_pack_index_mutual_exclusivity(self):
        db, q = self._db_q()
        shred = build_shred(db, q, rep="usr")
        assert shred.packed is not None and shred.paged is None
        with config.override(_shrunken(shred)):
            sh = build_shred(db, q, rep="usr")
        assert sh.packed is None and sh.paged is not None
        # Pages concatenate back to exactly the monolithic arena.
        np.testing.assert_array_equal(
            np.asarray(jnp.concatenate(sh.paged.pages)),
            np.asarray(shred.packed.arena))
        assert sh.paged.layout == shred.packed.layout

    def test_explicit_paged_request_raises_out_of_regime(self):
        db, q = self._db_q()
        shred = build_shred(db, q, rep="usr")
        dp = self._dparams(shred)
        with pytest.raises(ValueError, match="paged"):
            probe.select_draw(shred, dp, method="exprace", kernels="paged")

    def test_paged_draw_matches_reference_and_fused(self):
        db, q = self._db_q()
        key = jax.random.key(11)
        eng = QueryEngine(db)
        s_fused = eng.poisson_sample(q, key, kernels="fused")
        shred = build_shred(db, q, rep="usr")
        with config.override(_shrunken(shred)):
            eng2 = QueryEngine(db)
            s_paged = eng2.poisson_sample(q, key, kernels="paged")
            s_ref = eng2.poisson_sample(q, key, kernels="reference")
        for other in (s_ref, s_fused):
            np.testing.assert_array_equal(np.asarray(s_paged.positions),
                                          np.asarray(other.positions))
            assert int(s_paged.count) == int(other.count)
            for v in s_paged.columns:
                np.testing.assert_array_equal(
                    np.asarray(s_paged.columns[v]),
                    np.asarray(other.columns[v]), err_msg=v)

    def test_post_delta_paged_coherence(self):
        """pack_index stays coherent through reshred_incremental in the
        paged regime: incremental == from-scratch, pages included."""
        db, q = self._db_q()
        shred = build_shred(db, q, rep="usr")
        with config.override(_shrunken(shred)):
            base = build_shred(db, q, rep="usr")
            assert base.paged is not None
            delta = DeltaBatch.of(S={"insert": {"y": [1, 2], "z": [3, 0]}})
            new = reshred_incremental(base, db, q, delta)
            scratch = build_shred(db.apply(delta), q, rep="usr")
            assert (new.paged is None) == (scratch.paged is None)
            if new.paged is not None:
                assert new.paged.layout == scratch.paged.layout
                for a, b in zip(new.paged.pages, scratch.paged.pages):
                    np.testing.assert_array_equal(np.asarray(a),
                                                  np.asarray(b))
            assert_fused_matches(new)

    def test_stacked_paged_coherence(self):
        """Shard stacking carries the paged form like the packed one, and
        the reuse path restores dropped pages (mirrors the packed test)."""
        from repro.core.distributed import build_stacked, reshard_incremental

        db, q = self._db_q()
        shred = build_shred(db, q, rep="usr")
        with config.override(_shrunken(shred)):
            stacked, dbase = build_stacked(db, q, 2)
            # Per-shard arenas are smaller than the global one, so shards
            # may legitimately pack monoliths; either way the two forms
            # stay mutually exclusive and stack-coherent.
            assert (stacked.shred.packed is None) or (
                stacked.shred.paged is None)
            stripped = dataclasses.replace(
                stacked, shred=dataclasses.replace(
                    stacked.shred, packed=None, paged=None))
            restacked, _, reused, rebuilt = reshard_incremental(
                stripped, dbase, db, q, 2)
            assert (reused, rebuilt) == (2, 0)
            assert (restacked.shred.packed is None) == (
                stacked.shred.packed is None)
            assert (restacked.shred.paged is None) == (
                stacked.shred.paged is None)


def test_get_rows_rep_dispatch():
    rng = np.random.default_rng(6)
    db = Database.from_columns({
        "R": {"x": rng.integers(0, 4, 10), "y": rng.integers(0, 4, 10)},
        "S": {"y": rng.integers(0, 4, 8), "z": rng.integers(0, 4, 8)},
    })
    q = JoinQuery((Atom.of("R", "x", "y"), Atom.of("S", "y", "z")))
    shred = build_shred(db, q, rep="both")
    n = int(shred.join_size)
    if n == 0:
        return
    pos = jnp.arange(n, dtype=jnp.int64)
    gf = get(shred, pos, rep="usr_fused")
    gu = get(shred, pos, rep="usr")
    gc = get(shred, pos, rep="csr")
    for v in gu:
        np.testing.assert_array_equal(np.asarray(gf[v]), np.asarray(gu[v]))
        np.testing.assert_array_equal(np.asarray(gf[v]), np.asarray(gc[v]))
