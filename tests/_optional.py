"""Optional-dependency shims for the test suite.

The container image does not guarantee ``hypothesis``; property tests must
*skip* (not break collection) when it is absent, while the deterministic
tests in the same modules keep running. Usage:

    from _optional import HAVE_HYPOTHESIS, given, settings, st, HealthCheck

When hypothesis is installed these are the real objects; otherwise ``given``
returns a skip decorator and ``st``/``settings``/``HealthCheck`` are inert
stand-ins that absorb strategy construction at class-body time.
"""
import pytest

try:
    from hypothesis import HealthCheck, given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # pragma: no cover - depends on environment
    HAVE_HYPOTHESIS = False

    class _AnyAttr:
        """Absorbs attribute access / calls made while building strategies."""

        def __getattr__(self, name):
            return _AnyAttr()

        def __call__(self, *args, **kwargs):
            return _AnyAttr()

    st = _AnyAttr()
    HealthCheck = _AnyAttr()

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco
