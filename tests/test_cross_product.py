"""Keyless children end-to-end: disjoint atoms execute as cross-product
(single-group) edges through shred build, both GETs, Poisson sampling, and
the engine (the deliberate support decision documented in
jointree._gyo_parents and shred._edge_keys).
"""
import itertools

import numpy as np
import jax
import pytest

from repro.core import Atom, Database, JoinQuery, build_shred, yannakakis
from repro.engine import QueryEngine


@pytest.fixture(scope="module")
def db():
    return Database.from_columns({
        "R": {"x": [1, 2, 3], "p": [0.5, 0.2, 0.9]},
        "U": {"w": [10, 20]},
        "V": {"v": [7]},
        "S": {"x": [1, 1, 3], "y": [4, 5, 6]},
    })


def _rows(full):
    keys = sorted(full)
    return keys, sorted(zip(*[np.asarray(full[k]).tolist() for k in keys]))


@pytest.mark.parametrize("rep", ["usr", "csr"])
def test_pure_cross_product_full_join(db, rep):
    q = JoinQuery((Atom.of("R", "x", "p"), Atom.of("U", "w"),
                   Atom.of("V", "v")), prob_var="p")
    engine = QueryEngine(db, rep=rep)
    assert engine.join_size(q) == 3 * 2 * 1
    keys, got = _rows(engine.full_join(q))
    assert keys == ["p", "v", "w", "x"]
    want = sorted((p, 7, w, x)
                  for (x, p) in [(1, 0.5), (2, 0.2), (3, 0.9)]
                  for w in [10, 20])
    assert got == want


@pytest.mark.parametrize("rep", ["usr", "csr"])
def test_mixed_join_and_cross_product(db, rep):
    # {R, S} join on x; U is a disjoint component multiplied in.
    q = JoinQuery((Atom.of("R", "x", "p"), Atom.of("S", "x", "y"),
                   Atom.of("U", "w")), prob_var="p")
    engine = QueryEngine(db, rep=rep)
    joined = [(x, p, y) for (x, p) in [(1, 0.5), (2, 0.2), (3, 0.9)]
              for (xs, y) in [(1, 4), (1, 5), (3, 6)] if x == xs]
    assert engine.join_size(q) == len(joined) * 2
    keys, got = _rows(engine.full_join(q))
    assert keys == ["p", "w", "x", "y"]
    want = sorted((p, w, x, y) for (x, p, y) in joined for w in [10, 20])
    assert got == want


def test_cross_product_sampling_membership(db):
    q = JoinQuery((Atom.of("R", "x", "p"), Atom.of("U", "w"),
                   Atom.of("V", "v")), prob_var="p")
    engine = QueryEngine(db)
    full = engine.full_join(q)
    names = tuple(sorted(full))
    fullset = set(zip(*[np.asarray(full[k]).tolist() for k in names]))
    total = 0
    for seed in range(20):
        smp = engine.sample(q, jax.random.key(seed), auto=True)
        vmask = np.asarray(smp.valid())
        got = list(zip(*[np.asarray(smp.columns[k])[vmask].tolist()
                         for k in names]))
        assert len(got) == int(smp.count)
        assert all(t in fullset for t in got)
        total += len(got)
    # E[count per draw] = sum_x p(x) * |U| * |V| = 1.6 * 2 = 3.2
    assert 0 < total < 20 * 6


def test_cross_product_sampling_statistics(db):
    q = JoinQuery((Atom.of("R", "x", "p"), Atom.of("U", "w")), prob_var="p")
    engine = QueryEngine(db)
    plan = engine.compile(q)
    exp = plan.expected_k()
    assert exp == pytest.approx((0.5 + 0.2 + 0.9) * 2)
    cnts = [int(engine.sample(q, jax.random.key(i)).count) for i in range(80)]
    from repro.core import estimate
    sd = float(estimate.sample_std(plan.w, plan.p))
    z = (np.mean(cnts) - exp) / (sd / 80 ** 0.5)
    assert abs(z) < 4.5


def test_empty_factor_annihilates(db):
    db0 = Database.from_columns({
        "R": {"x": [1, 2], "p": [0.5, 0.5]},
        "E": {"e": np.zeros((0,), np.int64)},
    })
    q = JoinQuery((Atom.of("R", "x", "p"), Atom.of("E", "e")), prob_var="p")
    engine = QueryEngine(db0)
    assert engine.join_size(q) == 0
    smp = engine.sample(q, jax.random.key(0))
    assert int(smp.count) == 0 and not bool(smp.overflow)


def test_cross_product_matches_direct_flatten(db):
    q = JoinQuery((Atom.of("R", "x", "p"), Atom.of("U", "w")))
    shred = build_shred(db, q, rep="both")
    a = yannakakis.flatten(shred, rep="usr")
    b = yannakakis.flatten(shred, rep="csr")
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
    assert len(np.asarray(a["x"])) == 6
