"""One-launch fused draw correctness (DESIGN.md §14).

Property tests (hypothesis, optional via tests/_optional.py) over random
acyclic queries — chains, cross-product (keyless) edges, dangling tuples,
post-``apply_delta`` shreds — assert the Pallas kernel
(``kernels.fused_draw.fused_draw``) is *bit-identical* to its multi-launch
reference (``fused_draw_ref``: the same ``draw_core`` + ``tree_walk`` as
plain traced jnp) for both EXPRACE and flat PTBERN. Plus deterministic
tests of the fallback ladder (``probe.select_draw``), the ``KernelPolicy``
resolution order (per-call > ``override(...)`` > env), and the engine
route integration (``DrawSpec.kernels``).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _optional import HealthCheck, given, settings, st  # hypothesis or shims

from repro import config
from repro.core import (
    Atom, Database, DeltaBatch, JoinQuery, build_shred, probe,
    reshred_incremental, sampling,
)
from repro.engine import QueryEngine
from repro.kernels.fused_draw import fused_draw, fused_draw_ref

SET = dict(deadline=None, max_examples=15,
           suppress_health_check=[HealthCheck.too_slow])


def assert_fused_draw_matches(shred, p=None, seeds=(0, 1)):
    """Kernel == reference, bit for bit: positions, count, overflow, and
    every per-node row vector, for EXPRACE and (small n) flat PTBERN."""
    n = int(shred.join_size)
    if n == 0 or shred.packed is None:
        return
    R = int(shred.root.num_rows)
    if p is None:
        rng = np.random.default_rng(R * 7919 + n)
        p = jnp.asarray(np.clip(rng.random(R), 0.02, 0.98))
    dparams = sampling.fused_draw_params(
        shred.root.weight, p, shred.root_prefE)
    assert dparams is not None
    packed = shred.packed
    cap = max(8, n + 4)
    acap = 2 * cap + 8
    for seed in seeds:
        key = jax.random.key_data(jax.random.key(seed)).astype(jnp.uint32)
        for method, kw in (("exprace", dict(acap=acap)),
                           ("ptbern_flat", dict(n=n))):
            got = fused_draw(packed.arena, key, dparams,
                             layout=packed.layout, method=method, cap=cap,
                             interpret=True, **kw)
            want = fused_draw_ref(packed.arena, key, dparams,
                                  layout=packed.layout, method=method,
                                  cap=cap, **kw)
            for g, w, what in zip(got, want,
                                  ("rows", "positions", "count", "overflow")):
                np.testing.assert_array_equal(
                    np.asarray(g), np.asarray(w),
                    err_msg=f"{method}/{what}/seed={seed}")
            # Positions are ascending over valid lanes, sentinel n beyond.
            pos, cnt = np.asarray(got[1]), int(got[2])
            assert (np.diff(pos[:cnt]) >= 0).all(), method
            assert (pos[cnt:] == n).all(), method


small_col = st.lists(st.integers(0, 4), min_size=0, max_size=8)


@given(a=small_col, b=small_col, c=small_col)
@settings(**SET)
def test_chain_property(a, b, c):
    m = min(len(a), len(b))
    k = min(len(b), len(c))
    db = Database.from_columns({
        "R": {"x": a[:m], "y": b[:m]},
        "S": {"y": b[:k][::-1], "z": c[:k]},  # dangling rows arise naturally
    })
    q = JoinQuery((Atom.of("R", "x", "y"), Atom.of("S", "y", "z")))
    assert_fused_draw_matches(build_shred(db, q, rep="usr"))


@given(data=st.data())
@settings(**SET)
def test_cross_product_and_extreme_p_property(data):
    """Keyless (cross-product) edge + probabilities spanning both EXPRACE
    regimes (direct p <= 1/2 and the complement inversion p > 1/2)."""
    nf = data.draw(st.integers(1, 5), label="nf")
    ne = data.draw(st.integers(1, 4), label="ne")
    db = Database.from_columns({
        "F": {"a": data.draw(st.lists(st.integers(0, 3), min_size=nf,
                                      max_size=nf), label="fa")},
        "E": {"w": data.draw(st.lists(st.integers(0, 3), min_size=ne,
                                      max_size=ne), label="ew")},
    })
    q = JoinQuery((Atom.of("F", "a"), Atom.of("E", "w")))
    shred = build_shred(db, q, rep="usr")
    p = jnp.asarray(data.draw(
        st.lists(st.sampled_from([0.01, 0.3, 0.5, 0.7, 0.99]),
                 min_size=nf, max_size=nf), label="p"))
    assert_fused_draw_matches(shred, p=p)


@given(data=st.data())
@settings(**SET)
def test_post_delta_shred_property(data):
    """Fused draw stays bit-identical on incrementally reshredded
    indexes (the arena a delta rebuilt, not the one build_shred made)."""
    def col(name, n):
        return data.draw(st.lists(st.integers(0, 3), min_size=n, max_size=n),
                         label=name)

    nr = data.draw(st.integers(1, 6), label="nr")
    ns = data.draw(st.integers(1, 6), label="ns")
    db = Database.from_columns({
        "R": {"x": col("rx", nr), "y": col("ry", nr)},
        "S": {"y": col("sy", ns), "z": col("sz", ns)},
    })
    q = JoinQuery((Atom.of("R", "x", "y"), Atom.of("S", "y", "z")))
    base = build_shred(db, q, rep="usr")
    ins = data.draw(st.integers(1, 3), label="ins")
    delta = DeltaBatch.of(S={"insert": {"y": col("iy", ins),
                                        "z": col("iz", ins)}})
    assert_fused_draw_matches(reshred_incremental(base, db, q, delta))


# ---------------------------------------------------------------------------
# Deterministic twins of the properties above — hypothesis is optional in
# the container, and the bit-identity guarantee must hold regardless.
# ---------------------------------------------------------------------------

class TestBitIdentityDeterministic:
    def test_chain(self):
        for seed in range(4):
            rng = np.random.default_rng(seed)
            nr, ns = int(rng.integers(2, 14)), int(rng.integers(2, 12))
            db = Database.from_columns({
                "R": {"x": rng.integers(0, 4, nr),
                      "y": rng.integers(0, 4, nr)},
                "S": {"y": rng.integers(0, 4, ns),
                      "z": rng.integers(0, 4, ns)},
            })
            q = JoinQuery((Atom.of("R", "x", "y"), Atom.of("S", "y", "z")))
            assert_fused_draw_matches(build_shred(db, q, rep="usr"))

    def test_three_way_star(self):
        rng = np.random.default_rng(42)
        db = Database.from_columns({
            "R": {"x": rng.integers(0, 3, 10), "y": rng.integers(0, 3, 10)},
            "S": {"y": rng.integers(0, 3, 9), "z": rng.integers(0, 3, 9)},
            "T": {"y": rng.integers(0, 3, 7), "u": rng.integers(0, 3, 7)},
        })
        q = JoinQuery((Atom.of("R", "x", "y"), Atom.of("S", "y", "z"),
                       Atom.of("T", "y", "u")))
        assert_fused_draw_matches(build_shred(db, q, rep="usr"))

    def test_cross_product_extreme_p(self):
        db = Database.from_columns({
            "F": {"a": [0, 1, 2, 3]},
            "E": {"w": [5, 6, 7]},
        })
        q = JoinQuery((Atom.of("F", "a"), Atom.of("E", "w")))
        shred = build_shred(db, q, rep="usr")
        for pv in ([0.01, 0.3, 0.5, 0.99], [0.99, 0.98, 0.97, 0.96],
                   [0.5, 0.5, 0.5, 0.5]):
            p = jnp.asarray(pv[:int(shred.root.num_rows)])
            assert_fused_draw_matches(shred, p=p)

    def test_dangling_tuples(self):
        db = Database.from_columns({
            "R": {"x": [0, 1, 2, 3, 4], "y": [0, 1, 2, 9, 9]},  # 9s dangle
            "S": {"y": [0, 1, 2, 2, 7], "z": [0, 1, 2, 3, 4]},
        })
        q = JoinQuery((Atom.of("R", "x", "y"), Atom.of("S", "y", "z")))
        assert_fused_draw_matches(build_shred(db, q, rep="usr"))

    def test_post_delta_shred(self):
        rng = np.random.default_rng(7)
        db = Database.from_columns({
            "R": {"x": rng.integers(0, 3, 8), "y": rng.integers(0, 3, 8)},
            "S": {"y": rng.integers(0, 3, 6), "z": rng.integers(0, 3, 6)},
        })
        q = JoinQuery((Atom.of("R", "x", "y"), Atom.of("S", "y", "z")))
        base = build_shred(db, q, rep="usr")
        delta = DeltaBatch.of(S={"insert": {"y": [1, 2, 0], "z": [3, 3, 3]}})
        assert_fused_draw_matches(reshred_incremental(base, db, q, delta))


# ---------------------------------------------------------------------------
# Fallback ladder / route selection
# ---------------------------------------------------------------------------

def _shred_p(seed=3, nr=14, ns=10):
    rng = np.random.default_rng(seed)
    db = Database.from_columns({
        "R": {"x": rng.integers(0, 4, nr), "y": rng.integers(0, 4, nr)},
        "S": {"y": rng.integers(0, 4, ns), "z": rng.integers(0, 4, ns)},
    })
    q = JoinQuery((Atom.of("R", "x", "y"), Atom.of("S", "y", "z")))
    shred = build_shred(db, q, rep="usr")
    p = jnp.asarray(np.clip(rng.random(int(shred.root.num_rows)), 0.05, 0.9))
    return shred, p


class TestSelectDraw:
    def _dparams(self, shred, p):
        return sampling.fused_draw_params(shred.root.weight, p,
                                          shred.root_prefE)

    def test_auto_needs_preference(self):
        shred, p = _shred_p()
        dp = self._dparams(shred, p)
        base = config.KernelPolicy()  # interpret, no prefer -> pernode
        assert probe.select_draw(shred, dp, method="exprace",
                                 policy=base) == "pernode"
        assert probe.select_draw(
            shred, dp, method="exprace",
            policy=config.KernelPolicy(prefer=True)) == "fused"
        assert probe.select_draw(
            shred, dp, method="exprace",
            policy=config.KernelPolicy(interpret=False)) == "fused"

    def test_fused_draw_optout(self):
        shred, p = _shred_p()
        dp = self._dparams(shred, p)
        pol = config.KernelPolicy(prefer=True, fused_draw=False)
        assert probe.select_draw(shred, dp, method="exprace",
                                 policy=pol) == "pernode"

    def test_vmem_budget_falls_back(self):
        shred, p = _shred_p()
        dp = self._dparams(shred, p)
        pol = config.KernelPolicy(prefer=True, vmem_limit=1)
        assert probe.select_draw(shred, dp, method="exprace",
                                 policy=pol) == "pernode"
        with pytest.raises(ValueError):
            probe.select_draw(shred, dp, method="exprace", kernels="fused",
                              policy=pol)

    def test_no_params_falls_back(self):
        shred, p = _shred_p()
        pol = config.KernelPolicy(prefer=True)
        assert probe.select_draw(shred, None, method="exprace",
                                 policy=pol) == "pernode"
        with pytest.raises(ValueError):
            probe.select_draw(shred, None, method="exprace",
                              kernels="reference", policy=pol)

    def test_ptbern_n_budget(self):
        shred, p = _shred_p()
        dp = self._dparams(shred, p)
        n = int(shred.join_size)
        pol = config.KernelPolicy(prefer=True, vmem_limit=max(n, 64))
        assert probe.select_draw(shred, dp, method="ptbern_flat", n=n,
                                 policy=pol) == "fused"
        tight = config.KernelPolicy(prefer=True, vmem_limit=max(n // 2, 1))
        # n over the budget: Theta(n) lanes no longer fit VMEM.
        if n > 1 and shred.packed.layout.size <= max(n // 2, 1):
            assert probe.select_draw(shred, dp, method="ptbern_flat", n=n,
                                     policy=tight) == "pernode"

    def test_explicit_pernode_always_honored(self):
        shred, p = _shred_p()
        dp = self._dparams(shred, p)
        pol = config.KernelPolicy(prefer=True)
        assert probe.select_draw(shred, dp, method="exprace",
                                 kernels="pernode", policy=pol) == "pernode"

    def test_reference_runs_with_kernels_disabled(self):
        shred, p = _shred_p()
        dp = self._dparams(shred, p)
        pol = config.KernelPolicy(enabled=False)
        assert probe.select_draw(shred, dp, method="exprace",
                                 kernels="reference", policy=pol) == "reference"
        with pytest.raises(ValueError):
            probe.select_draw(shred, dp, method="exprace", kernels="fused",
                              policy=pol)


# ---------------------------------------------------------------------------
# KernelPolicy resolution order
# ---------------------------------------------------------------------------

class TestKernelPolicy:
    def test_env_is_default_constructor(self, monkeypatch):
        monkeypatch.setenv("REPRO_PALLAS_DISABLE", "1")
        assert not config.current_policy().enabled
        monkeypatch.setenv("REPRO_PALLAS_DISABLE", "0")
        assert config.current_policy().enabled
        # Historical empty-string semantics: INTERPRET='' means True (the
        # CI matrix relies on it), PREFER='' means False.
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "")
        monkeypatch.setenv("REPRO_PALLAS_PREFER", "")
        pol = config.current_policy()
        assert pol.interpret and not pol.prefer and not pol.preferred
        monkeypatch.setenv("REPRO_PALLAS_PREFER", "1")
        assert config.current_policy().preferred

    def test_override_beats_env(self, monkeypatch):
        monkeypatch.setenv("REPRO_PALLAS_DISABLE", "1")
        with config.override(config.KernelPolicy(enabled=True)):
            assert config.current_policy().enabled
        assert not config.current_policy().enabled

    def test_per_call_beats_override(self):
        with config.override(config.KernelPolicy(enabled=False)):
            pol = config.KernelPolicy(enabled=True, prefer=True)
            assert config.current_policy(pol).preferred
        # Contexts nest and unwind.
        assert config.current_policy().enabled

    def test_preferred_property(self):
        assert config.KernelPolicy(interpret=False).preferred
        assert config.KernelPolicy(interpret=True, prefer=True).preferred
        assert not config.KernelPolicy(interpret=True).preferred
        assert not config.KernelPolicy(enabled=False,
                                       interpret=False).preferred

    def test_bench_tiny_helpers(self, monkeypatch):
        # monkeypatch records the pre-test value and restores at teardown,
        # even though set_bench_tiny mutates the env directly in config.py.
        monkeypatch.setenv("REPRO_BENCH_TINY", "0")
        config.set_bench_tiny(True)
        assert config.bench_tiny()
        config.set_bench_tiny(False)
        assert not config.bench_tiny()


# ---------------------------------------------------------------------------
# Engine route integration (DrawSpec.kernels)
# ---------------------------------------------------------------------------

class TestEngineRoutes:
    def _db_q(self):
        rng = np.random.default_rng(9)
        db = Database.from_columns({
            "R": {"x": rng.integers(0, 5, 24), "y": rng.integers(0, 5, 24),
                  "p": np.clip(rng.random(24), 0.05, 0.9)},
            "S": {"y": rng.integers(0, 5, 18), "z": rng.integers(0, 5, 18)},
        })
        q = JoinQuery((Atom.of("R", "x", "y", "p"), Atom.of("S", "y", "z")),
                      prob_var="p")
        return db, q

    def test_auto_routes_fused_under_preference(self):
        db, q = self._db_q()
        with config.override(config.KernelPolicy(prefer=True)):
            eng = QueryEngine(db)
            plan = eng.compile(q)
            assert plan._route == "fused"
            key = jax.random.key(11)
            sf = plan.sample(key)
            sref = eng.poisson_sample(q, key, kernels="reference")
            np.testing.assert_array_equal(np.asarray(sf.positions),
                                          np.asarray(sref.positions))
            assert int(sf.count) == int(sref.count)
            for v in sf.columns:
                np.testing.assert_array_equal(np.asarray(sf.columns[v]),
                                              np.asarray(sref.columns[v]))

    def test_auto_stays_pernode_without_preference(self):
        db, q = self._db_q()
        # Pin the default policy: the CI interpret leg exports
        # REPRO_PALLAS_PREFER=1, which would flip the auto route.
        with config.override(config.KernelPolicy()):
            plan = QueryEngine(db).compile(q)
        assert plan._route == "pernode"

    def test_kernels_is_plan_identity(self):
        db, q = self._db_q()
        eng = QueryEngine(db)
        a = eng.compile(q, kernels="pernode")
        b = eng.compile(q, kernels="reference")
        assert a is not b
        assert eng.compile(q, kernels="pernode") is a  # warm hit

    def test_batched_fused_lanes_match_single(self):
        db, q = self._db_q()
        with config.override(config.KernelPolicy(prefer=True)):
            plan = QueryEngine(db).compile(q)
            assert plan._route == "fused"
            keys = jax.random.split(jax.random.key(12), 5)
            sb = plan.sample_batch(keys)
            for i in range(5):
                si = plan.sample(keys[i])
                np.testing.assert_array_equal(np.asarray(sb.positions[i]),
                                              np.asarray(si.positions))
                assert int(sb.count[i]) == int(si.count)

    def test_apply_delta_rebinds_route(self):
        db, q = self._db_q()
        with config.override(config.KernelPolicy(prefer=True)):
            eng = QueryEngine(db)
            plan = eng.compile(q)
            key = jax.random.key(13)
            plan.sample(key)  # warm
            eng.apply_delta(DeltaBatch.of(
                S={"insert": {"y": [1, 3], "z": [0, 2]}}))
            plan2 = eng.compile(q)
            assert plan2._route == "fused"
            # warm upgraded plan == cold engine on the post-delta snapshot
            sf = plan2.sample(key)
            sc = QueryEngine(eng.db).compile(q).sample(key)
            np.testing.assert_array_equal(np.asarray(sf.positions),
                                          np.asarray(sc.positions))

    def test_explicit_fused_without_preference(self):
        """kernels='fused' bypasses the preference gate (capability and
        enablement still required)."""
        db, q = self._db_q()
        eng = QueryEngine(db)
        plan = eng.compile(q, kernels="fused")
        assert plan._route == "fused"
        s = plan.sample(jax.random.key(14))
        assert int(s.count) >= 0

    def test_explicit_fused_raises_when_disabled(self):
        db, q = self._db_q()
        with config.override(config.KernelPolicy(enabled=False)):
            with pytest.raises(ValueError, match="fused"):
                QueryEngine(db).compile(q, kernels="fused")

    def test_ptbern_fused_matches_reference(self):
        db, q = self._db_q()
        with config.override(config.KernelPolicy(prefer=True)):
            eng = QueryEngine(db)
            plan = eng.compile(q, method="ptbern_flat")
            assert plan._route == "fused"
            key = jax.random.key(15)
            sf = plan.sample(key)
            sref = eng.poisson_sample(q, key, method="ptbern_flat",
                                      kernels="reference")
            np.testing.assert_array_equal(np.asarray(sf.positions),
                                          np.asarray(sref.positions))

    def test_per_call_rep_pins_pernode(self):
        """An explicit rep override draws from the per-node sampler (the
        fused kernel has no rep), matching the no-preference stream."""
        db, q = self._db_q()
        with config.override(config.KernelPolicy(prefer=True)):
            plan = QueryEngine(db).compile(q)
            assert plan._route == "fused"
            key = jax.random.key(16)
            s_rep = plan.sample(key, rep="usr")
        s_pn = QueryEngine(db).compile(q, kernels="pernode").sample(key)
        np.testing.assert_array_equal(np.asarray(s_rep.positions),
                                      np.asarray(s_pn.positions))
