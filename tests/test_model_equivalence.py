"""Strong model invariant: prefill-via-decode == full forward, per family.

One assertion validates the whole serving stack against the training stack:
KV caches, RoPE positions, SSM/RWKV recurrent states, cross-attention
caches, window masks, and the chunked-scan attention all have to agree with
the one-shot forward pass to float32 precision.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import encode, forward, prefill

# one representative per family mechanism
FAMS = ["smollm_135m", "gemma3_1b", "olmoe_1b_7b", "whisper_small",
        "rwkv6_7b", "zamba2_1p2b", "llama32_vision_11b"]


@pytest.mark.parametrize("arch", FAMS)
def test_decode_equals_forward(arch):
    cfg = configs.reduced(configs.get_config(arch))
    key = jax.random.key(0)
    params = jax.jit(lambda k: __import__("repro.models", fromlist=["init_model"])
                     .init_model(cfg, k))(key)
    B, S = 2, 9
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab, jnp.int32)
    mem = None
    if cfg.n_memory_tokens and not cfg.has_encoder:
        mem = jax.random.normal(key, (B, cfg.n_memory_tokens, cfg.d_model), jnp.float32)
    if cfg.has_encoder:
        frames = jax.random.normal(key, (B, cfg.n_memory_tokens, cfg.enc_d_model),
                                   jnp.float32)
        mem = encode(params, cfg, frames)
    logits_full, _ = forward(params, cfg, tokens, mem)
    logits_dec, _ = prefill(params, cfg, tokens, S + 2, mem)
    err = float(jnp.max(jnp.abs(logits_full[:, -1].astype(jnp.float32)
                                - logits_dec[:, 0].astype(jnp.float32))))
    assert err < 5e-3, f"{arch}: decode/forward diverge by {err}"
