"""Integration: the fault-tolerant training loop end-to-end on CPU.

Covers: loss decreases on Poisson-join-sampled data; checkpoint/restart
resumes mid-run and matches an uninterrupted run exactly (bitwise state);
corrupt newest checkpoint falls back; serving decodes a batch.
"""
import dataclasses

import numpy as np
import jax
import pytest

from repro.launch.train import TrainConfig, train


def _tc(tmp_path, **kw):
    base = dict(arch="smollm_135m", steps=30, batch=4, seq_len=32,
                ckpt_dir=str(tmp_path / "ckpt"), ckpt_every=10, log_every=1000)
    base.update(kw)
    return TrainConfig(**base)


@pytest.mark.slow
def test_loss_decreases_on_join_sampled_data(tmp_path):
    out = train(_tc(tmp_path, steps=60, data="poisson_join"))
    assert out["losses"][-1] < out["losses"][0]


@pytest.mark.slow
def test_restart_resumes_and_matches_uninterrupted(tmp_path):
    # run A: 30 steps straight through
    a = train(_tc(tmp_path, ckpt_dir=str(tmp_path / "a")))
    # run B: 20 steps (checkpoints at 10, 20), then "crash" + resume to 30
    b1 = train(_tc(tmp_path, steps=20, ckpt_dir=str(tmp_path / "b")))
    b2 = train(_tc(tmp_path, steps=30, ckpt_dir=str(tmp_path / "b")))
    # resumed run must produce identical trailing losses (deterministic data,
    # bitwise-restored state)
    np.testing.assert_allclose(a["losses"][20:], b2["losses"], rtol=1e-5)


@pytest.mark.slow
def test_resume_skips_corrupt_checkpoint(tmp_path):
    train(_tc(tmp_path, steps=20, ckpt_dir=str(tmp_path / "c")))
    # corrupt step 20, leave step 10 intact
    shard = tmp_path / "c" / "step_0000000020" / "shard0.npz"
    shard.write_bytes(b"corrupted!")
    out = train(_tc(tmp_path, steps=25, ckpt_dir=str(tmp_path / "c")))
    # resumed from 10 -> produced losses for steps 10..24
    assert len(out["losses"]) == 15


@pytest.mark.slow
def test_serve_batch_decodes():
    from repro.launch.serve import Request, serve_batch
    reqs = [Request(prompt=[1, 2, 3], max_new=4),
            Request(prompt=[5, 6, 7, 8, 9], max_new=4)]
    done = serve_batch("smollm_135m", reqs)
    for r in done:
        assert len(r.out) == 4
        assert all(0 <= t < 256 for t in r.out)


@pytest.mark.slow
def test_serve_hybrid_arch():
    from repro.launch.serve import Request, serve_batch
    done = serve_batch("zamba2_1p2b", [Request(prompt=[1, 2, 3, 4], max_new=3)])
    assert len(done[0].out) == 3
