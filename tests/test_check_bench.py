"""The CI perf gate's verdict taxonomy: regression (exit 1) vs coverage
loss (exit 3 — a baselined suite/rows missing from the fresh run), plus the
baseline refresh path. A renamed suite must NOT pass silently and must be
distinguishable from a slowdown.
"""
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "tools"))

import check_bench  # noqa: E402


CSV = """name,us_per_call,derived
# --- alpha ---
alpha/a,100.0,
alpha/b,200.0,
alpha/info,0.0,cache=hit
# --- beta ---
beta/x,50.0,
"""


def _write(tmp_path, name, text):
    p = tmp_path / name
    p.write_text(text)
    return p


def _baseline(tmp_path, suite, rows):
    p = tmp_path / f"BENCH_{suite}.json"
    p.write_text(json.dumps({"suite": suite, "rows": rows}))
    return p


def test_parse_skips_informational_rows(tmp_path):
    suites = check_bench.parse_csv(_write(tmp_path, "b.csv", CSV))
    assert suites == {"alpha": {"alpha/a": 100.0, "alpha/b": 200.0},
                      "beta": {"beta/x": 50.0}}


def test_gate_ok(tmp_path):
    csv = _write(tmp_path, "b.csv", CSV)
    _baseline(tmp_path, "alpha", {"alpha/a": 100.0, "alpha/b": 200.0})
    suites = check_bench.parse_csv(csv)
    assert check_bench.check(
        suites, check_bench.load_baselines(tmp_path), 0.30) == 0


def test_gate_regression_exit_1(tmp_path):
    csv = _write(tmp_path, "b.csv", CSV)
    _baseline(tmp_path, "alpha", {"alpha/a": 10.0, "alpha/b": 20.0})
    suites = check_bench.parse_csv(csv)
    assert check_bench.check(
        suites, check_bench.load_baselines(tmp_path),
        0.30) == check_bench.EXIT_REGRESSED == 1


def test_missing_suite_exit_3(tmp_path, capsys):
    """A suite present in the baseline but absent from the run (renamed or
    dropped) is a coverage failure with its own exit code and an
    actionable message."""
    csv = _write(tmp_path, "b.csv", CSV)
    _baseline(tmp_path, "gamma", {"gamma/g": 10.0})
    suites = check_bench.parse_csv(csv)
    rc = check_bench.check(suites, check_bench.load_baselines(tmp_path), 0.30)
    assert rc == check_bench.EXIT_MISSING_SUITE == 3
    err = capsys.readouterr().err
    assert "gamma" in err and "--update gamma" in err


def test_renamed_rows_exit_3(tmp_path):
    csv = _write(tmp_path, "b.csv", CSV)
    _baseline(tmp_path, "alpha",
              {"alpha/old1": 10.0, "alpha/old2": 10.0, "alpha/a": 100.0})
    suites = check_bench.parse_csv(csv)
    assert check_bench.check(
        suites, check_bench.load_baselines(tmp_path),
        0.30) == check_bench.EXIT_MISSING_SUITE


def test_regression_beats_missing_in_exit_code(tmp_path, capsys):
    """Mixed failure: the regression verdict wins the exit code (following
    the exit-3 refresh playbook would bake the slowdown into the baseline),
    but both failures are still reported."""
    csv = _write(tmp_path, "b.csv", CSV)
    _baseline(tmp_path, "alpha", {"alpha/a": 10.0, "alpha/b": 10.0})
    _baseline(tmp_path, "gamma", {"gamma/g": 10.0})
    suites = check_bench.parse_csv(csv)
    rc = check_bench.check(suites, check_bench.load_baselines(tmp_path), 0.30)
    assert rc == check_bench.EXIT_REGRESSED
    err = capsys.readouterr().err
    assert "alpha" in err and "gamma" in err


def test_gated_row_regression_despite_healthy_median(tmp_path, capsys):
    """An SLO row (p99) blows past the threshold while the median over the
    suite stays healthy: gate_rows still fails the gate with exit 1."""
    csv = _write(tmp_path, "b.csv", """name,us_per_call,derived
# --- serve ---
serve/p50,100.0,
serve/p99,500.0,
serve/other,100.0,
""")
    p = tmp_path / "BENCH_serve.json"
    p.write_text(json.dumps({
        "suite": "serve",
        "rows": {"serve/p50": 100.0, "serve/p99": 200.0,
                 "serve/other": 100.0},
        "gate_rows": ["serve/p99"]}))
    suites = check_bench.parse_csv(csv)
    rc = check_bench.check(suites, check_bench.load_baselines(tmp_path), 0.30)
    assert rc == check_bench.EXIT_REGRESSED
    assert "gated row serve/p99" in capsys.readouterr().err


def test_gated_row_within_threshold_passes(tmp_path):
    csv = _write(tmp_path, "b.csv", """name,us_per_call,derived
# --- serve ---
serve/p50,100.0,
serve/p99,220.0,
""")
    p = tmp_path / "BENCH_serve.json"
    p.write_text(json.dumps({
        "suite": "serve",
        "rows": {"serve/p50": 100.0, "serve/p99": 200.0},
        "gate_rows": ["serve/p99"]}))
    suites = check_bench.parse_csv(csv)
    assert check_bench.check(
        suites, check_bench.load_baselines(tmp_path), 0.30) == 0


def test_missing_gated_row_is_coverage_failure(tmp_path, capsys):
    """Enough rows match for the median, but the gated row itself was
    renamed away: exit 3, not a silent pass."""
    csv = _write(tmp_path, "b.csv", """name,us_per_call,derived
# --- serve ---
serve/p50,100.0,
serve/a,100.0,
serve/b,100.0,
""")
    p = tmp_path / "BENCH_serve.json"
    p.write_text(json.dumps({
        "suite": "serve",
        "rows": {"serve/p50": 100.0, "serve/a": 100.0, "serve/b": 100.0,
                 "serve/p99": 200.0},
        "gate_rows": ["serve/p99"]}))
    suites = check_bench.parse_csv(csv)
    rc = check_bench.check(suites, check_bench.load_baselines(tmp_path), 0.30)
    assert rc == check_bench.EXIT_MISSING_SUITE
    assert "gated row 'serve/p99' missing" in capsys.readouterr().err


def test_update_auto_gates_p99_rows_for_new_baseline(tmp_path):
    csv = _write(tmp_path, "b.csv", """name,us_per_call,derived
# --- serve ---
serve/p50,100.0,
serve/p99,200.0,
""")
    suites = check_bench.parse_csv(csv)
    assert check_bench.update(suites, ["serve"], tmp_path) == 0
    data = json.loads((tmp_path / "BENCH_serve.json").read_text())
    assert data["gate_rows"] == ["serve/p99"]


def test_update_preserves_and_prunes_existing_gate_rows(tmp_path):
    """A refresh keeps hand-chosen gates (even non-p99 ones) and drops
    gates whose rows no longer exist."""
    p = tmp_path / "BENCH_serve.json"
    p.write_text(json.dumps({
        "suite": "serve",
        "rows": {"serve/p50": 1.0, "serve/gone": 1.0},
        "gate_rows": ["serve/p50", "serve/gone"]}))
    csv = _write(tmp_path, "b.csv", """name,us_per_call,derived
# --- serve ---
serve/p50,100.0,
serve/p99,200.0,
""")
    suites = check_bench.parse_csv(csv)
    assert check_bench.update(suites, ["serve"], tmp_path) == 0
    data = json.loads(p.read_text())
    assert data["gate_rows"] == ["serve/p50"]  # kept, pruned, NOT auto-p99


def test_update_writes_baseline(tmp_path):
    csv = _write(tmp_path, "b.csv", CSV)
    suites = check_bench.parse_csv(csv)
    assert check_bench.update(suites, ["beta"], tmp_path) == 0
    data = json.loads((tmp_path / "BENCH_beta.json").read_text())
    assert data == {"suite": "beta", "rows": {"beta/x": 50.0}}
    # the freshly written baseline gates clean
    assert check_bench.check(
        suites, check_bench.load_baselines(tmp_path), 0.30) == 0


def test_repo_baselines_name_live_suites():
    """Every committed BENCH_*.json names a suite benchmarks.run defines —
    the committed baselines can never themselves trip exit 3."""
    run_py = (ROOT / "benchmarks" / "run.py").read_text()
    for f in sorted(ROOT.glob("BENCH_*.json")):
        suite = json.loads(f.read_text())["suite"]
        assert f'("{suite}"' in run_py, \
            f"{f.name} names suite {suite!r} not defined in benchmarks/run.py"
