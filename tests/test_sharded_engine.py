"""The sharded engine path (DESIGN.md §8): planner, correctness vs the
single-device engine, and the stacked-shred cache contract.

(a) sharded full-join == single-device full join (bit-identical, order
    included: shard flattens concatenate to the global flatten) and
    sharded samples are valid join tuples, bit-reproducible against a
    host loop folding the shard index into the same base key;
(b) a second call with the same (fingerprint, mesh) never rebuilds the
    stacked shred (CacheStats counters);
(c) the shard planner respects data axes and ``min_shard_rows``.

These tests run on whatever devices exist: the in-process tests force the
stacked path via explicit ``axes`` (so 1-device CI still exercises it),
and the CI 8-virtual-device matrix leg (XLA_FLAGS
--xla_force_host_platform_device_count=8) runs them on a real multi-device
mesh. The slow subprocess test pins 8 devices regardless.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import pytest

from repro.core import Atom, Database, JoinQuery
from repro.core.distributed import (
    build_stacked_shred, partition_root, semijoin_filter,
)
from repro.engine import CapacityPolicy, QueryEngine, ShardedPlan, plan_shards
from repro.engine.executors import _sample_jit


@pytest.fixture(scope="module")
def db():
    rng = np.random.default_rng(11)
    return Database.from_columns({
        "R": {"x": rng.integers(0, 12, 90), "p": rng.random(90) * 0.5},
        "S": {"x": rng.integers(0, 12, 140), "y": rng.integers(0, 9, 140)},
        "T": {"y": rng.integers(0, 9, 60), "z": np.arange(60)},
    })


@pytest.fixture(scope="module")
def query():
    return JoinQuery((Atom.of("R", "x", "p"), Atom.of("S", "x", "y"),
                      Atom.of("T", "y", "z")), prob_var="p")


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((len(jax.devices()),), ("data",))


def _tuples(cols, keys, k=None):
    arrs = [np.asarray(cols[v]) for v in keys]
    if k is not None:
        arrs = [a[:k] for a in arrs]
    return list(zip(*arrs))


# -- (c) shard planner ------------------------------------------------------

def test_plan_shards_picks_data_axes():
    devs = jax.devices()
    mesh = jax.make_mesh((len(devs), 1), ("data", "model"))
    sp = plan_shards(mesh, root_rows=10_000)
    if len(devs) > 1:
        assert sp.axes == ("data",) and sp.num_shards == len(devs)
    else:
        assert sp.axes == () and sp.num_shards == 1
    # model-only meshes never shard the root
    mm = jax.make_mesh((len(devs),), ("model",))
    assert plan_shards(mm, root_rows=10_000).num_shards == 1


def test_plan_shards_min_rows_floor():
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    tight = CapacityPolicy(min_shard_rows=10**9)
    assert plan_shards(mesh, root_rows=100, policy=tight).num_shards == 1
    # explicit axes are honored regardless of the floor
    sp = plan_shards(mesh, root_rows=1, policy=tight, axes=("data",))
    assert sp.num_shards == len(jax.devices())


# -- library layer ----------------------------------------------------------

def test_partition_root_covers_and_pads(db, query):
    part = partition_root(db, query, 4)
    assert sum(part.valid) == 90
    assert all(d.relations[part.root_name].num_rows == part.rows_per_shard
               for d in part.shards)


def test_semijoin_filter_preserves_join(db, query):
    filtered = semijoin_filter(db, query)
    engine = QueryEngine(db)
    a = engine.full_join(query)
    b = QueryEngine(filtered).full_join(query)
    for v in a:
        np.testing.assert_array_equal(np.asarray(a[v]), np.asarray(b[v]))
    # it only ever shrinks the non-root relations
    assert filtered.relations["S"].num_rows <= db.relations["S"].num_rows
    assert filtered.relations["R"].num_rows == db.relations["R"].num_rows


def test_stacked_shred_join_sizes(db, query):
    st = build_stacked_shred(db, query, 4)
    assert st.join_size == QueryEngine(db).join_size(query)


# -- (a) correctness vs the single-device engine ----------------------------

def test_sharded_full_join_bit_identical(db, query, mesh):
    engine = QueryEngine(db)
    got = engine.full_join(query, mesh=mesh, axes=("data",))
    want = engine.full_join(query)
    assert set(got) == set(want)
    for v in want:
        np.testing.assert_array_equal(np.asarray(got[v]), np.asarray(want[v]))


def test_sharded_sample_is_valid_and_fold_reproducible(db, query, mesh):
    engine = QueryEngine(db)
    plan = engine.compile_sharded(query, mesh, axes=("data",))
    assert isinstance(plan, ShardedPlan)
    key = jax.random.key(7)
    smp = engine.sample(query, key, mesh=mesh, axes=("data",))
    k = int(smp.count)
    full = engine.full_join(query)
    keys = tuple(sorted(full))
    fullset = set(_tuples(full, keys))
    got = _tuples(smp.columns, keys, k)
    assert all(t in fullset for t in got)

    # Host emulation of the device-folded key scheme: bit-identical.
    st = plan.stacked
    ref, ref_pos, base = [], [], 0
    for s in range(plan.num_shards):
        shred_s = jax.tree.map(lambda x: x[s], st.shred)
        r = _sample_jit(shred_s, st.w[s], st.p[s], st.prefE[s],
                        jax.random.fold_in(key, s), cap=plan.cap,
                        rep=plan.rep, method="exprace", acap=plan.acap)
        c = int(r.count)
        ref += _tuples(r.columns, keys, c)
        ref_pos += list(np.asarray(r.positions)[:c] + base)
        base += int(st.prefE[s, -1])
    assert got == ref
    np.testing.assert_array_equal(np.asarray(smp.positions)[:k], ref_pos)


def test_sharded_sample_statistics(db, query, mesh):
    engine = QueryEngine(db)
    plan = engine.compile_sharded(query, mesh, axes=("data",))
    single = engine.compile(query)
    cnts = [int(engine.sample(query, jax.random.key(i), mesh=mesh,
                              axes=("data",)).count) for i in range(40)]
    from repro.core import estimate
    exp = single.expected_k()
    sd = float(estimate.sample_std(single.w, single.p))
    z = (np.mean(cnts) - exp) / (sd / 40 ** 0.5)
    assert abs(z) < 4.5, (np.mean(cnts), exp, z)
    assert plan.expected_k() == pytest.approx(exp)


# -- (b) cache behavior -----------------------------------------------------

def test_sharded_warm_no_stacked_rebuild(db, query, mesh):
    engine = QueryEngine(db)
    engine.sample(query, jax.random.key(0), mesh=mesh, axes=("data",))
    st0 = engine.stats.snapshot()
    assert st0.shred_builds == 1
    # Warm: new draws, the other entry point, and a second mesh object of
    # the same shape all reuse the one stacked shred.
    engine.sample(query, jax.random.key(1), mesh=mesh, axes=("data",))
    engine.full_join(query, mesh=mesh, axes=("data",))
    mesh2 = jax.make_mesh((len(jax.devices()),), ("data",))
    engine.sample(query, jax.random.key(2), mesh=mesh2, axes=("data",))
    st1 = engine.stats
    assert st1.shred_builds == st0.shred_builds, \
        "warm sharded calls must not rebuild the stacked shred"
    assert st1.plan_hits >= 2
    # The single-device path is a *different* shred cache entry.
    engine.sample(query, jax.random.key(3))
    assert engine.stats.shred_builds == st0.shred_builds + 1


def test_sharded_empty_root(mesh):
    """A 0-row root partitions into 0-row shards; both entry points return
    empty, matching the single-device contract."""
    db0 = Database.from_columns({
        "R": {"x": np.zeros((0,), np.int64), "p": np.zeros((0,), np.float64)},
        "S": {"x": np.array([1, 2]), "y": np.array([3, 4])},
    })
    q = JoinQuery((Atom.of("R", "x", "p"), Atom.of("S", "x", "y")),
                  prob_var="p")
    engine = QueryEngine(db0)
    smp = engine.sample(q, jax.random.key(0), mesh=mesh, axes=("data",))
    assert int(smp.count) == 0 and not bool(smp.overflow)
    full = engine.full_join(q, mesh=mesh, axes=("data",))
    assert all(len(v) == 0 for v in full.values())


def test_sharded_auto_redraw_overflow(db, query, mesh):
    """A deliberately tiny capacity overflows; auto mode recovers."""
    engine = QueryEngine(db)
    s = engine.sample(query, jax.random.key(4), mesh=mesh, axes=("data",),
                      cap=1)
    assert bool(s.overflow)
    s = engine.sample(query, jax.random.key(4), mesh=mesh, axes=("data",),
                      auto=True)
    assert not bool(s.overflow)


# -- acceptance: real 8-device mesh (subprocess) ----------------------------

SCRIPT = textwrap.dedent("""
    import numpy as np, jax
    assert len(jax.devices()) == 8, jax.devices()
    from repro.core import Atom, Database, JoinQuery
    from repro.engine import QueryEngine, ShardedPlan

    rng = np.random.default_rng(11)
    db = Database.from_columns({
        "R": {"x": rng.integers(0, 12, 90), "p": rng.random(90) * 0.5},
        "S": {"x": rng.integers(0, 12, 140), "y": rng.integers(0, 9, 140)},
        "T": {"y": rng.integers(0, 9, 60), "z": np.arange(60)},
    })
    q = JoinQuery((Atom.of("R", "x", "p"), Atom.of("S", "x", "y"),
                   Atom.of("T", "y", "z")), prob_var="p")
    mesh = jax.make_mesh((8,), ("data",))
    engine = QueryEngine(db)
    plan = engine.compile_sharded(q, mesh)      # auto planner, real 8 shards
    assert isinstance(plan, ShardedPlan) and plan.num_shards == 8

    # Sharded sample == the single-device engine under the same
    # seed-folding scheme (one plain-engine draw per shard block).
    key = jax.random.key(3)
    smp = engine.sample(q, key, mesh=mesh)
    k = int(smp.count)
    keys = tuple(sorted(smp.columns))
    got = sorted(zip(*[np.asarray(smp.columns[v])[:k] for v in keys]))

    from repro.core.distributed import partition_root, semijoin_filter
    part = partition_root(semijoin_filter(db, q), q, 8)
    ref = []
    for s, sdb in enumerate(part.shards):
        # kernels="pernode": the sharded executors always run the per-node
        # route, so the per-shard reference must too — under a Pallas-
        # preferring policy a plain engine would auto-route to the fused
        # draw, whose stream is its own (DESIGN.md section 14).
        r = QueryEngine(sdb).sample(q, jax.random.fold_in(key, s),
                                    cap=plan.cap, acap=plan.acap,
                                    kernels="pernode")
        c = int(r.count)
        ref += list(zip(*[np.asarray(r.columns[v])[:c] for v in keys]))
    assert got == sorted(ref), (len(got), len(ref))

    # Warm path: zero stacked-shred rebuilds.
    before = engine.stats.shred_builds
    engine.sample(q, jax.random.key(4), mesh=mesh)
    engine.full_join(q, mesh=mesh)
    assert engine.stats.shred_builds == before
    print("SHARDED_ENGINE_OK")
""")


@pytest.mark.slow
def test_sharded_engine_8_devices():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert "SHARDED_ENGINE_OK" in r.stdout
