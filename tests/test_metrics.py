"""Nearest-rank percentile (launch/metrics.py): the estimator the serve
loop, fleet router, and serve benchmark all report through.

The bug this replaces: ``int(q * len(ys))`` as a 0-based index is one rank
high — p50 of [1,2,3,4] returned 3 and p99 overshot on short lists.
"""
import pytest

from repro.launch.metrics import latency_summary, percentile


def test_p50_even_length_is_lower_median():
    # nearest-rank: ceil(0.5 * 4) = 2nd smallest
    assert percentile([1, 2, 3, 4], 0.5) == 2
    assert percentile([4, 3, 2, 1], 0.5) == 2  # order-insensitive


def test_p50_odd_length_is_middle():
    assert percentile([5, 1, 3], 0.5) == 3


def test_p99_short_list_is_max_only_when_rank_says_so():
    # N=4: ceil(0.99*4)=4 -> max; that's the correct nearest-rank answer.
    assert percentile([1, 2, 3, 4], 0.99) == 4
    # N=200: ceil(0.99*200)=198 -> NOT the max (the old impl indexed
    # int(0.99*200)=198 0-based = the 199th value, overshooting by a rank).
    xs = list(range(1, 201))
    assert percentile(xs, 0.99) == 198


def test_extremes_and_singleton():
    assert percentile([7.5], 0.5) == 7.5
    assert percentile([1, 2, 3], 0.0) == 1  # rank clamps to 1
    assert percentile([1, 2, 3], 1.0) == 3


def test_known_quartiles():
    # Classic nearest-rank example: ceil(q*N) over a 10-sample list.
    xs = [15, 20, 35, 40, 50, 55, 60, 70, 80, 90]
    assert percentile(xs, 0.3) == 35   # ceil(3.0) = 3rd
    assert percentile(xs, 0.35) == 40  # ceil(3.5) = 4th
    assert percentile(xs, 0.9) == 80   # ceil(9.0) = 9th


def test_validation():
    with pytest.raises(ValueError, match="empty"):
        percentile([], 0.5)
    with pytest.raises(ValueError, match="q must be"):
        percentile([1.0], 1.5)
    with pytest.raises(ValueError, match="q must be"):
        percentile([1.0], -0.1)


def test_latency_summary_units_and_empty():
    s = latency_summary([0.001, 0.002, 0.004])
    assert s["p50_ms"] == pytest.approx(2.0)
    assert s["max_ms"] == pytest.approx(4.0)
    assert latency_summary([]) == {"p50_ms": 0.0, "p99_ms": 0.0,
                                   "max_ms": 0.0}
