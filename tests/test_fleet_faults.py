"""Fault injection over the fleet (DESIGN.md §12): crash a replica
mid-flush, delay a replica's delta application past a version barrier,
drop transport messages — and in every case the router's *exact* retry
(draws are pure given seed + version) completes every accepted request at
its stamped version, with nothing lost and nothing served twice.
"""
import jax
import numpy as np
import pytest

from repro.core import Atom, Database, JoinQuery
from repro.core.delta import DeltaBatch
from repro.engine import QueryEngine, query_fingerprint
from repro.launch.fleet import (
    CRASH, DOWN, DROP, FaultInjector, Fleet, JoinSampleRequest, Rejected,
    UpdateRequest,
)


@pytest.fixture(scope="module")
def db():
    rng = np.random.default_rng(3)
    return Database.from_columns({
        "R": {"x": rng.integers(0, 10, 70), "p": rng.random(70) * 0.5},
        "S": {"x": rng.integers(0, 10, 110), "y": rng.integers(0, 8, 110)},
    })


@pytest.fixture(scope="module")
def q(db):
    return JoinQuery((Atom.of("R", "x", "p"), Atom.of("S", "x", "y")),
                     prob_var="p")


def _delta(i=0):
    return DeltaBatch.of(S={"insert": {"x": [i % 10, (i + 3) % 10],
                                       "y": [i % 8, (i + 1) % 8]},
                            "delete": [0]})


def _check_complete_and_unique(accepted, done):
    """The fleet invariant: every accepted request completes exactly once
    (nothing lost, nothing delivered twice)."""
    draws = [r for r in done if isinstance(r, JoinSampleRequest)]
    assert {id(r) for r in draws} == {id(r) for r in accepted}
    assert len(draws) == len(accepted)
    assert all(r.count is not None and r.db_version is not None
               for r in draws)


# -- crash a replica mid-flush ----------------------------------------------

def test_crash_mid_flush_retries_on_healthy_replica(db, q):
    faults = FaultInjector()
    fleet = Fleet(db, replicas=3, max_batch=4, max_wait_ms=1e9,
                  faults=faults, retry_timeout_s=0.05)
    home = fleet.router._route(query_fingerprint(q))
    # the 2nd flush on the home replica dies with the whole batch pending
    faults.inject(f"{home}:flush", CRASH, at=2)
    accepted = [JoinSampleRequest(query=q, seed=i) for i in range(12)]
    for r in accepted:
        assert fleet.submit(r) is None
    done = fleet.drain()
    _check_complete_and_unique(accepted, done)
    assert faults.pending == 0  # the fault really fired
    assert fleet.router.health[home] == DOWN
    assert fleet.router.retries >= 4  # the lost batch was re-sent
    # results are still bit-identical to a cold single engine per seed
    ref = QueryEngine(db)
    for r in accepted:
        assert r.db_version == 0
        want = ref.sample(q, jax.random.key(r.seed))
        assert (r.count, r.overflow) == (int(want.count), bool(want.overflow))


def test_crash_during_catchup_apply(db, q):
    """A replica dying while applying a log delta at the barrier: the
    stamped draw that forced the barrier is retried elsewhere and still
    completes at its stamped (post-delta) version."""
    faults = FaultInjector()
    fleet = Fleet(db, replicas=2, max_batch=100, max_wait_ms=1e9,
                  faults=faults, retry_timeout_s=0.05)
    home = fleet.router._route(query_fingerprint(q))
    faults.inject(f"{home}:apply", CRASH)
    fleet.submit(UpdateRequest(_delta()))
    r = JoinSampleRequest(query=q, seed=5)  # stamped v1 -> forces catch-up
    assert fleet.submit(r) is None
    done = fleet.drain()
    assert faults.pending == 0
    assert r in done and r.db_version == 1
    want = QueryEngine(db.apply(_delta())).sample(q, jax.random.key(5))
    assert r.count == int(want.count)


# -- delay delta application past a version barrier --------------------------

def test_delayed_draw_crosses_version_barrier_exact_stale_serve(db, q):
    """Delay the wire so a draw stamped v0 reaches its replica only after
    the replica has applied the v1 delta: the replica serves it from its
    v0 snapshot — exactly the stamped version, not the newer one."""
    faults = FaultInjector()
    fleet = Fleet(db, replicas=2, max_batch=1, max_wait_ms=1e9,
                  faults=faults, retry_timeout_s=10.0)
    home = fleet.router._route(query_fingerprint(q))
    # the 1st draw to the home replica is delayed 10ms
    faults.inject(f"deliver:router->{home}", ("delay", 0.010))
    old = JoinSampleRequest(query=q, seed=1)
    fleet.submit(old)                        # stamped v0, delayed in flight
    fleet.submit(UpdateRequest(_delta()))    # commits v1
    new = JoinSampleRequest(query=q, seed=2)
    fleet.submit(new)                        # stamped v1, arrives FIRST
    done = fleet.advance(0.02) + fleet.drain()
    assert faults.pending == 0
    _check_complete_and_unique([old, new], done)
    # the barrier was crossed while `old` was in flight...
    assert new.db_version == 1 and old.db_version == 0
    home_rep = next(r for r in fleet.replicas if r.name == home)
    assert home_rep.stale_serves == 1  # ...and served from the v0 snapshot
    ref0 = QueryEngine(db)
    ref1 = QueryEngine(db.apply(_delta()))
    assert old.count == int(ref0.sample(q, jax.random.key(1)).count)
    assert new.count == int(ref1.sample(q, jax.random.key(2)).count)


# -- drop transport messages -------------------------------------------------

def test_dropped_request_message_is_retried(db, q):
    faults = FaultInjector()
    fleet = Fleet(db, replicas=2, max_batch=1, max_wait_ms=1e9,
                  faults=faults, retry_timeout_s=0.05)
    home = fleet.router._route(query_fingerprint(q))
    faults.inject(f"deliver:router->{home}", DROP)
    r = JoinSampleRequest(query=q, seed=3)
    fleet.submit(r)
    assert fleet.take_completed() == []  # the draw vanished on the wire
    done = fleet.advance(0.06)  # retry timer fires, re-sends
    assert faults.pending == 0 and fleet.router.retries == 1
    assert done == [r] and r.count is not None
    want = QueryEngine(db).sample(q, jax.random.key(3))
    assert r.count == int(want.count)


def test_dropped_response_message_served_once_completed_once(db, q):
    """The response (not the request) drops: the retried draw hits the
    replica's served-cache and is answered idempotently — the client gets
    exactly one completion and the engine never recomputes."""
    faults = FaultInjector()
    fleet = Fleet(db, replicas=2, max_batch=1, max_wait_ms=1e9,
                  faults=faults, retry_timeout_s=0.05)
    home = fleet.router._route(query_fingerprint(q))
    faults.inject(f"deliver:{home}->router", DROP)
    r = JoinSampleRequest(query=q, seed=4)
    fleet.submit(r)
    assert fleet.take_completed() == []  # served, but the response dropped
    home_rep = next(x for x in fleet.replicas if x.name == home)
    dispatches_after_serve = home_rep.batcher.dispatches
    done = fleet.advance(0.06)
    assert faults.pending == 0
    assert done == [r] and r.count is not None
    assert home_rep.duplicates == 1  # answered from the served cache
    assert home_rep.batcher.dispatches == dispatches_after_serve  # no recompute
    drained = fleet.drain()
    assert drained == []  # nothing pending anywhere
    want = QueryEngine(db).sample(q, jax.random.key(4))
    assert r.count == int(want.count)


# -- the drain invariant under a mixed fault plan ----------------------------

def test_mixed_faults_drain_loses_nothing(db, q):
    """One crash + one drop + one delay in a single interleaved stream of
    draws and updates: the fleet drains with every accepted request
    completed at its stamped version, none lost, none duplicated."""
    faults = FaultInjector()
    fleet = Fleet(db, replicas=3, max_batch=3, max_wait_ms=1e9,
                  faults=faults, retry_timeout_s=0.05)
    home = fleet.router._route(query_fingerprint(q))
    successor = fleet.replicas[
        (next(i for i, r in enumerate(fleet.replicas) if r.name == home) + 1)
        % 3].name
    faults.inject(f"deliver:router->{home}", ("delay", 0.005), at=2)
    faults.inject(f"{home}:flush", CRASH, at=3)
    faults.inject(f"deliver:{successor}->router", DROP, at=1)
    accepted, done, dbs = [], [], [db]
    for i in range(18):
        if i % 6 == 5:
            fleet.submit(UpdateRequest(_delta(i)))
            dbs.append(dbs[-1].apply(_delta(i)))
        else:
            r = JoinSampleRequest(query=q, seed=100 + i)
            res = fleet.submit(r)
            assert not isinstance(res, Rejected)
            accepted.append(r)
        done += fleet.advance(0.001)
    done += fleet.advance(0.1)  # let retry timers fire
    done += fleet.drain()
    done = [x for x in done if isinstance(x, JoinSampleRequest)]
    _check_complete_and_unique(accepted, done)
    # every draw matches a cold engine at its stamped version
    refs = {}
    for r in accepted:
        eng = refs.setdefault(r.db_version, QueryEngine(dbs[r.db_version]))
        want = eng.sample(q, jax.random.key(r.seed))
        assert (r.count, r.overflow) == (int(want.count), bool(want.overflow))
    # replicas that survived converged to the log head
    for rep in fleet.replicas:
        if rep.name in fleet.router.drained:
            assert rep.engine.db.version == fleet.db_version
