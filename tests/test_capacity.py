"""Capacity planning + overflow semantics: the static-shape contract that
makes the samplers jit-safe is 'overflow is always flagged, never silent'."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import Atom, Database, JoinQuery, estimate, sampling
from repro.engine import QueryEngine


def _db():
    rng = np.random.default_rng(0)
    return Database.from_columns({
        "R": {"x": rng.integers(0, 10, 80), "p": np.full(80, 0.6)},
        "S": {"x": rng.integers(0, 10, 120), "z": np.arange(120)},
    })


Q = JoinQuery((Atom.of("R", "x", "p"), Atom.of("S", "x", "z")), prob_var="p")


def test_overflow_flagged_and_redraw_succeeds():
    s = QueryEngine(_db()).compile(Q)
    tiny = s.sample(jax.random.key(0), cap=8, acap=16)
    assert bool(tiny.overflow), "a cap far below E[k] must flag overflow"
    full = s.sample_auto(jax.random.key(0))
    assert not bool(full.overflow)
    assert int(full.count) > 8


def test_default_capacity_rarely_overflows():
    s = QueryEngine(_db()).compile(Q)
    overflows = sum(bool(s.sample(jax.random.key(i)).overflow) for i in range(50))
    assert overflows == 0  # 6-sigma planning: P(overflow) ~ 1e-9 per draw


def test_capacity_planner_moments():
    w = jnp.asarray([10, 20, 30], jnp.int64)
    p = jnp.asarray([0.5, 0.1, 0.9], jnp.float64)
    mean = float(estimate.expected_sample_size(w, p))
    assert abs(mean - (5 + 2 + 27)) < 1e-9
    var = 10 * .25 + 20 * .09 + 30 * .09
    assert abs(float(estimate.sample_std(w, p)) - var ** .5) < 1e-9
    cap = estimate.plan_capacity(mean, var ** .5)
    assert cap >= mean + 6 * var ** .5
    assert cap % 128 == 0  # TPU lane alignment


def test_exprace_arrival_mass_bounds():
    """Lam <= ln2 * sum(w)/... and >= E[k_direct]: the sampler's scratch is
    within a constant factor of the output size for every p."""
    w = jnp.asarray([100, 100, 100], jnp.int64)
    for pv in ([0.01, 0.5, 0.99], [1.0, 0.0, 0.5]):
        p = jnp.asarray(pv, jnp.float64)
        mass = float(estimate.exprace_arrival_mass(w, p))
        bound = float(jnp.sum(w * jnp.log(2.0)))
        assert mass <= bound + 1e-9


def test_geo_capacity_overflow_consistency():
    """GEO with insufficient cap flags 'more beyond' and never emits
    out-of-range positions."""
    ps = jax.jit(sampling.geo_positions, static_argnums=(2, 3))(
        jax.random.key(1), 0.9, 100000, 256)
    assert bool(ps.overflow)
    pos = np.asarray(ps.positions)[: int(ps.count)]
    assert (pos < 100000).all() and len(pos) == 256
