"""Versioned snapshots + incremental reshred (DESIGN.md §11).

(a) ``Database.apply``: versions increase monotonically, untouched
    relations are shared by reference, malformed deltas are rejected;
(b) ``reshred_incremental`` is bit-identical to a from-scratch shred of
    the post-delta snapshot — property-tested over random deltas
    (inserts, deletes of chained rows, multi-relation batches) for both
    representations, plus chained delta sequences;
(c) ``QueryEngine.apply_delta`` upgrades warm cache entries: zero shred
    rebuilds, zero plan recompiles, zero retraces for shape-preserving
    deltas (CacheStats + jit-cache introspection), across single-draw,
    batched, and sharded sampling — while ``rebind`` with an identical
    schema still invalidates (the documented contract);
(d) stacked indexes re-partition only shards whose rows changed
    (``reshard_incremental`` per-shard reuse).
"""
import numpy as np
import jax
import pytest

from _optional import given, settings, st  # hypothesis, or skip shims

from repro.core import Atom, Database, JoinQuery, build_shred
from repro.core.delta import DeltaBatch, RelationDelta
from repro.core.distributed import build_stacked, reshard_incremental
from repro.core.shred import reshred_incremental
from repro.engine import QueryEngine, ShardedPlan


def _db(seed=11, nr=90, ns=140, nt=60):
    rng = np.random.default_rng(seed)
    return Database.from_columns({
        "R": {"x": rng.integers(0, 12, nr), "p": rng.random(nr) * 0.5},
        "S": {"x": rng.integers(0, 12, ns), "y": rng.integers(0, 9, ns)},
        "T": {"y": rng.integers(0, 9, nt), "z": np.arange(nt)},
    })


Q3 = JoinQuery((Atom.of("R", "x", "p"), Atom.of("S", "x", "y"),
                Atom.of("T", "y", "z")), prob_var="p")


def _random_delta(db, seed, max_ins=6, max_del=5):
    """A random multi-relation DeltaBatch: per-relation inserts (new and
    existing key values) and deletes (uniform row choice — chained rows,
    group heads, and singletons all get hit across seeds)."""
    rng = np.random.default_rng(seed)
    spec = {}
    gens = {
        "R": lambda k: {"x": rng.integers(0, 15, k), "p": rng.random(k)},
        "S": lambda k: {"x": rng.integers(0, 15, k),
                        "y": rng.integers(0, 11, k)},
        "T": lambda k: {"y": rng.integers(0, 11, k),
                        "z": rng.integers(0, 99, k)},
    }
    for name in db.relations:
        if rng.random() < 0.25:
            continue  # leave this relation untouched
        n = db.relations[name].num_rows
        ins = int(rng.integers(0, max_ins + 1))
        dele = int(rng.integers(0, min(max_del, n) + 1))
        if ins == 0 and dele == 0:
            continue
        s = {}
        if ins:
            s["insert"] = gens[name](ins)
        if dele:
            s["delete"] = rng.choice(n, size=dele, replace=False)
        spec[name] = s
    if not spec:  # guarantee a non-empty batch
        spec["S"] = {"insert": gens["S"](1)}
    return DeltaBatch.of(**spec)


def assert_shreds_bit_identical(a, b):
    la, ta = jax.tree.flatten(a)
    lb, tb = jax.tree.flatten(b)
    assert ta == tb, "pytree structure differs"
    for x, y in zip(la, lb):
        assert x.dtype == y.dtype, (x.dtype, y.dtype)
        assert x.shape == y.shape, (x.shape, y.shape)
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# -- (a) Database.apply ------------------------------------------------------

def test_apply_versions_and_sharing():
    db = _db()
    assert db.version == 0
    delta = DeltaBatch.of(S={"insert": {"x": [1], "y": [2]}})
    db1 = db.apply(delta)
    assert db1.version == 1 and db.version == 0  # immutable snapshots
    # untouched relations shared by reference, touched ones replaced
    assert db1.relations["R"] is db.relations["R"]
    assert db1.relations["T"] is db.relations["T"]
    assert db1.relations["S"] is not db.relations["S"]
    assert db1.relations["S"].num_rows == db.relations["S"].num_rows + 1
    assert db1.apply(delta).version == 2


def test_apply_layout_is_survivors_then_inserts():
    db = Database.from_columns({"A": {"k": [10, 11, 12, 13]}})
    db1 = db.apply(DeltaBatch.of(A={"delete": [1], "insert": {"k": [99]}}))
    np.testing.assert_array_equal(
        np.asarray(db1.relations["A"].column("k")), [10, 12, 13, 99])


def test_apply_validation():
    db = Database.from_columns({"A": {"k": [1, 2], "v": [3, 4]}})
    with pytest.raises(KeyError, match="unknown"):
        db.apply(DeltaBatch.of(B={"delete": [0]}))
    with pytest.raises(ValueError, match="schema"):
        db.apply(DeltaBatch.of(A={"insert": {"k": [1]}}))  # missing column v
    with pytest.raises(ValueError, match="ragged"):
        db.apply(DeltaBatch.of(A={"insert": {"k": [1], "v": [2, 3]}}))
    with pytest.raises(ValueError, match="delete_mask"):
        db.apply(DeltaBatch(
            {"A": RelationDelta(delete_mask=np.zeros(5, np.bool_))}))
    with pytest.raises(ValueError, match="at least one relation"):
        DeltaBatch({})
    with pytest.raises(ValueError, match="empty"):
        db.apply(DeltaBatch({"A": RelationDelta()}))
    with pytest.raises(ValueError, match="out of range"):
        db.apply(DeltaBatch.of(A={"delete": [-1]}))  # no numpy wraparound
    with pytest.raises(ValueError, match="out of range"):
        db.apply(DeltaBatch.of(A={"delete": [2]}))
    with pytest.raises(ValueError, match="duplicate"):
        db.apply(DeltaBatch.of(A={"delete": [0, 0]}))


# -- (b) reshred bit-identity ------------------------------------------------

@pytest.mark.parametrize("rep", ["usr", "csr", "both"])
def test_reshred_incremental_bit_identical_seeded(rep):
    db = _db()
    base = build_shred(db, Q3, rep=rep)
    for seed in range(12):
        delta = _random_delta(db, seed)
        inc = reshred_incremental(base, db, Q3, delta)
        scratch = build_shred(db.apply(delta), Q3, rep=rep)
        assert_shreds_bit_identical(inc, scratch)


@pytest.mark.parametrize("rep", ["usr", "csr"])
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10**9))
def test_reshred_incremental_bit_identical_property(rep, seed):
    db = _db()
    base = build_shred(db, Q3, rep=rep)
    delta = _random_delta(db, seed, max_ins=8, max_del=8)
    assert_shreds_bit_identical(
        reshred_incremental(base, db, Q3, delta),
        build_shred(db.apply(delta), Q3, rep=rep))


def test_reshred_delete_chained_rows_csr():
    """Deleting rows in the middle/head of CSR same-key chains relinks the
    survivors exactly like a rebuild."""
    db = Database.from_columns({
        "R": {"x": [5, 5, 5], "p": [0.5, 0.5, 0.5]},
        "S": {"x": [5, 5, 5, 5, 5, 7], "y": [0, 1, 2, 3, 4, 5]},
    })
    q = JoinQuery((Atom.of("R", "x", "p"), Atom.of("S", "x", "y")),
                  prob_var="p")
    base = build_shred(db, q, rep="csr")
    for rows in ([0], [2], [4], [0, 2, 4], [1, 3]):  # head, middle, tail
        delta = DeltaBatch.of(S={"delete": rows})
        assert_shreds_bit_identical(
            reshred_incremental(base, db, q, delta),
            build_shred(db.apply(delta), q, rep="csr"))


def test_reshred_chained_deltas():
    """A lineage of deltas merged one-by-one tracks from-scratch builds."""
    db = _db(seed=3)
    cur = build_shred(db, Q3, rep="both")
    for seed in range(6):
        delta = _random_delta(db, 1000 + seed)
        cur = reshred_incremental(cur, db, Q3, delta)
        db = db.apply(delta)
        assert_shreds_bit_identical(cur, build_shred(db, Q3, rep="both"))


def test_reshred_untouched_query_returns_base():
    db = Database.from_columns({
        "R": {"x": [1, 2], "p": [0.5, 0.5]}, "S": {"x": [1], "y": [3]},
        "Unrelated": {"w": [9]},
    })
    q = JoinQuery((Atom.of("R", "x", "p"), Atom.of("S", "x", "y")),
                  prob_var="p")
    base = build_shred(db, q)
    delta = DeltaBatch.of(Unrelated={"insert": {"w": [1]}})
    assert reshred_incremental(base, db, q, delta) is base


def test_reshred_multicolumn_join_keys():
    rng = np.random.default_rng(5)
    db = Database.from_columns({
        "R": {"a": rng.integers(0, 6, 40), "b": rng.integers(0, 6, 40),
              "p": rng.random(40)},
        "S": {"a": rng.integers(0, 6, 70), "b": rng.integers(0, 6, 70),
              "c": np.arange(70)},
    })
    q = JoinQuery((Atom.of("R", "a", "b", "p"), Atom.of("S", "a", "b", "c")),
                  prob_var="p")
    base = build_shred(db, q, rep="both")
    for seed in range(6):
        r2 = np.random.default_rng(seed)
        delta = DeltaBatch.of(S={
            "insert": {"a": r2.integers(0, 8, 4), "b": r2.integers(0, 8, 4),
                       "c": r2.integers(0, 9, 4)},
            "delete": r2.choice(70, 5, replace=False)})
        assert_shreds_bit_identical(
            reshred_incremental(base, db, q, delta),
            build_shred(db.apply(delta), q, rep="both"))


def test_reshred_cross_product_edge():
    db = Database.from_columns({
        "R": {"x": [1, 2, 3], "p": [0.5, 0.2, 0.9]},
        "U": {"w": [10, 20, 30]},
    })
    q = JoinQuery((Atom.of("R", "x", "p"), Atom.of("U", "w")), prob_var="p")
    base = build_shred(db, q, rep="both")
    delta = DeltaBatch.of(U={"insert": {"w": [40, 50]}, "delete": [1]},
                          R={"insert": {"x": [4], "p": [0.1]}})
    assert_shreds_bit_identical(
        reshred_incremental(base, db, q, delta),
        build_shred(db.apply(delta), q, rep="both"))


# -- (c) engine cache contract ----------------------------------------------

def _shape_preserving_delta():
    """2 in / 2 out on S: every cached array keeps its shape, so warm draws
    must reuse the existing traces."""
    return DeltaBatch.of(S={"insert": {"x": [3, 7], "y": [1, 2]},
                            "delete": [0, 1]})


def test_apply_delta_zero_rebuilds_zero_retraces():
    db = _db()
    engine = QueryEngine(db)
    key = jax.random.key(0)
    engine.sample(Q3, key)
    engine.sample_batch(Q3, jax.random.split(key, 4))
    plan = engine.compile(Q3)
    st0 = engine.stats.snapshot()
    introspect = hasattr(plan._jit, "_cache_size")
    if introspect:
        t_single = plan._jit._cache_size()
        t_batched = plan._batched_jit._cache_size()

    engine.apply_delta(_shape_preserving_delta())
    assert engine.db.version == 1
    engine.sample(Q3, jax.random.key(1))
    engine.sample_batch(Q3, jax.random.split(jax.random.key(2), 4))

    st1 = engine.stats
    assert st1.shred_builds == st0.shred_builds, \
        "warm draws after apply_delta must not rebuild the shred"
    assert st1.plan_misses == st0.plan_misses, \
        "warm draws after apply_delta must not recompile the plan"
    assert st1.shred_upgrades >= 1 and st1.plan_upgrades >= 1
    assert engine.compile(Q3) is plan, "plan object survives the upgrade"
    if introspect:
        assert plan._jit._cache_size() == t_single, \
            "shape-preserving delta must not retrace the single-draw executor"
        assert plan._batched_jit._cache_size() == t_batched, \
            "shape-preserving delta must not retrace the batched executor"


def test_apply_delta_samples_match_fresh_engine():
    db = _db()
    engine = QueryEngine(db)
    key = jax.random.key(7)
    engine.sample(Q3, key)  # warm the cache pre-delta
    for seed in range(3):
        delta = _random_delta(db, 40 + seed)
        engine.apply_delta(delta)
        db = db.apply(delta)
    fresh = QueryEngine(db)
    plan = engine.compile(Q3)
    a = engine.sample(Q3, key)
    b = fresh.sample(Q3, key, cap=plan.default_capacity(),
                     acap=plan.arrival_capacity())
    np.testing.assert_array_equal(np.asarray(a.positions),
                                  np.asarray(b.positions))
    for v in b.columns:
        np.testing.assert_array_equal(np.asarray(a.columns[v]),
                                      np.asarray(b.columns[v]))
    assert engine.join_size(Q3) == fresh.join_size(Q3)
    full_a, full_b = engine.full_join(Q3), fresh.full_join(Q3)
    for v in full_b:
        np.testing.assert_array_equal(np.asarray(full_a[v]),
                                      np.asarray(full_b[v]))


def test_apply_delta_untouched_query_rekeyed_free():
    db = _db()
    engine = QueryEngine(db)
    q_free = JoinQuery((Atom.of("T", "y", "z"),))  # delta never touches T
    engine.full_join(q_free)
    engine.sample(Q3, jax.random.key(0))
    st0 = engine.stats.snapshot()
    engine.apply_delta(_shape_preserving_delta())  # touches S only
    engine.full_join(q_free)
    st1 = engine.stats
    assert st1.shred_builds == st0.shred_builds
    # Only the touched query's entries did upgrade work.
    assert st1.shred_upgrades == st0.shred_upgrades + 1
    assert st1.plan_upgrades == st0.plan_upgrades + 1


def test_rebind_still_invalidates_identical_schema():
    """The documented contract: rebind ALWAYS invalidates, even for an
    identical schema fingerprint — apply_delta is the warm path."""
    db = _db()
    engine = QueryEngine(db)
    engine.sample(Q3, jax.random.key(0))
    assert len(engine._plans) == 1 and len(engine._shreds) == 1
    st0 = engine.stats.snapshot()
    engine.rebind(_db())  # same seed: byte-identical data, same schema
    assert len(engine._plans) == 0 and len(engine._shreds) == 0
    engine.sample(Q3, jax.random.key(0))
    assert engine.stats.shred_builds == st0.shred_builds + 1
    assert engine.stats.plan_misses == st0.plan_misses + 1


def test_apply_delta_sharded_zero_rebuilds():
    db = _db(nr=96)
    engine = QueryEngine(db)
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    plan = engine.compile_sharded(Q3, mesh, axes=("data",))
    assert isinstance(plan, ShardedPlan)
    key = jax.random.key(3)
    engine.sample(Q3, key, mesh=mesh, axes=("data",))
    engine.sample_batch(Q3, jax.random.split(key, 4), mesh=mesh,
                        axes=("data",))
    st0 = engine.stats.snapshot()
    n_samplers = len(plan._samplers) + len(plan._batched_samplers)

    engine.apply_delta(_shape_preserving_delta())
    a = engine.sample(Q3, key, mesh=mesh, axes=("data",))
    engine.sample_batch(Q3, jax.random.split(key, 4), mesh=mesh,
                        axes=("data",))
    st1 = engine.stats
    assert st1.shred_builds == st0.shred_builds, \
        "warm sharded draws after apply_delta must not rebuild the stack"
    assert st1.plan_misses == st0.plan_misses
    assert st1.shards_reused + st1.shards_rebuilt == plan.num_shards
    # shape-preserving + sticky capacities: the shard_map executors are the
    # same cached callables (no new (cap, acap) entries)
    assert len(plan._samplers) + len(plan._batched_samplers) == n_samplers
    # correctness against a cold engine on the applied snapshot
    fresh = QueryEngine(db.apply(_shape_preserving_delta()))
    b = fresh.sample(Q3, key, mesh=mesh, axes=("data",), cap=plan.cap,
                     acap=plan.acap)
    np.testing.assert_array_equal(np.asarray(a.positions),
                                  np.asarray(b.positions))


def test_stacked_repartitions_only_changed_shards():
    """Core-level per-shard reuse: a delta confined to the tail of the root
    block layout rebuilds the tail shard only (DESIGN.md §11)."""
    db = _db(nr=96)
    stacked, base = build_stacked(db, Q3, 4)
    # Replace two tail-block root rows with rows whose x values already
    # occur elsewhere: the semijoin filter output and every non-tail block
    # are unchanged.
    xs = np.asarray(db.relations["R"].column("x"))
    delta = DeltaBatch.of(R={"insert": {"x": xs[:2], "p": [0.1, 0.2]},
                             "delete": [90, 91]})
    new_stacked, new_base, reused, rebuilt = reshard_incremental(
        stacked, base, db.apply(delta), Q3, 4)
    assert reused == 3 and rebuilt == 1
    want, _ = build_stacked(db.apply(delta), Q3, 4)
    assert_shreds_bit_identical(new_stacked.shred, want.shred)
    np.testing.assert_array_equal(np.asarray(new_stacked.prefE),
                                  np.asarray(want.prefE))
    assert new_stacked.join_sizes == want.join_sizes
    # a child delta invalidates the shared children: every shard rebuilds
    delta2 = _shape_preserving_delta()
    s2, _, reused2, rebuilt2 = reshard_incremental(
        new_stacked, new_base, db.apply(delta).apply(delta2), Q3, 4)
    assert reused2 == 0 and rebuilt2 == 4
    want2, _ = build_stacked(db.apply(delta).apply(delta2), Q3, 4)
    assert_shreds_bit_identical(s2.shred, want2.shred)


def test_stacked_reuse_survives_unrelated_relation_delta():
    """A delta that ALSO touches a relation outside the query (another
    tenant's table) must not defeat per-shard reuse."""
    rng = np.random.default_rng(11)
    db = Database.from_columns({
        "R": {"x": rng.integers(0, 12, 96), "p": rng.random(96) * 0.5},
        "S": {"x": rng.integers(0, 12, 140), "y": rng.integers(0, 9, 140)},
        "T": {"y": rng.integers(0, 9, 60), "z": np.arange(60)},
        "Other": {"w": np.arange(30)},
    })
    stacked, base = build_stacked(db, Q3, 4)
    xs = np.asarray(db.relations["R"].column("x"))
    delta = DeltaBatch.of(
        R={"insert": {"x": xs[:2], "p": [0.1, 0.2]}, "delete": [90, 91]},
        Other={"delete": [0]})
    new_stacked, _, reused, rebuilt = reshard_incremental(
        stacked, base, db.apply(delta), Q3, 4)
    assert reused == 3 and rebuilt == 1
    want, _ = build_stacked(db.apply(delta), Q3, 4)
    assert_shreds_bit_identical(new_stacked.shred, want.shred)


def test_explain_and_cache_info_report_versions():
    db = _db()
    engine = QueryEngine(db)
    engine.sample(Q3, jax.random.key(0))
    info = engine.cache_info()
    assert info["db_version"] == 0
    assert all(e["version"] == 0 for e in info["shreds"] + info["plans"])
    engine.apply_delta(_shape_preserving_delta())
    info = engine.cache_info()
    assert info["db_version"] == 1
    assert all(e["version"] == 1 for e in info["shreds"] + info["plans"])
    out = engine.explain(Q3)
    assert "db version=1" in out
    assert "upgrades" in out
