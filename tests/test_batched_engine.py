"""The batched multi-draw path (DESIGN.md §10): per-lane bit-identity with
sequential draws, batch-bucketing, and the cache contract.

(a) ``sample_batch(q, split(key, B))`` is bit-identical per lane to B
    sequential ``sample(q, key_i)`` calls — both representations, both
    methods, and through the sharded plan (explicit axes force the
    stacked path on any device count; the slow subprocess test pins a
    real 8-virtual-device mesh);
(b) a warm same-bucket batch performs zero shred/plan rebuilds
    (CacheStats) and reuses the one cached trace (batch sizes are
    bucketed to powers of two);
(c) the single-draw API remains a thin B=1 facade: interleaving single
    and batched draws shares one plan cache entry.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import Atom, Database, JoinQuery
from repro.engine import QueryEngine, ShardedPlan
from repro.engine.executors import bucket_size, pad_batch_keys


@pytest.fixture(scope="module")
def db():
    rng = np.random.default_rng(11)
    return Database.from_columns({
        "R": {"x": rng.integers(0, 12, 90), "p": rng.random(90) * 0.5},
        "S": {"x": rng.integers(0, 12, 140), "y": rng.integers(0, 9, 140)},
        "T": {"y": rng.integers(0, 9, 60), "z": np.arange(60)},
    })


@pytest.fixture(scope="module")
def query():
    return JoinQuery((Atom.of("R", "x", "p"), Atom.of("S", "x", "y"),
                      Atom.of("T", "y", "z")), prob_var="p")


def _assert_lane_equal(batched, single, b):
    assert int(batched.count[b]) == int(single.count)
    assert bool(batched.overflow[b]) == bool(single.overflow)
    np.testing.assert_array_equal(np.asarray(batched.positions[b]),
                                  np.asarray(single.positions))
    for v in single.columns:
        np.testing.assert_array_equal(np.asarray(batched.columns[v][b]),
                                      np.asarray(single.columns[v]))


# -- (a) bit-identity with sequential draws ---------------------------------

@pytest.mark.parametrize("rep", ["usr", "csr"])
@pytest.mark.parametrize("method", ["exprace", "ptbern_flat"])
def test_sample_batch_bit_identical(db, query, rep, method):
    engine = QueryEngine(db, rep=rep)
    B = 6  # not a power of two: exercises the pad-and-slice path
    keys = jax.random.split(jax.random.key(3), B)
    batched = engine.sample_batch(query, keys, method=method)
    assert batched.positions.shape[0] == B and batched.batch == B
    for b in range(B):
        single = engine.sample(query, keys[b], method=method)
        _assert_lane_equal(batched, single, b)


def test_sample_batch_sharded_bit_identical(db, query):
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    engine = QueryEngine(db)
    assert isinstance(engine.compile_sharded(query, mesh, axes=("data",)),
                      ShardedPlan)
    B = 5
    keys = jax.random.split(jax.random.key(7), B)
    batched = engine.sample_batch(query, keys, mesh=mesh, axes=("data",))
    assert batched.positions.shape[0] == B
    for b in range(B):
        single = engine.sample(query, keys[b], mesh=mesh, axes=("data",))
        _assert_lane_equal(batched, single, b)


def test_sample_batch_degenerate_mesh_falls_back(db, query):
    """An auto-planned 1-shard mesh routes batched draws through the
    single-device plan, matching the meshless call bit-for-bit."""
    mesh = jax.make_mesh((len(jax.devices()),), ("model",))  # never shards
    keys = jax.random.split(jax.random.key(1), 3)
    a = QueryEngine(db).sample_batch(query, keys, mesh=mesh)
    b = QueryEngine(db).sample_batch(query, keys)
    for v in b.columns:
        np.testing.assert_array_equal(np.asarray(a.columns[v]),
                                      np.asarray(b.columns[v]))
    np.testing.assert_array_equal(np.asarray(a.positions),
                                  np.asarray(b.positions))


def test_sample_batch_valid_mask_and_membership(db, query):
    engine = QueryEngine(db)
    keys = jax.random.split(jax.random.key(2), 4)
    smp = engine.sample_batch(query, keys)
    v = np.asarray(smp.valid())
    assert v.shape == smp.positions.shape
    n = engine.join_size(query)
    pos = np.asarray(smp.positions)
    assert (pos[v] >= 0).all() and (pos[v] < n).all()
    full = engine.full_join(query)
    names = tuple(sorted(full))
    fullset = set(zip(*[np.asarray(full[k]) for k in names]))
    for b in range(4):
        got = list(zip(*[np.asarray(smp.columns[k][b])[v[b]] for k in names]))
        assert len(got) == int(smp.count[b])
        assert all(t in fullset for t in got)


def test_sample_batch_empty_join():
    db0 = Database.from_columns({
        "R": {"x": np.zeros((0,), np.int64), "p": np.zeros((0,), np.float64)},
        "S": {"x": np.array([1, 2]), "y": np.array([3, 4])},
    })
    q = JoinQuery((Atom.of("R", "x", "p"), Atom.of("S", "x", "y")),
                  prob_var="p")
    engine = QueryEngine(db0)
    smp = engine.sample_batch(q, jax.random.split(jax.random.key(0), 3))
    assert smp.positions.shape[0] == 3
    assert int(np.asarray(smp.count).sum()) == 0
    assert not np.asarray(smp.overflow).any()
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    smp = engine.sample_batch(q, jax.random.split(jax.random.key(0), 3),
                              mesh=mesh, axes=("data",))
    assert smp.positions.shape[0] == 3
    assert int(np.asarray(smp.count).sum()) == 0


def test_sample_batch_requires_prob_var(db):
    q = JoinQuery((Atom.of("R", "x", "p"), Atom.of("S", "x", "y")))
    with pytest.raises(ValueError, match="prob_var"):
        QueryEngine(db).sample_batch(q, jax.random.split(jax.random.key(0), 2))


# -- (b) bucketing + cache contract -----------------------------------------

def test_bucket_size():
    assert [bucket_size(b) for b in (1, 2, 3, 4, 5, 8, 9, 64, 65)] == \
        [1, 2, 4, 4, 8, 8, 16, 64, 128]
    with pytest.raises(ValueError):
        bucket_size(0)


def test_pad_batch_keys_pads_to_bucket():
    keys = jax.random.split(jax.random.key(0), 6)
    padded, b = pad_batch_keys(keys)
    assert b == 6 and padded.shape[0] == 8
    # pad lanes repeat the last key; original lanes are untouched
    kd = jax.random.key_data(padded)
    np.testing.assert_array_equal(np.asarray(kd[:6]),
                                  np.asarray(jax.random.key_data(keys)))
    np.testing.assert_array_equal(np.asarray(kd[6]), np.asarray(kd[5]))


def test_warm_same_bucket_batch_zero_rebuilds(db, query):
    engine = QueryEngine(db)
    engine.sample_batch(query, jax.random.split(jax.random.key(0), 5))
    st0 = engine.stats.snapshot()
    assert st0.shred_builds == 1 and st0.plan_misses == 1
    # Same bucket (8): different batch size, different keys — warm.
    engine.sample_batch(query, jax.random.split(jax.random.key(1), 7))
    engine.sample_batch(query, jax.random.split(jax.random.key(2), 8))
    st1 = engine.stats
    assert st1.shred_builds == st0.shred_builds, \
        "warm same-bucket batches must not rebuild the shred"
    assert st1.plan_misses == st0.plan_misses, \
        "warm same-bucket batches must not recompile the plan"
    assert st1.plan_hits >= st0.plan_hits + 2


def test_warm_same_bucket_batch_zero_retraces(db, query):
    """Same-bucket batches reuse one cached trace of the batched executor
    (the pow-2 bucketing claim, checked at the jit-cache level)."""
    engine = QueryEngine(db)
    plan = engine.compile(query)
    if not hasattr(plan._batched_jit, "_cache_size"):
        pytest.skip("jit cache introspection not available on this jax")
    plan.sample_batch(jax.random.split(jax.random.key(0), 5))
    traces = plan._batched_jit._cache_size()
    plan.sample_batch(jax.random.split(jax.random.key(1), 6))
    plan.sample_batch(jax.random.split(jax.random.key(2), 8))
    assert plan._batched_jit._cache_size() == traces
    plan.sample_batch(jax.random.split(jax.random.key(3), 9))  # next bucket
    assert plan._batched_jit._cache_size() == traces + 1


def test_single_and_batched_share_one_plan(db, query):
    engine = QueryEngine(db)
    engine.sample(query, jax.random.key(0))
    st0 = engine.stats.snapshot()
    engine.sample_batch(query, jax.random.split(jax.random.key(0), 4))
    assert engine.stats.plan_misses == st0.plan_misses
    assert engine.stats.shred_builds == st0.shred_builds


# -- (a, acceptance) real 8-device mesh (subprocess) ------------------------

SCRIPT = textwrap.dedent("""
    import numpy as np, jax
    assert len(jax.devices()) == 8, jax.devices()
    from repro.core import Atom, Database, JoinQuery
    from repro.engine import QueryEngine, ShardedPlan

    rng = np.random.default_rng(11)
    db = Database.from_columns({
        "R": {"x": rng.integers(0, 12, 90), "p": rng.random(90) * 0.5},
        "S": {"x": rng.integers(0, 12, 140), "y": rng.integers(0, 9, 140)},
        "T": {"y": rng.integers(0, 9, 60), "z": np.arange(60)},
    })
    q = JoinQuery((Atom.of("R", "x", "p"), Atom.of("S", "x", "y"),
                   Atom.of("T", "y", "z")), prob_var="p")
    mesh = jax.make_mesh((8,), ("data",))
    engine = QueryEngine(db)
    plan = engine.compile_sharded(q, mesh)
    assert isinstance(plan, ShardedPlan) and plan.num_shards == 8

    B = 6
    keys = jax.random.split(jax.random.key(3), B)
    batched = engine.sample_batch(q, keys, mesh=mesh)
    st0 = engine.stats.snapshot()
    for b in range(B):
        single = engine.sample(q, keys[b], mesh=mesh)
        assert int(batched.count[b]) == int(single.count)
        np.testing.assert_array_equal(np.asarray(batched.positions[b]),
                                      np.asarray(single.positions))
        for v in single.columns:
            np.testing.assert_array_equal(np.asarray(batched.columns[v][b]),
                                          np.asarray(single.columns[v]))
    # ... and the whole comparison loop was warm: zero stacked rebuilds.
    assert engine.stats.shred_builds == st0.shred_builds
    engine.sample_batch(q, jax.random.split(jax.random.key(9), 5), mesh=mesh)
    assert engine.stats.shred_builds == st0.shred_builds
    print("BATCHED_ENGINE_8DEV_OK")
""")


@pytest.mark.slow
def test_batched_engine_8_devices():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert "BATCHED_ENGINE_8DEV_OK" in r.stdout
