"""The public-API drift gate: an export/signature change must land with a
regenerated API.md (exit 1 on drift, exit 3 when no snapshot is committed
— the same verdict taxonomy as tools/check_bench.py), and the COMMITTED
snapshot must gate clean against the live modules, so tier-1 itself fails
on undocumented API drift.
"""
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "tools"))

import check_api  # noqa: E402


def test_render_is_deterministic():
    assert check_api.render() == check_api.render()


def test_render_covers_the_three_packages_and_key_exports():
    text = check_api.render()
    for mod in check_api.MODULES:
        assert f"## {mod}" in text
    # spot-checks: one load-bearing export per package, with signatures
    assert "class QueryEngine" in text
    assert "class DrawSpec" in text
    assert "class PoissonJoinSource" in text
    assert "def corpus_delta(" in text
    # class surfaces include their public methods
    assert "def sample_batch(" in text


def test_check_fresh_snapshot_passes(tmp_path, capsys):
    p = tmp_path / "API.md"
    p.write_text(check_api.render())
    assert check_api.check(p) == 0
    assert "ok" in capsys.readouterr().out


def test_check_drift_exit_1_with_diff_and_refresh_hint(tmp_path, capsys):
    p = tmp_path / "API.md"
    p.write_text(check_api.render().replace(
        "class QueryEngine", "class QueryEngineRenamed"))
    rc = check_api.check(p)
    assert rc == check_api.EXIT_DRIFT == 1
    err = capsys.readouterr().err
    assert "QueryEngineRenamed" in err  # the diff names the drifted line
    assert "--update" in err            # and the refresh playbook


def test_missing_snapshot_exit_3(tmp_path, capsys):
    rc = check_api.check(tmp_path / "API.md")
    assert rc == check_api.EXIT_MISSING_BASELINE == 3
    assert "--update" in capsys.readouterr().err


def test_update_then_check_roundtrip(tmp_path):
    p = tmp_path / "API.md"
    assert check_api.update(p) == 0
    assert check_api.check(p) == 0


def test_committed_snapshot_matches_live_surface():
    """The repo's committed API.md can never itself be stale: any public
    export or signature change must regenerate it in the same commit."""
    assert check_api.DEFAULT_BASELINE.is_file(), \
        "API.md missing from the repo root"
    assert check_api.check(check_api.DEFAULT_BASELINE) == 0
