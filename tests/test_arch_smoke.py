"""Per-architecture smoke tests (assignment deliverable f).

Each of the 10 assigned architectures is instantiated at a REDUCED config of
the same family (same block-type pattern, same GQA grouping; small widths /
few experts / tiny vocab) and runs one forward + one train-gradient step on
CPU, asserting output shapes and finiteness. The FULL configs are exercised
only by the dry-run (ShapeDtypeStruct, no allocation).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import encode, forward, init_model, loss_fn, decode_step, init_cache, prefill

B, S = 2, 12


def _batch(cfg, key):
    b = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab, jnp.int32),
        "targets": jax.random.randint(key, (B, S), 0, cfg.vocab, jnp.int32),
    }
    if cfg.n_memory_tokens and not cfg.has_encoder:
        b["memory"] = jax.random.normal(key, (B, cfg.n_memory_tokens, cfg.d_model),
                                        jnp.float32)
    if cfg.has_encoder:
        b["frames"] = jax.random.normal(key, (B, cfg.n_memory_tokens, cfg.enc_d_model),
                                        jnp.float32)
    return b


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_forward_and_grad(arch):
    cfg = configs.reduced(configs.get_config(arch))
    key = jax.random.key(42)
    params = init_model(cfg, key)
    batch = _batch(cfg, key)

    mem = batch.get("memory")
    if cfg.has_encoder:
        mem = encode(params, cfg, batch["frames"])
    logits, _ = jax.jit(lambda p, t: forward(p, cfg, t, mem))(params, batch["tokens"])
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), "NaN/Inf in logits"

    loss, grads = jax.value_and_grad(lambda p: loss_fn(p, cfg, batch)[0])(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g.astype(jnp.float32)).all()) for g in leaves)
    assert sum(float(jnp.sum(jnp.abs(g))) for g in leaves) > 0, "zero gradients"


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_decode_step(arch):
    cfg = configs.reduced(configs.get_config(arch))
    key = jax.random.key(7)
    params = init_model(cfg, key)
    batch = _batch(cfg, key)
    mem = batch.get("memory")
    if cfg.has_encoder:
        mem = encode(params, cfg, batch["frames"])
    # prefill then one extra decode step
    _, cache = prefill(params, cfg, batch["tokens"], S + 4, mem)
    tok = batch["tokens"][:, -1:]
    logits, cache2 = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t, S))(params, cache, tok)
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    # cache structure is preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_full_config_metadata(arch):
    """Full configs carry the exact assigned hyperparameters."""
    cfg = configs.get_config(arch)
    expect = {
        "smollm_135m": (30, 576, 9, 3, 1536, 49152),
        "starcoder2_7b": (32, 4608, 36, 4, 18432, 49152),
        "gemma3_1b": (26, 1152, 4, 1, 6912, 262144),
        "llama3_405b": (126, 16384, 128, 8, 53248, 128256),
        "llama32_vision_11b": (40, 4096, 32, 8, 14336, 128256),
        "llama4_scout_17b_16e": (48, 5120, 40, 8, 8192, 202048),
        "olmoe_1b_7b": (16, 2048, 16, 16, 1024, 50304),
        "whisper_small": (12, 768, 12, 12, 3072, 51865),
        "rwkv6_7b": (32, 4096, 64, 64, 14336, 65536),
        "zamba2_1p2b": (38, 2048, 32, 32, 8192, 32000),
    }[arch]
    L, d, H, KV, ff, V = expect
    assert cfg.n_layers == L and cfg.d_model == d and cfg.vocab == V
    assert cfg.n_heads == H and cfg.n_kv_heads == KV
    assert (cfg.moe_dff or cfg.d_ff) == ff or cfg.d_ff == ff


def test_param_counts_plausible():
    """Sanity: full-config param counts are in the right ballpark."""
    expectations = {
        "smollm_135m": (0.10e9, 0.20e9),
        "starcoder2_7b": (6e9, 9e9),
        "gemma3_1b": (0.7e9, 1.6e9),
        "llama3_405b": (380e9, 430e9),
        "olmoe_1b_7b": (5e9, 8.5e9),
        "rwkv6_7b": (5e9, 9e9),
        "zamba2_1p2b": (0.9e9, 1.8e9),
    }
    for arch, (lo, hi) in expectations.items():
        n = configs.get_config(arch).param_count()
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_long500k_applicability():
    runs = {a for a in configs.ARCHS
            if configs.shape_applicable(configs.get_config(a), "long_500k") is None}
    assert runs == {"rwkv6_7b", "zamba2_1p2b"}
