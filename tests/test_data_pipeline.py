"""Determinism contracts of the engine-native training data plane
(DESIGN.md §13): ``PoissonJoinSource.batch_at(step)`` is a pure function
of (seed, step, delta schedule) —

  * invariant under dp re-meshing (the same byte stream on 1 vs 8 virtual
    devices, checked in subprocesses);
  * resumable mid-epoch (a fresh source consumed from step R matches the
    uninterrupted stream, across delta barriers);
  * delta-barrier aligned (no prefetch window straddles two snapshot
    versions; every batch records the version it was drawn at);
  * explicit about capacity rounding: a draw that undershoots ``batch``
    wraps doc ids deterministically and increments the ``wrapped``
    counter instead of wrapping silently.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import Database
from repro.data import PoissonJoinSource, corpus_delta, make_corpus_db

SEQ, VOCAB = 13, 97


def _source(db=None, batch=4, seed=7, deltas=(), window=4, **kw):
    db = db if db is not None else make_corpus_db(96, 8, SEQ, VOCAB, seed=3)
    return PoissonJoinSource(db, SEQ, batch, seed=seed, deltas=deltas,
                             window=window, **kw)


def _deltas(db, at=(6,)):
    """A schedule of live-corpus events, each built against the snapshot it
    applies to (insert + retire at every barrier)."""
    events, snap = [], db
    for i, s in enumerate(at):
        d = corpus_delta(snap, SEQ, VOCAB, insert=16, retire=range(4),
                         seed=100 + i)
        events.append((s, d))
        snap = snap.apply(d)
    return tuple(events)


def _stream(src, steps, start=0):
    out = []
    for s in range(start, steps):
        b = src.batch_at(s)
        out.append({k: np.asarray(v) for k, v in b.items()})
    return out


# -- re-meshing invariance (subprocess: 1 vs 8 virtual devices) --------------

MESH_SCRIPT = textwrap.dedent("""
    import json
    import numpy as np
    from repro.data import PoissonJoinSource, corpus_delta, make_corpus_db

    SEQ, VOCAB = 13, 97
    db = make_corpus_db(96, 8, SEQ, VOCAB, seed=3)
    delta = corpus_delta(db, SEQ, VOCAB, insert=16, retire=range(4), seed=100)
    src = PoissonJoinSource(db, SEQ, 4, seed=7, deltas=((6, delta),), window=4)
    out = []
    for step in range(10):
        b = src.batch_at(step)
        out.append({
            "doc_ids": np.asarray(b["doc_ids"]).tolist(),
            "tokens": np.asarray(b["tokens"]).tolist(),
            "sampled_k": int(b["sampled_k"]),
            "db_version": int(b["db_version"]),
        })
    print("STREAM:" + json.dumps(out))
""")


def _run_stream(devices: int):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", MESH_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    line = [l for l in r.stdout.splitlines() if l.startswith("STREAM:")][0]
    return json.loads(line[len("STREAM:"):])


@pytest.mark.slow
def test_batch_stream_invariant_under_re_meshing():
    """1 vs 8 virtual devices: the full stream (tokens, doc ids, raw counts,
    versions) is byte-identical — dp re-meshing cannot skew sampling."""
    assert _run_stream(1) == _run_stream(8)


# -- resume-mid-epoch equality ----------------------------------------------

def test_resume_mid_epoch_bit_identical():
    """A fresh source consumed from step R reproduces the uninterrupted
    stream exactly, including across a delta barrier before AND after R."""
    db = make_corpus_db(96, 8, SEQ, VOCAB, seed=3)
    deltas = _deltas(db, at=(3, 9))
    full = _stream(_source(db, deltas=deltas), 12)
    resumed = _stream(_source(db, deltas=deltas), 12, start=5)
    for a, b in zip(full[5:], resumed):
        for k in a:
            np.testing.assert_array_equal(a[k], b[k]), k


def test_rewind_requires_fresh_source():
    """The engine only moves forward: stepping back across an applied
    barrier is an explicit error, not silently-wrong data."""
    db = make_corpus_db(96, 8, SEQ, VOCAB, seed=3)
    src = _source(db, deltas=_deltas(db, at=(4,)))
    src.batch_at(6)  # advances past the barrier
    with pytest.raises(ValueError, match="fresh source"):
        src.batch_at(2)


# -- delta-barrier alignment -------------------------------------------------

def test_no_window_straddles_a_barrier():
    """Window bounds are pure in (step, schedule) and clipped so no window
    contains steps of two snapshot versions — even for barriers off the
    ``window`` alignment grid."""
    db = make_corpus_db(96, 8, SEQ, VOCAB, seed=3)
    deltas = _deltas(db, at=(5, 9))  # neither aligned to window=4
    src = _source(db, deltas=deltas)
    for step in range(16):
        s0, end = src._window_bounds(step)
        assert s0 <= step < end
        for e, _ in deltas:
            assert not (s0 < e < end), \
                f"window [{s0},{end}) straddles the barrier at {e}"
        # purity: a fresh source computes the same bounds
        assert _source(db, deltas=deltas)._window_bounds(step) == (s0, end)


def test_batches_record_their_snapshot_version():
    db = make_corpus_db(96, 8, SEQ, VOCAB, seed=3)
    deltas = _deltas(db, at=(5, 9))
    src = _source(db, deltas=deltas)
    got = [b["db_version"] for b in _stream(src, 12)]
    assert got == [src.version_at(s) for s in range(12)]
    assert got == [0] * 5 + [1] * 4 + [2] * 3


def test_pre_barrier_batches_unaffected_by_schedule():
    """Batches before the first barrier are identical with and without the
    delta schedule — a scheduled future event must not perturb the past."""
    db = make_corpus_db(96, 8, SEQ, VOCAB, seed=3)
    plain = _stream(_source(db), 6)
    live = _stream(_source(db, deltas=_deltas(db, at=(6,))), 6)
    for a, b in zip(plain, live):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        np.testing.assert_array_equal(a["doc_ids"], b["doc_ids"])


# -- capacity rounding + the wrap path (satellite: no silent wrap) -----------

def test_capacity_rounds_to_lane_multiple_and_covers_batch():
    src = _source(batch=200)
    assert src.cap % 128 == 0
    assert src.cap >= 200


def test_small_sample_wrap_is_deterministic_and_counted():
    """A corpus so small/low-quality the draw can never fill ``batch``:
    doc ids wrap cyclically over the k sampled docs and every served batch
    increments ``wrapped`` — never a silent modulo."""
    db = Database.from_columns({
        "Doc": {"doc": np.arange(6), "clust": np.zeros(6, np.int64)},
        "ClusterQuality": {"clust": np.array([0]), "p": np.array([0.4])},
        "_tokens": {"flat":
                    np.random.default_rng(0).integers(0, VOCAB, 6 * SEQ)},
    })
    src = PoissonJoinSource(db, SEQ, batch=16, seed=11, window=2)
    assert src.wrapped == 0
    served = 0
    for step in range(6):
        b = src.batch_at(step)
        k = int(b["sampled_k"])
        assert k < 16  # only 6 docs exist; the draw can never fill 16
        served += 1
        docs = np.asarray(b["doc_ids"])
        assert docs.shape == (16,)
        lanes = max(k, 1)  # k == 0 serves the first buffer lane
        np.testing.assert_array_equal(
            docs, docs[np.arange(16) % lanes],
            err_msg="wrap must repeat the sampled prefix cyclically")
    assert src.wrapped == served
    assert src.overflows == 0


def test_wrapped_counter_stays_zero_when_draws_fill_batch():
    src = _source(batch=2)  # 96 docs, mean quality 0.3: k >= 2 essentially
    for step in range(4):   # always under seed 7 (bit-frozen by determinism)
        src.batch_at(step)
    assert src.wrapped == 0
