"""Join-tree construction: GYO acyclicity, connectedness, rerooting."""
import pytest

from repro.core import Atom, JoinQuery, gyo_join_tree, is_acyclic, reroot_for
from repro.core.jointree import JoinTreeNode


def _connected(tree: JoinTreeNode) -> bool:
    """Join-tree connectedness: atoms containing each variable form a subtree."""
    nodes = tree.nodes()
    allvars = set().union(*[set(n.atom.variables) for n in nodes])
    for v in allvars:
        # count connected components of the v-induced subtree
        marked = {id(n) for n in nodes if v in n.atom.var_set()}

        def comps(n, inside):
            has = id(n) in marked
            cnt = 1 if (has and not inside) else 0
            for c in n.children:
                cnt += comps(c, has)
            return cnt

        if comps(tree, False) > 1:
            return False
    return True


def q(*atoms, prob=None):
    return JoinQuery(tuple(atoms), prob_var=prob)


class TestGYO:
    def test_chain_acyclic(self):
        query = q(Atom.of("R", "a", "b"), Atom.of("S", "b", "c"), Atom.of("T", "c", "d"))
        assert is_acyclic(query)
        assert _connected(gyo_join_tree(query))

    def test_star_acyclic(self):
        query = q(Atom.of("F", "a", "b", "c"), Atom.of("D1", "a", "x"),
                  Atom.of("D2", "b", "y"), Atom.of("D3", "c", "z"))
        assert is_acyclic(query)
        tree = gyo_join_tree(query)
        assert _connected(tree)
        assert len(tree.nodes()) == 4

    def test_triangle_cyclic(self):
        # The paper's prototypical cyclic query R(x,y) |><| S(y,z) |><| T(z,x).
        query = q(Atom.of("R", "x", "y"), Atom.of("S", "y", "z"), Atom.of("T", "z", "x"))
        assert not is_acyclic(query)
        with pytest.raises(ValueError):
            gyo_join_tree(query)

    def test_square_cyclic(self):
        query = q(Atom.of("R", "a", "b"), Atom.of("S", "b", "c"),
                  Atom.of("T", "c", "d"), Atom.of("U", "d", "a"))
        assert not is_acyclic(query)

    def test_self_join_aliases(self):
        query = q(Atom.of("P", "x", "g", alias="P1"), Atom.of("P", "y", "g", alias="P2"))
        assert is_acyclic(query)
        assert {n.atom.name for n in gyo_join_tree(query).nodes()} == {"P1", "P2"}

    def test_duplicate_alias_rejected(self):
        with pytest.raises(ValueError):
            q(Atom.of("P", "x"), Atom.of("P", "y"))

    def test_single_atom(self):
        tree = gyo_join_tree(q(Atom.of("R", "a", "b")))
        assert len(tree.nodes()) == 1


class TestReroot:
    def test_reroot_moves_var_to_root(self):
        query = q(Atom.of("R", "a", "b"), Atom.of("S", "b", "c"), Atom.of("T", "c", "p"))
        tree = gyo_join_tree(query)
        rr = reroot_for(tree, "p")
        assert "p" in rr.atom.var_set()
        assert {n.atom.name for n in rr.nodes()} == {"R", "S", "T"}
        assert _connected(rr)

    def test_reroot_preserves_edges(self):
        query = q(Atom.of("F", "a", "b", "c"), Atom.of("D1", "a", "x"),
                  Atom.of("D2", "b", "p"), Atom.of("D3", "c", "z"))
        tree = gyo_join_tree(query)
        rr = reroot_for(tree, "p")
        assert rr.atom.name == "D2"

        def edges(t):
            out = set()
            for n in t.nodes():
                for c in n.children:
                    out.add(frozenset((n.atom.name, c.atom.name)))
            return out

        assert edges(tree) == edges(rr)

    def test_reroot_missing_var(self):
        tree = gyo_join_tree(q(Atom.of("R", "a", "b")))
        with pytest.raises(ValueError):
            reroot_for(tree, "zzz")


def test_prob_var_validation():
    with pytest.raises(ValueError):
        q(Atom.of("R", "a"), prob="nope")


class TestDisjointAtoms:
    """Cross products are *deliberately* acyclic: disjoint atoms become
    keyless (single-group) edges of the join tree — supported end-to-end
    through shred/GET (tests/test_cross_product.py)."""

    def test_two_disjoint_atoms_acyclic(self):
        query = q(Atom.of("R", "x"), Atom.of("U", "w"))
        assert is_acyclic(query)
        tree = gyo_join_tree(query)
        assert {n.atom.name for n in tree.nodes()} == {"R", "U"}
        assert _connected(tree)

    def test_three_disjoint_atoms_chain_deterministically(self):
        query = q(Atom.of("R", "x"), Atom.of("U", "w"), Atom.of("V", "v"))
        t1 = gyo_join_tree(query)
        t2 = gyo_join_tree(query)
        assert [n.atom.name for n in t1.nodes()] == \
            [n.atom.name for n in t2.nodes()]

    def test_two_joined_components(self):
        # {R, S} joined on b; {U, V} joined on w; no variable across.
        query = q(Atom.of("R", "a", "b"), Atom.of("S", "b", "c"),
                  Atom.of("U", "w"), Atom.of("V", "w", "z"))
        assert is_acyclic(query)
        assert _connected(gyo_join_tree(query))

    def test_reroot_across_components(self):
        query = q(Atom.of("R", "a", "p"), Atom.of("U", "w"), prob="p")
        tree = gyo_join_tree(query)
        rr = reroot_for(tree, "p")
        assert rr.atom.name == "R"
        assert {n.atom.name for n in rr.nodes()} == {"R", "U"}

    def test_cyclic_component_not_masked_by_disjoint_atom(self):
        # A triangle stays cyclic no matter how many disjoint atoms the
        # vacuous ear check could eliminate first.
        triangle = (Atom.of("A", "x", "y"), Atom.of("B", "y", "z"),
                    Atom.of("C", "z", "x"))
        assert not is_acyclic(q(*triangle))
        assert not is_acyclic(q(*triangle, Atom.of("U", "w")))
        assert not is_acyclic(q(Atom.of("U", "w"), *triangle,
                                Atom.of("V", "v")))

    def test_acyclic_component_plus_cyclic_component(self):
        query = q(Atom.of("R", "a", "b"), Atom.of("S", "b", "c"),
                  Atom.of("A", "x", "y"), Atom.of("B", "y", "z"),
                  Atom.of("C", "z", "x"))
        assert not is_acyclic(query)
