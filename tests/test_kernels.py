"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
sweeping shapes and dtypes, plus hypothesis property tests (which skip
gracefully when hypothesis is not installed — see tests/_optional.py)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _optional import given, settings, st  # hypothesis, or skip shims

from repro.kernels import ops
from repro.kernels import ref


SET = dict(deadline=None, max_examples=12)


class TestBsearchProbe:
    @pytest.mark.parametrize("np_len", [2, 7, 64, 129, 1000])
    @pytest.mark.parametrize("nq", [1, 5, 128, 300])
    def test_matches_ref(self, np_len, nq):
        rng = np.random.default_rng(np_len * 1000 + nq)
        w = rng.integers(0, 5, np_len - 1)
        pref = jnp.asarray(np.concatenate([[0], np.cumsum(w)]), jnp.int32)
        total = int(pref[-1])
        q = jnp.asarray(rng.integers(0, max(total, 1), nq), jnp.int32)
        got = ops.searchsorted_prefix(pref, q)
        want = ref.bsearch_probe_ref(pref, q.reshape(1, -1)).reshape(-1)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_int64_fallback(self):
        pref = jnp.asarray([0, 2**33, 2**34], jnp.int64)
        q = jnp.asarray([0, 2**33 - 1, 2**33, 2**34 - 1], jnp.int64)
        got = ops.searchsorted_prefix(pref, q)
        np.testing.assert_array_equal(np.asarray(got), [0, 0, 1, 1])

    @given(st.lists(st.integers(0, 10), min_size=1, max_size=200))
    @settings(**SET)
    def test_property_random_weights(self, ws):
        pref = jnp.asarray(np.concatenate([[0], np.cumsum(ws)]), jnp.int32)
        total = int(pref[-1])
        if total == 0:
            return  # empty position space: nothing to probe
        q = jnp.arange(total, dtype=jnp.int32)
        got = np.asarray(ops.searchsorted_prefix(pref, q))
        want = np.asarray(ref.bsearch_probe_ref(pref, q.reshape(1, -1))).reshape(-1)
        np.testing.assert_array_equal(got, want)
        # semantic invariant: pref[j] <= q < pref[j + 1]
        prefn = np.asarray(pref)
        assert (prefn[got] <= np.asarray(q)).all()
        assert (np.asarray(q) < prefn[got + 1]).all()


class TestOpsDispatch:
    """Call-time behavior of the ops wrappers: env flags are read per call
    (not frozen at import), explicit ``interpret=`` overrides win, and
    ``REPRO_PALLAS_DISABLE`` forces the XLA fallback."""

    def _pref_q(self):
        pref = jnp.asarray(np.concatenate([[0], np.cumsum([2, 3, 1, 4])]),
                           jnp.int32)
        q = jnp.asarray([0, 1, 2, 5, 9], jnp.int32)
        return pref, q

    def test_interpret_env_read_at_call_time(self, monkeypatch):
        pref, q = self._pref_q()
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
        assert ops.interpret_default()
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
        assert not ops.interpret_default()  # no re-import needed

    def test_explicit_interpret_overrides_env(self, monkeypatch):
        # env says compiled mode (which this CPU container cannot lower);
        # the per-call override must still take the interpreter path.
        pref, q = self._pref_q()
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
        got = ops.searchsorted_prefix(pref, q, interpret=True)
        want = ref.bsearch_probe_ref(pref, q.reshape(1, -1)).reshape(-1)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_disable_env_forces_fallback(self, monkeypatch):
        pref, q = self._pref_q()
        monkeypatch.setenv("REPRO_PALLAS_DISABLE", "1")
        assert not ops.pallas_enabled()
        assert not ops.pallas_preferred()
        got = ops.searchsorted_prefix(pref, q)  # pure-XLA path
        want = ref.bsearch_probe_ref(pref, q.reshape(1, -1)).reshape(-1)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_disable_env_covers_geo_and_attention(self, monkeypatch):
        # The disable escape hatch must cover EVERY wrapper, not only the
        # index kernels: GEO and attention fall back to their ref oracles.
        u = jax.random.uniform(jax.random.key(0), (300,), jnp.float32,
                               minval=1e-6, maxval=1.0 - 1e-6)
        ks = jax.random.split(jax.random.key(1), 3)
        q = jax.random.normal(ks[0], (1, 2, 32), jnp.float32)
        k = jax.random.normal(ks[1], (1, 2, 256, 32), jnp.float32)
        v = jax.random.normal(ks[2], (1, 2, 256, 32), jnp.float32)
        qs = q[:, :, None, :].repeat(256, axis=2)
        on = (np.asarray(ops.geo_positions_fused(u, 0.1)),
              np.asarray(ops.decode_attention(q, k, v, block_s=128)),
              np.asarray(ops.prefill_attention(qs, k, v, block_q=128,
                                               block_k=128)))
        monkeypatch.setenv("REPRO_PALLAS_DISABLE", "1")
        off = (np.asarray(ops.geo_positions_fused(u, 0.1)),
               np.asarray(ops.decode_attention(q, k, v, block_s=128)),
               np.asarray(ops.prefill_attention(qs, k, v, block_q=128,
                                                block_k=128)))
        np.testing.assert_array_equal(on[0], off[0])
        np.testing.assert_allclose(on[1], off[1], rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(on[2], off[2], rtol=2e-4, atol=2e-4)

    def test_float_prefix_takes_fallback(self):
        # EXPRACE's inverse-CDF search over the float mass vector: dtypes
        # never permit the int32 kernel; the fallback must be exact.
        pref = jnp.asarray([0.0, 1.5, 2.25, 7.0], jnp.float64)
        q = jnp.asarray([0.0, 1.4999, 1.5, 6.9999, 7.5], jnp.float64)
        got = ops.searchsorted_prefix(pref, q)
        np.testing.assert_array_equal(np.asarray(got), [0, 0, 1, 2, 3])


class TestTreeProbe:
    """Fused tree-probe kernel vs the per-node USR walk (bit-identity over
    full join shapes lives in tests/test_probe_fused.py; this is the
    kernel-level shape/tiling sweep)."""

    def _shred(self, seed, nr, ns):
        from repro.core import Atom, Database, JoinQuery, build_shred
        rng = np.random.default_rng(seed)
        db = Database.from_columns({
            "R": {"x": rng.integers(0, 6, nr), "y": rng.integers(0, 6, nr)},
            "S": {"y": rng.integers(0, 6, ns), "z": rng.integers(0, 6, ns)},
        })
        q = JoinQuery((Atom.of("R", "x", "y"), Atom.of("S", "y", "z")))
        return build_shred(db, q, rep="usr")

    @pytest.mark.parametrize("k", [1, 127, 128, 129, 1000])
    @pytest.mark.parametrize("block_rows", [1, 8])
    def test_matches_per_node_across_tilings(self, k, block_rows):
        from repro.core.probe import usr_get_rows
        from repro.kernels.tree_probe import tree_probe
        shred = self._shred(k, 40, 30)
        assert shred.packed is not None
        n = int(shred.join_size)
        pos = jnp.asarray(np.random.default_rng(k).integers(0, n, k))
        want = usr_get_rows(shred, pos)
        tiles = ops.to_tiles(pos.astype(jnp.int32))
        out = tree_probe(shred.packed.arena, tiles,
                         layout=shred.packed.layout, block_rows=block_rows,
                         interpret=True)
        flat = np.asarray(out.reshape(out.shape[0], -1)[:, :k])
        for i, name in enumerate(shred.packed.layout.names):
            np.testing.assert_array_equal(flat[i], np.asarray(want[name]),
                                          err_msg=name)


class TestPrefixSum:
    @pytest.mark.parametrize("n", [1, 127, 128, 129, 8192, 10000])
    @pytest.mark.parametrize("dtype", [jnp.int32, jnp.float32])
    def test_matches_ref(self, n, dtype):
        rng = np.random.default_rng(n)
        x = jnp.asarray(rng.integers(0, 9, n), dtype)
        got = np.asarray(ops.prefix_sum(x))
        want = np.cumsum(np.asarray(x)).astype(np.asarray(x).dtype)
        np.testing.assert_allclose(got, want, rtol=1e-6)

    def test_exclusive(self):
        x = jnp.asarray([3, 1, 4, 1, 5], jnp.int32)
        got = np.asarray(ops.prefix_sum(x, exclusive=True))
        np.testing.assert_array_equal(got, [0, 3, 4, 8, 9])

    def test_int64_fallback(self):
        x = jnp.asarray([2**32, 2**32, 1], jnp.int64)
        got = np.asarray(ops.prefix_sum(x))
        np.testing.assert_array_equal(got, [2**32, 2**33, 2**33 + 1])

    def test_block_boundary_carry(self):
        # value exactly at tile boundaries exercises the SMEM carry chain
        n = 64 * 128 * 2 + 1
        x = jnp.ones((n,), jnp.int32)
        got = np.asarray(ops.prefix_sum(x))
        assert got[-1] == n and got[64 * 128] == 64 * 128 + 1


class TestGeoGaps:
    @pytest.mark.parametrize("n", [64, 128, 1000, 9000])
    @pytest.mark.parametrize("p", [0.001, 0.1, 0.5, 0.9])
    def test_matches_ref(self, n, p):
        u = jax.random.uniform(jax.random.key(n), (n,), jnp.float32,
                               minval=1e-6, maxval=1.0 - 1e-6)
        got = np.asarray(ops.geo_positions_fused(u, p))
        want = np.asarray(ref.geo_gaps_ref(u, p))
        np.testing.assert_array_equal(got, want)

    def test_positions_strictly_ascending(self):
        u = jax.random.uniform(jax.random.key(0), (5000,), jnp.float32,
                               minval=1e-6, maxval=1.0 - 1e-6)
        pos = np.asarray(ops.geo_positions_fused(u, 0.05))
        assert (np.diff(pos) > 0).all()


class TestFlashDecode:
    @pytest.mark.parametrize("B,H,KVH,S,D", [
        (1, 4, 4, 512, 64),
        (2, 8, 2, 1024, 64),    # GQA 4:1
        (2, 4, 1, 2048, 128),   # MQA
        (1, 2, 2, 640, 128),    # padded S (not block multiple)
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, B, H, KVH, S, D, dtype):
        ks = jax.random.split(jax.random.key(B * S + H), 3)
        q = jax.random.normal(ks[0], (B, H, D), dtype)
        k = jax.random.normal(ks[1], (B, KVH, S, D), dtype)
        v = jax.random.normal(ks[2], (B, KVH, S, D), dtype)
        got = ops.decode_attention(q, k, v)
        want = ref.flash_decode_ref(q, k, v, jnp.zeros((B, S), jnp.float32))
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol, atol=tol)

    def test_bias_masking_matches_short_cache(self):
        """-inf bias over the tail == attention over the truncated cache."""
        B, H, S, D, L = 1, 2, 1024, 64, 700
        ks = jax.random.split(jax.random.key(0), 3)
        q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, H, S, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, H, S, D), jnp.float32)
        bias = jnp.where(jnp.arange(S)[None, :] < L, 0.0, -1e30).astype(jnp.float32)
        got = ops.decode_attention(q, k, v, bias)
        want = ref.flash_decode_ref(q, k[:, :, :L], v[:, :, :L],
                                    jnp.zeros((B, L), jnp.float32))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_softmax_normalization(self):
        """With v == 1, attention output must be exactly 1 (partition check)."""
        B, H, S, D = 1, 2, 512, 64
        q = jax.random.normal(jax.random.key(1), (B, H, D), jnp.float32)
        k = jax.random.normal(jax.random.key(2), (B, H, S, D), jnp.float32)
        v = jnp.ones((B, H, S, D), jnp.float32)
        got = ops.decode_attention(q, k, v)
        np.testing.assert_allclose(np.asarray(got), 1.0, rtol=1e-5)


class TestFlashPrefill:
    @pytest.mark.parametrize("B,H,KVH,S,D", [
        (1, 4, 4, 512, 64),
        (2, 8, 2, 512, 64),     # GQA 4:1
        (1, 4, 1, 1536, 128),   # MQA, padded S (not an lcm multiple)
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_ref(self, B, H, KVH, S, D, causal):
        ks = jax.random.split(jax.random.key(S + H), 3)
        q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, KVH, S, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, KVH, S, D), jnp.float32)
        got = ops.prefill_attention(q, k, v, causal=causal,
                                    block_q=128, block_k=256)
        want = ref.flash_prefill_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_causal_first_token_attends_itself_only(self):
        B, H, S, D = 1, 2, 256, 64
        q = jax.random.normal(jax.random.key(0), (B, H, S, D), jnp.float32)
        k = jax.random.normal(jax.random.key(1), (B, H, S, D), jnp.float32)
        v = jax.random.normal(jax.random.key(2), (B, H, S, D), jnp.float32)
        got = ops.prefill_attention(q, k, v, causal=True, block_q=128, block_k=128)
        np.testing.assert_allclose(np.asarray(got[:, :, 0]), np.asarray(v[:, :, 0]),
                                   rtol=1e-5, atol=1e-5)
