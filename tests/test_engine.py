"""The unified query engine: correctness vs the pre-refactor paths, and
the compiled-plan / shred cache contract (DESIGN.md §7).

(a) full-join results bit-identical to the direct build_shred+flatten path;
(b) Poisson samples bit-identical to PoissonSampler under a fixed key;
(c) a second invocation with the same query fingerprint hits the plan
    cache — no shred rebuild (asserted by instrumenting build_shred).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    Atom, Database, JoinQuery, PoissonSampler, build_shred, yannakakis,
)
from repro.core.shred import build_shred as raw_build_shred
from repro.engine import (
    CapacityPolicy, QueryEngine, query_fingerprint, schema_fingerprint,
)


@pytest.fixture(scope="module")
def db():
    rng = np.random.default_rng(11)
    return Database.from_columns({
        "R": {"x": rng.integers(0, 12, 90), "p": rng.random(90) * 0.5},
        "S": {"x": rng.integers(0, 12, 140), "y": rng.integers(0, 9, 140)},
        "T": {"y": rng.integers(0, 9, 60), "z": np.arange(60)},
    })


@pytest.fixture(scope="module")
def query():
    return JoinQuery((Atom.of("R", "x", "p"), Atom.of("S", "x", "y"),
                      Atom.of("T", "y", "z")), prob_var="p")


# -- (a) full join ----------------------------------------------------------

@pytest.mark.parametrize("rep", ["usr", "csr"])
def test_full_join_bit_identical_to_direct_path(db, query, rep):
    engine = QueryEngine(db, rep=rep)
    got = engine.full_join(query)
    shred = build_shred(db, query, rep=rep)       # the pre-engine path
    want = yannakakis.flatten(shred, rep=rep)
    assert set(got) == set(want)
    for v in want:
        np.testing.assert_array_equal(np.asarray(got[v]), np.asarray(want[v]))


@pytest.mark.filterwarnings("ignore:core.yannakakis.full_join is deprecated")
def test_full_join_facade_matches_engine(db, query):
    engine = QueryEngine(db)
    a = engine.full_join(query)
    b = yannakakis.full_join(db, query)
    for v in a:
        np.testing.assert_array_equal(np.asarray(a[v]), np.asarray(b[v]))


# -- (b) Poisson sampling ---------------------------------------------------

@pytest.mark.filterwarnings("ignore:core.PoissonSampler is deprecated")
def test_poisson_sample_bit_identical_to_sampler(db, query):
    engine = QueryEngine(db)
    sampler = PoissonSampler(db, query)
    for seed in range(4):
        key = jax.random.key(seed)
        a = engine.poisson_sample(query, key)
        b = sampler.sample(key)
        assert int(a.count) == int(b.count)
        np.testing.assert_array_equal(np.asarray(a.positions),
                                      np.asarray(b.positions))
        for v in b.columns:
            np.testing.assert_array_equal(np.asarray(a.columns[v]),
                                          np.asarray(b.columns[v]))


def test_poisson_sample_statistics(db, query):
    """Mean sample count matches the exact E[k] from the index."""
    engine = QueryEngine(db)
    plan = engine.compile(query)
    cnts = [int(engine.poisson_sample(query, jax.random.key(i)).count)
            for i in range(60)]
    from repro.core import estimate
    exp = plan.expected_k()
    sd = float(estimate.sample_std(plan.w, plan.p))
    z = (np.mean(cnts) - exp) / (sd / 60 ** 0.5)
    assert abs(z) < 4.5


def test_sample_membership(db, query):
    engine = QueryEngine(db)
    smp = engine.poisson_sample(query, jax.random.key(2), auto=True)
    v = np.asarray(smp.valid())
    full = engine.full_join(query)
    keys = tuple(sorted(full))
    fullset = set(zip(*[np.asarray(full[k]) for k in keys]))
    got = list(zip(*[np.asarray(smp.columns[k])[v] for k in keys]))
    assert len(got) == int(smp.count)
    assert all(t in fullset for t in got)


# -- (c) cache behavior -----------------------------------------------------

def test_warm_cache_no_shred_rebuild(db, query, monkeypatch):
    import repro.engine.engine as engmod

    calls = []

    def counting_build(d, q, rep="usr"):
        calls.append((query_fingerprint(q), rep))
        return raw_build_shred(d, q, rep=rep)

    monkeypatch.setattr(engmod, "build_shred", counting_build)
    engine = QueryEngine(db)

    engine.poisson_sample(query, jax.random.key(0))
    assert len(calls) == 1
    # Warm: same fingerprint — full join, sampling, join_size all reuse it.
    engine.poisson_sample(query, jax.random.key(1))
    engine.full_join(query)
    engine.join_size(query)
    assert len(calls) == 1, "warm-cache calls must not rebuild the index"
    assert engine.stats.shred_builds == 1
    assert engine.stats.plan_hits >= 2

    # An *equal but distinct* query object has the same fingerprint.
    query2 = JoinQuery(tuple(query.atoms), prob_var=query.prob_var)
    assert query_fingerprint(query2) == query_fingerprint(query)
    engine.poisson_sample(query2, jax.random.key(2))
    assert len(calls) == 1

    # A different rep is a different shred cache entry.
    engine.full_join(query, rep="csr")
    assert len(calls) == 2


def test_plan_cache_shared_across_methods(db, query, monkeypatch):
    """Two methods = two plans but ONE shred (same fingerprint+rep)."""
    import repro.engine.engine as engmod

    calls = []
    monkeypatch.setattr(
        engmod, "build_shred",
        lambda d, q, rep="usr": (calls.append(rep) or raw_build_shred(d, q, rep=rep)))
    engine = QueryEngine(db)
    engine.compile(query, method="exprace")
    engine.compile(query, method="ptbern_flat")
    assert engine.stats.plan_misses == 2
    assert calls == ["usr"]


def test_lru_eviction(db):
    engine = QueryEngine(db, max_plans=2)
    queries = [
        JoinQuery((Atom.of("R", "x", f"p{i}"), Atom.of("S", "x", "y")),
                  prob_var=f"p{i}")
        for i in range(3)
    ]
    for q in queries:
        engine.compile(q)
    assert len(engine._plans) == 2
    assert len(engine._shreds) == 2


def test_fingerprints():
    db = Database.from_columns({
        "R": {"x": [1, 2], "p": [0.5, 0.5]}, "S": {"x": [1], "y": [3]}})
    qa = JoinQuery((Atom.of("R", "x", "p"), Atom.of("S", "x", "y")),
                   prob_var="p")
    qb = JoinQuery((Atom.of("R", "x", "p"), Atom.of("S", "x", "y")))
    assert query_fingerprint(qa) != query_fingerprint(qb)  # prob_var matters
    db2 = Database.from_columns({
        "R": {"x": [1, 2, 3], "p": [0.5, 0.5, 0.5]}, "S": {"x": [1], "y": [3]}})
    assert schema_fingerprint(db) != schema_fingerprint(db2)  # row counts


def test_rebind_invalidates(db, query):
    engine = QueryEngine(db)
    engine.compile(query)
    assert len(engine._plans) == 1
    engine.rebind(db)
    assert len(engine._plans) == 0 and len(engine._shreds) == 0


def test_capacity_policy_is_engine_scoped(db, query):
    """A tighter policy produces smaller buffers; overflow still flagged."""
    tight = QueryEngine(db, policy=CapacityPolicy(sigmas=0.0, slack=0,
                                                  lane_multiple=1))
    loose = QueryEngine(db)
    pt = tight.compile(query)
    pl = loose.compile(query)
    assert pt.default_capacity() <= pl.default_capacity()
    s = loose.poisson_sample(query, jax.random.key(0), auto=True)
    assert not bool(s.overflow)


def test_uniform_sample_via_engine(db, query):
    engine = QueryEngine(db)
    n = engine.join_size(query)
    smp = engine.uniform_sample(query, jax.random.key(5), 0.1)
    k = int(smp.count)
    assert 0 <= k <= smp.capacity
    pos = np.asarray(smp.positions)[:k]
    assert (pos >= 0).all() and (pos < n).all()


def test_prob_var_required_for_poisson(db):
    q = JoinQuery((Atom.of("R", "x", "p"), Atom.of("S", "x", "y")))
    engine = QueryEngine(db)
    with pytest.raises(ValueError, match="prob_var"):
        engine.poisson_sample(q, jax.random.key(0))
    # ... but full_join on the same query is fine.
    full = engine.full_join(q)
    assert len(next(iter(full.values()))) == engine.join_size(q)
