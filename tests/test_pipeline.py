"""Pipeline parallelism: GPipe schedule == sequential oracle, on a real
4-device stage mesh (subprocess)."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.parallel.pipeline import pipeline_forward, reference_forward

    assert len(jax.devices()) == 4
    try:                         # jax >= 0.5; older releases have no AxisType
        from jax.sharding import AxisType
        mesh = jax.make_mesh((4,), ("stage",),
                             axis_types=(AxisType.Auto,))
    except ImportError:
        mesh = jax.make_mesh((4,), ("stage",))

    D = 16
    def stage_fn(p, x):          # shape-preserving block
        return jnp.tanh(x @ p["w"] + p["b"])

    key = jax.random.key(0)
    params = {
        "w": jax.random.normal(key, (4, D, D)) * 0.5,
        "b": jnp.zeros((4, D)),
    }
    batch = jax.random.normal(jax.random.fold_in(key, 1), (6, 8, D))  # 6 micro

    got = pipeline_forward(stage_fn, params, batch, mesh)
    want = reference_forward(stage_fn, params, batch)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    print("PIPELINE_OK")
""")


@pytest.mark.slow
def test_gpipe_matches_sequential():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert "PIPELINE_OK" in r.stdout
