"""Fleet determinism property test (DESIGN.md §12).

For random interleavings of draws and deltas across 2-4 replicas — with
random wire delays perturbing delivery order — the replicated fleet is
*bit-identical* to the single-engine baseline:

(a) every replica's post-replay snapshot (and every snapshot it recorded
    at a version barrier) equals ``Database.apply``-ing the shared log
    sequentially;
(b) every draw's ``(count, overflow, rows)`` equals the single-engine
    ``MicroBatcher`` result for the same seed and stamped version.
"""
import numpy as np
import pytest

from _optional import HealthCheck, given, settings, st  # hypothesis or skip

from repro.core import Atom, Database, JoinQuery
from repro.core.delta import DeltaBatch
from repro.engine import QueryEngine
from repro.launch.fleet import (
    Fleet, JoinSampleRequest, UpdateRequest, serve_join_samples,
)


@pytest.fixture(scope="module")
def db():
    rng = np.random.default_rng(17)
    return Database.from_columns({
        "R": {"x": rng.integers(0, 10, 60), "p": rng.random(60) * 0.5},
        "S": {"x": rng.integers(0, 10, 100), "y": rng.integers(0, 8, 100)},
    })


@pytest.fixture(scope="module")
def shapes(db):
    q1 = JoinQuery((Atom.of("R", "x", "p"),), prob_var="p")
    q2 = JoinQuery((Atom.of("R", "x", "p"), Atom.of("S", "x", "y")),
                   prob_var="p")
    return (q1, q2)


def _delta(i):
    return DeltaBatch.of(S={"insert": {"x": [i % 10, (i + 5) % 10],
                                       "y": [i % 8, (i + 2) % 8]},
                            "delete": [0]})


def _stream(shapes, ops):
    """ops -> request stream; op 0 is an update, 1/2 pick a draw shape.
    Seeds come from the position so every draw is unique."""
    out = []
    for i, op in enumerate(ops):
        if op == 0:
            out.append(UpdateRequest(_delta(i)))
        else:
            out.append(JoinSampleRequest(query=shapes[op - 1], seed=100 + i))
    return out


def _assert_db_bit_identical(got, want):
    assert got.version == want.version
    assert set(got.relations) == set(want.relations)
    for name, rel in want.relations.items():
        other = got.relations[name]
        assert other.num_rows == rel.num_rows
        for col in rel.columns:
            a = np.asarray(other.column(col))
            b = np.asarray(rel.column(col))
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(a, b)


def _run_interleaving(db, shapes, n_replicas, ops, max_batch, delays):
    ops = ops + [1, 2]  # always at least one draw of each shape
    from repro.launch.fleet import FaultInjector

    faults = FaultInjector()
    fleet = Fleet(db, replicas=n_replicas, max_batch=max_batch,
                  max_wait_ms=1e9, max_inflight=1024, faults=faults,
                  collect_rows=True)
    for ridx, at, delay in delays:
        name = fleet.replicas[ridx % n_replicas].name
        faults.inject(f"deliver:router->{name}", ("delay", delay), at=at)

    reqs = _stream(shapes, ops)
    done = []
    for r in reqs:
        assert fleet.submit(r) is None  # window is large: nothing rejects
        done += fleet.advance(0.001)
    done += fleet.advance(0.05) + fleet.drain()
    draws = [r for r in done if isinstance(r, JoinSampleRequest)]
    assert {id(r) for r in draws} == {
        id(r) for r in reqs if isinstance(r, JoinSampleRequest)}

    # (a) the log, applied sequentially, is the version history; every
    # replica snapshot — final and recorded — is bit-identical to it.
    dbs = [db]
    for lsn in range(1, fleet.log.head + 1):
        dbs.append(dbs[-1].apply(fleet.log.entry(lsn)))
    assert fleet.db_version == dbs[-1].version
    for rep in fleet.replicas:
        if rep.name in fleet.router.drained:
            _assert_db_bit_identical(rep.engine.db, dbs[-1])
        for version, snap in rep.snapshots.items():
            _assert_db_bit_identical(snap, dbs[version])

    # (b) each draw equals the single-engine MicroBatcher at its stamp.
    base = {(r.seed, r.db_version): r
            for r in serve_join_samples(QueryEngine(db), _stream(shapes, ops),
                                        max_batch=max_batch,
                                        collect_rows=True)
            if isinstance(r, JoinSampleRequest)}
    for r in draws:
        want = base[(r.seed, r.db_version)]
        assert (r.count, r.overflow) == (want.count, want.overflow)
        assert set(r.rows) == set(want.rows)
        for c in want.rows:
            np.testing.assert_array_equal(r.rows[c], want.rows[c])


@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    n_replicas=st.integers(2, 4),
    ops=st.lists(st.integers(0, 2), min_size=4, max_size=12),
    max_batch=st.sampled_from([1, 3, 100]),
    delays=st.lists(
        st.tuples(st.integers(0, 3),            # replica index (mod n)
                  st.integers(1, 3),            # nth message on that edge
                  st.sampled_from([0.003, 0.015])),
        max_size=3, unique_by=lambda d: (d[0], d[1])),
)
def test_random_interleavings_bit_identical_to_single_engine(
        db, shapes, n_replicas, ops, max_batch, delays):
    _run_interleaving(db, shapes, n_replicas, ops, max_batch, delays)


@pytest.mark.parametrize("n_replicas,ops,max_batch,delays", [
    # a pinned mixed stream with a mid-stream delta and a delayed edge —
    # runs even without hypothesis so the property body always has coverage
    (3, [1, 2, 0, 1, 2, 1, 0, 2, 1], 3, [(0, 1, 0.015), (1, 2, 0.003)]),
    (2, [2, 0, 2, 2], 1, [(0, 1, 0.003)]),
])
def test_pinned_interleavings(db, shapes, n_replicas, ops, max_batch, delays):
    _run_interleaving(db, shapes, n_replicas, ops, max_batch, delays)
