"""Elastic re-meshing: a run checkpointed under 4 devices resumes under 2
devices (node loss) and produces the same loss trajectory as an
uninterrupted single-device run — the data stream is deterministic in
(seed, step) and the global batch is mesh-independent."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import sys, json
    from repro.launch.train import TrainConfig, train
    steps, ckpt = int(sys.argv[1]), sys.argv[2]
    out = train(TrainConfig(arch="smollm_135m", steps=steps, batch=8,
                            seq_len=24, ckpt_dir=ckpt, ckpt_every=10,
                            log_every=1000, data="synthetic"))
    print("LOSSES:" + json.dumps(out["losses"]))
""")


def _run(devices: int, steps: int, ckpt: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT, str(steps), ckpt],
                       env=env, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    line = [l for l in r.stdout.splitlines() if l.startswith("LOSSES:")][0]
    return json.loads(line[len("LOSSES:"):])


@pytest.mark.slow
def test_resume_across_device_counts(tmp_path):
    """The elasticity contract: the SAMPLE STREAM is identical across mesh
    sizes (deterministic in (seed, step)); the loss trajectory agrees up to
    float reassociation (different DP reduction orders are not bitwise —
    measured ~0.5% drift over 20 steps)."""
    ref = _run(1, 20, str(tmp_path / "ref"))           # uninterrupted, 1 dev
    _run(4, 10, str(tmp_path / "elastic"))             # phase 1 on 4 devices
    tail = _run(2, 20, str(tmp_path / "elastic"))      # "node failure" -> 2
    assert len(tail) == 10                              # resumed at step 10
    import numpy as np
    np.testing.assert_allclose(ref[10:], tail, rtol=0.02)


def test_batch_stream_mesh_independent():
    """The core guarantee behind elastic resume: batch_at(step) bytes do not
    depend on the device count / mesh at all."""
    import numpy as np
    from repro.data import SyntheticLMSource, make_corpus_db, PoissonJoinSource
    src = SyntheticLMSource(100, 16, 8, seed=5)
    a = src.batch_at(7)
    b = SyntheticLMSource(100, 16, 8, seed=5).batch_at(7)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    db = make_corpus_db(64, 8, 17, 100, seed=3)
    p1 = PoissonJoinSource(db, 17, 4, seed=9).batch_at(11)
    p2 = PoissonJoinSource(db, 17, 4, seed=9).batch_at(11)
    np.testing.assert_array_equal(np.asarray(p1["tokens"]), np.asarray(p2["tokens"]))
