"""End-to-end Poisson sampling over joins (Index-and-Probe vs M&S).

This suite deliberately exercises the *deprecated* facades
(``core.PoissonSampler``, ``core.yannakakis.full_join``) — it is their
contract coverage until removal, so the DeprecationWarnings are expected
here (and asserted explicitly in ``TestDeprecation``). New code goes
through ``repro.engine.QueryEngine``.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    Atom, Database, JoinQuery, PoissonSampler, estimate, yannakakis,
)

pytestmark = pytest.mark.filterwarnings(
    "ignore:core.PoissonSampler is deprecated",
    "ignore:core.yannakakis.full_join is deprecated",
)


@pytest.fixture(scope="module")
def contact_db():
    """A miniature of the paper's Q_c: Person self-join x ContactProb."""
    rng = np.random.default_rng(7)
    nper, npool, nage = 120, 8, 3
    grid = [(g, a1, a2) for g in range(npool) for a1 in range(nage) for a2 in range(nage)]
    return Database.from_columns({
        "Person": {"pers": np.arange(nper), "age": rng.integers(0, nage, nper),
                   "pool": rng.integers(0, npool, nper)},
        "ContactProb": {"pool": [g for g, _, _ in grid],
                        "age1": [a for _, a, _ in grid],
                        "age2": [a for _, _, a in grid],
                        "prob": rng.random(len(grid)) * 0.25},
    })


@pytest.fixture(scope="module")
def contact_query():
    return JoinQuery((
        Atom.of("Person", "per1", "age1", "pool", alias="P1"),
        Atom.of("Person", "per2", "age2", "pool", alias="P2"),
        Atom.of("ContactProb", "pool", "age1", "age2", "prob"),
    ), prob_var="prob")


class TestPoissonSampler:
    def test_sample_membership(self, contact_db, contact_query):
        s = PoissonSampler(contact_db, contact_query, rep="both")
        smp = s.sample_auto(jax.random.key(0))
        v = np.asarray(smp.valid())
        full = yannakakis.full_join(contact_db, contact_query)
        keys = ("per1", "per2", "pool", "age1", "age2")
        fullset = set(zip(*[np.asarray(full[k]) for k in keys]))
        got = list(zip(*[np.asarray(smp.columns[k])[v] for k in keys]))
        assert len(got) == int(smp.count)
        assert all(t in fullset for t in got)

    def test_sample_count_statistics(self, contact_db, contact_query):
        s = PoissonSampler(contact_db, contact_query)
        cnts = [int(s.sample(jax.random.key(i)).count) for i in range(60)]
        exp = s.expected_k()
        sd = float(estimate.sample_std(s.w, s.p))
        z = (np.mean(cnts) - exp) / (sd / 60 ** 0.5)
        assert abs(z) < 4.5

    def test_csr_usr_same_sample(self, contact_db, contact_query):
        s = PoissonSampler(contact_db, contact_query, rep="both")
        a = s.sample(jax.random.key(3), rep="usr")
        b = s.sample(jax.random.key(3), rep="csr")
        for k in a.columns:
            assert np.array_equal(np.asarray(a.columns[k]), np.asarray(b.columns[k])), k

    def test_prob_var_at_root(self, contact_db, contact_query):
        s = PoissonSampler(contact_db, contact_query)
        assert contact_query.prob_var in s.shred.root.variables

    def test_uniform_sampling_methods(self, contact_db, contact_query):
        s = PoissonSampler(contact_db, contact_query)
        n = s.join_size
        for method in ("bern", "geo", "hybrid", "binom"):
            smp = s.uniform_sample(jax.random.key(1), 0.05, method=method)
            c = int(smp.count)
            sd = (n * 0.05 * 0.95) ** 0.5
            assert abs(c - n * 0.05) < 6 * sd, (method, c, n * 0.05)

    def test_sample_determinism(self, contact_db, contact_query):
        s = PoissonSampler(contact_db, contact_query)
        a = s.sample(jax.random.key(11))
        b = s.sample(jax.random.key(11))
        assert np.array_equal(np.asarray(a.positions), np.asarray(b.positions))

    def test_ptbern_flat_matches_exprace_stats(self, contact_db, contact_query):
        s1 = PoissonSampler(contact_db, contact_query, method="exprace")
        s2 = PoissonSampler(contact_db, contact_query, method="ptbern_flat")
        c1 = [int(s1.sample(jax.random.key(i)).count) for i in range(40)]
        c2 = [int(s2.sample(jax.random.key(i)).count) for i in range(40)]
        se = (np.var(c1) / 40 + np.var(c2) / 40) ** 0.5
        assert abs(np.mean(c1) - np.mean(c2)) < 4.5 * max(se, 1e-9)


class TestMaterializeAndScan:
    def test_ms_expectation(self, contact_db, contact_query):
        kept = []
        for i in range(25):
            _, keep = yannakakis.materialize_and_scan(
                jax.random.key(i), contact_db, contact_query)
            kept.append(int(np.asarray(keep).sum()))
        s = PoissonSampler(contact_db, contact_query)
        exp = s.expected_k()
        sd = float(estimate.sample_std(s.w, s.p))
        z = (np.mean(kept) - exp) / (sd / 25 ** 0.5)
        assert abs(z) < 4.5

    def test_ms_uniform(self, contact_db, contact_query):
        cols, keep = yannakakis.materialize_and_scan(
            jax.random.key(0), contact_db, contact_query, uniform_p=0.1)
        n = keep.shape[0]
        assert abs(int(keep.sum()) - 0.1 * n) < 6 * (n * 0.09) ** 0.5


def test_empty_join_sampling():
    db = Database.from_columns({"R": {"x": [1, 2], "p": [0.5, 0.5]},
                                "S": {"x": [7, 9]}})
    q = JoinQuery((Atom.of("R", "x", "p"), Atom.of("S", "x")), prob_var="p")
    s = PoissonSampler(db, q)
    assert s.join_size == 0
    smp = s.sample(jax.random.key(0))
    assert int(smp.count) == 0


class TestDeprecation:
    """The legacy facades must say, loudly, where to go instead."""

    def _db_q(self):
        db = Database.from_columns({"R": {"x": [1, 2], "p": [0.5, 0.5]},
                                    "S": {"x": [1, 2]}})
        q = JoinQuery((Atom.of("R", "x", "p"), Atom.of("S", "x")),
                      prob_var="p")
        return db, q

    def test_poisson_sampler_warns(self):
        db, q = self._db_q()
        with pytest.warns(DeprecationWarning, match="QueryEngine"):
            PoissonSampler(db, q)

    def test_full_join_warns(self):
        db, q = self._db_q()
        with pytest.warns(DeprecationWarning, match="QueryEngine"):
            yannakakis.full_join(db, q)
