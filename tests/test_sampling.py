"""Statistical correctness of position samplers (paper §5).

Every sampler is checked against exact Bernoulli-process statistics:
count moments and (for the non-uniform EXPRACE) per-position marginals and
pairwise joint inclusion — the strongest practical test of "independent
Bernoulli trial per tuple" semantics.
"""
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import sampling

N_SEEDS = 120


def _collect(fn, n, nseeds=N_SEEDS):
    counts, seen = [], np.zeros(n)
    jfn = jax.jit(fn)
    for s in range(nseeds):
        ps = jfn(jax.random.key(s))
        c = int(ps.count)
        counts.append(c)
        pos = np.asarray(ps.positions)[:c]
        assert (pos >= 0).all() and (pos < n).all()
        assert len(np.unique(pos)) == c, "positions must be distinct"
        seen[pos] += 1
    return np.asarray(counts), seen / nseeds


@pytest.mark.parametrize("method", ["bern", "geo", "binom", "hybrid"])
@pytest.mark.parametrize("p", [0.02, 0.3, 0.5, 0.8])
def test_uniform_count_moments(method, p):
    n, cap = 600, 768
    fn = {
        "bern": sampling.bern_positions,
        "geo": sampling.geo_positions,
        "binom": sampling.binom_positions,
        "hybrid": sampling.hybrid_positions,
    }[method]
    counts, incl = _collect(lambda k: fn(k, p, n, cap), n)
    z = (counts.mean() - n * p) / ((n * p * (1 - p)) ** 0.5 / len(counts) ** 0.5)
    assert abs(z) < 4.5, f"{method} p={p}: count mean z={z:.2f}"
    # inclusion rate across positions ~ p
    zi = (incl.mean() - p) / ((p * (1 - p) / (n * len(counts))) ** 0.5)
    assert abs(zi) < 4.5, f"{method} p={p}: inclusion z={zi:.2f}"


@pytest.mark.parametrize("method,p", [("geo", 0.0), ("geo", 1.0),
                                      ("bern", 0.0), ("bern", 1.0),
                                      ("hybrid", 0.0), ("hybrid", 1.0)])
def test_uniform_endpoints(method, p):
    n, cap = 100, 128
    fn = {"bern": sampling.bern_positions, "geo": sampling.geo_positions,
          "hybrid": sampling.hybrid_positions}[method]
    ps = jax.jit(fn, static_argnums=(2, 3))(jax.random.key(0), p, n, cap)
    assert int(ps.count) == (0 if p == 0.0 else n)
    if p == 1.0:
        assert np.array_equal(np.asarray(ps.positions)[:n], np.arange(n))


def test_geo_positions_sorted_strict():
    ps = jax.jit(sampling.geo_positions, static_argnums=(2, 3))(
        jax.random.key(1), 0.2, 5000, 2048)
    pos = np.asarray(ps.positions)[: int(ps.count)]
    assert (np.diff(pos) > 0).all()


def test_geo_overflow_flagged():
    # cap too small for p*n: must flag overflow rather than silently truncate.
    ps = jax.jit(sampling.geo_positions, static_argnums=(2, 3))(
        jax.random.key(0), 0.5, 10000, 128)
    assert bool(ps.overflow)


class TestExprace:
    def _run(self, wv, pv, cap=64, acap=128, nseeds=800):
        w = jnp.asarray(wv, jnp.int64)
        p = jnp.asarray(pv, jnp.float64)
        prefE = jnp.concatenate([jnp.zeros(1, jnp.int64), jnp.cumsum(w)])
        nf = int(prefE[-1])
        fn = jax.jit(partial(sampling.exprace_positions, cap=cap, arrival_cap=acap))
        seen = np.zeros(nf)
        pair = np.zeros((nf, nf))
        counts = []
        for s in range(nseeds):
            ps = fn(jax.random.key(s), w, p, prefE)
            assert not bool(ps.overflow)
            c = int(ps.count)
            counts.append(c)
            pos = np.asarray(ps.positions)[:c]
            assert (pos >= 0).all() and (pos < nf).all()
            assert (np.diff(pos) > 0).all(), "sorted distinct"
            seen[pos] += 1
            m = np.zeros(nf)
            m[pos] = 1
            pair += np.outer(m, m)
        rootid = np.searchsorted(np.asarray(prefE), np.arange(nf), side="right") - 1
        pexp = np.asarray(p)[rootid]
        zm = (seen / nseeds - pexp) / np.maximum((pexp * (1 - pexp) / nseeds) ** 0.5, 1e-9)
        eij = np.outer(pexp, pexp)
        np.fill_diagonal(eij, pexp)
        zp = (pair / nseeds - eij) / np.maximum((eij * (1 - eij) / nseeds) ** 0.5, 1e-9)
        np.fill_diagonal(zp, 0)
        return np.asarray(counts), np.abs(zm).max(), np.abs(zp).max(), float(np.sum(np.asarray(w) * np.asarray(p)))

    def test_marginals_and_pairwise_exact(self):
        counts, zm, zp, exp = self._run([8, 5, 3, 7, 1, 4], [0.35, 0.9, 1.0, 0.0, 0.5, 0.75])
        z = (counts.mean() - exp) / (counts.std(ddof=1) / len(counts) ** 0.5)
        assert abs(z) < 4.5
        assert zm < 5.0, f"marginal inclusion bias: max|z|={zm:.2f}"
        assert zp < 5.5, f"pairwise dependence: max|z|={zp:.2f}"

    def test_complement_path_high_p(self):
        counts, zm, zp, exp = self._run([10, 6], [0.97, 0.85], nseeds=600)
        assert zm < 5.0 and zp < 5.5

    def test_endpoint_probabilities_deterministic(self):
        w = jnp.asarray([5, 4], jnp.int64)
        p = jnp.asarray([1.0, 0.0], jnp.float64)
        prefE = jnp.asarray([0, 5, 9], jnp.int64)
        ps = jax.jit(partial(sampling.exprace_positions, cap=16, arrival_cap=16))(
            jax.random.key(0), w, p, prefE)
        assert int(ps.count) == 5
        assert np.array_equal(np.asarray(ps.positions)[:5], np.arange(5))

    def test_zero_weight_roots_never_sampled(self):
        w = jnp.asarray([0, 6, 0], jnp.int64)
        p = jnp.asarray([1.0, 0.5, 1.0], jnp.float64)
        prefE = jnp.asarray([0, 0, 6, 6], jnp.int64)
        fn = jax.jit(partial(sampling.exprace_positions, cap=16, arrival_cap=32))
        for s in range(50):
            ps = fn(jax.random.key(s), w, p, prefE)
            pos = np.asarray(ps.positions)[: int(ps.count)]
            assert (pos < 6).all()

    def test_matches_host_oracle_distribution(self):
        """EXPRACE count distribution == paper-faithful sequential PT* oracle."""
        wv, pv = [12, 9, 20], [0.15, 0.6, 0.33]
        w = jnp.asarray(wv, jnp.int64)
        p = jnp.asarray(pv, jnp.float64)
        prefE = jnp.concatenate([jnp.zeros(1, jnp.int64), jnp.cumsum(w)])
        fn = jax.jit(partial(sampling.exprace_positions, cap=64, arrival_cap=96))
        ours = [int(fn(jax.random.key(s), w, p, prefE).count) for s in range(400)]
        rng = np.random.default_rng(0)
        host = [len(sampling.pt_positions_host(rng, wv, pv, "hybrid")) for _ in range(400)]
        # two-sample z-test on means
        se = (np.var(ours) / 400 + np.var(host) / 400) ** 0.5
        z = (np.mean(ours) - np.mean(host)) / max(se, 1e-9)
        assert abs(z) < 4.5, f"EXPRACE vs host oracle: z={z:.2f}"


def test_host_oracle_methods_agree():
    rng = np.random.default_rng(1)
    w, p = [30, 40], [0.2, 0.45]
    means = {}
    for m in ("bern", "geo", "hybrid"):
        ks = [len(sampling.pt_positions_host(rng, w, p, m)) for _ in range(300)]
        means[m] = np.mean(ks)
    exp = 30 * 0.2 + 40 * 0.45
    for m, v in means.items():
        assert abs(v - exp) < 3.0, (m, v, exp)
