"""Substrate tests: checkpointing (incl. fault injection), data pipeline
determinism, optimizer, gradient compression, schedules, hlo cost parser."""
import json
import os
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import PoissonJoinSource, SyntheticLMSource, make_corpus_db
from repro.optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine
from repro.parallel import compress_int8, decompress_int8


class TestCheckpoint:
    def _tree(self, x=1.0):
        return {"a": jnp.full((4, 3), x), "b": {"c": jnp.arange(5), "d": jnp.float32(x)}}

    def test_save_restore_roundtrip(self, tmp_path):
        cm = CheckpointManager(tmp_path, async_save=False)
        t = self._tree(2.5)
        cm.save(7, t)
        step, got = cm.restore(self._tree(0.0))
        assert step == 7
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_keep_n_gc(self, tmp_path):
        cm = CheckpointManager(tmp_path, keep_n=2, async_save=False)
        for s in (1, 2, 3, 4):
            cm.save(s, self._tree(s))
        assert cm.all_steps() == [3, 4]

    def test_corruption_falls_back(self, tmp_path):
        cm = CheckpointManager(tmp_path, async_save=False)
        cm.save(1, self._tree(1.0))
        cm.save(2, self._tree(2.0))
        # corrupt the newest shard (torn write / bad disk)
        shard = tmp_path / "step_0000000002" / "shard0.npz"
        shard.write_bytes(shard.read_bytes()[:-20] + b"garbage_garbage_g_20")
        step, got = cm.restore(self._tree(0.0))
        assert step == 1, "must fall back to the previous valid checkpoint"
        assert float(got["b"]["d"]) == 1.0

    def test_partial_save_invisible(self, tmp_path):
        """A tmp dir left by a crash mid-save is never restored."""
        cm = CheckpointManager(tmp_path, async_save=False)
        cm.save(5, self._tree(5.0))
        (tmp_path / "tmp.9.0").mkdir()
        (tmp_path / "tmp.9.0" / "shard0.npz").write_bytes(b"junk")
        step, _ = cm.restore(self._tree(0.0))
        assert step == 5

    def test_async_save(self, tmp_path):
        cm = CheckpointManager(tmp_path, async_save=True)
        cm.save(3, self._tree(3.0))
        cm.wait()
        assert cm.all_steps() == [3]


class TestDataPipeline:
    def test_deterministic_in_seed_step(self):
        db = make_corpus_db(64, 8, 17, 100, seed=3)
        a = PoissonJoinSource(db, 17, 4, seed=9).batch_at(5)
        b = PoissonJoinSource(db, 17, 4, seed=9).batch_at(5)
        np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))

    def test_different_steps_differ(self):
        db = make_corpus_db(64, 8, 17, 100, seed=3)
        src = PoissonJoinSource(db, 17, 4, seed=9)
        a, b = src.batch_at(1), src.batch_at(2)
        assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))

    def test_quality_weighting(self):
        """Docs in higher-quality clusters must be sampled more often."""
        db = make_corpus_db(400, 2, 9, 50, seed=0)
        # force cluster 0 -> p=0.9, cluster 1 -> p=0.05
        import jax.numpy as jnp_
        db.relations["ClusterQuality"].columns["p"] = jnp_.asarray([0.9, 0.05])
        src = PoissonJoinSource(db, 9, 16, seed=1)
        clusters = np.asarray(db.relations["Doc"].column("clust"))
        counts = np.zeros(2)
        for step in range(30):
            s = src.engine.sample(src.query,
                                  jax.random.fold_in(src.key, step),
                                  cap=src.cap)
            docs = np.asarray(s.columns["doc"])[:int(s.count)]
            for c in clusters[docs]:
                counts[c] += 1
        n0 = (clusters == 0).sum()
        n1 = (clusters == 1).sum()
        rate0, rate1 = counts[0] / max(n0, 1), counts[1] / max(n1, 1)
        assert rate0 > 5 * rate1, (rate0, rate1)

    def test_synthetic_source_shapes(self):
        src = SyntheticLMSource(100, 16, 4, seed=0)
        b = src.batch_at(0)
        assert b["tokens"].shape == (4, 16) and b["targets"].shape == (4, 16)


class TestOptim:
    def test_adamw_converges_quadratic(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
        params = {"w": jnp.asarray([4.0, -3.0])}
        state = adamw_init(cfg, params)
        for _ in range(200):
            g = {"w": 2 * params["w"]}  # d/dw ||w||^2
            params, state, _ = adamw_update(cfg, params, g, state)
        assert float(jnp.abs(params["w"]).max()) < 1e-2

    def test_factored_matches_dense_direction(self):
        k = jax.random.key(0)
        p = {"w": jax.random.normal(k, (8, 6))}
        g = {"w": jax.random.normal(jax.random.fold_in(k, 1), (8, 6))}
        dense = adamw_update(AdamWConfig(lr=0.01), p, g,
                             adamw_init(AdamWConfig(), p))[0]["w"]
        fact_cfg = AdamWConfig(lr=0.01, factored=True)
        fact = adamw_update(fact_cfg, p, g, adamw_init(fact_cfg, p))[0]["w"]
        # same sign of update on first step (rank-1 v approx is exact at t=1
        # up to the row/col means); directions should broadly agree
        agree = jnp.mean((jnp.sign(dense - p["w"]) == jnp.sign(fact - p["w"])))
        assert float(agree) > 0.9

    def test_clip_norm(self):
        from repro.optim.adamw import clip_by_global_norm
        g = {"w": jnp.full((10,), 100.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped)))
        assert abs(float(total) - 1.0) < 1e-5

    def test_schedule(self):
        assert float(warmup_cosine(0, warmup=10, total=100)) == 0.0
        assert abs(float(warmup_cosine(10, warmup=10, total=100)) - 1.0) < 1e-6
        assert float(warmup_cosine(100, warmup=10, total=100)) <= 0.11


class TestCompression:
    def test_roundtrip_error_bounded(self):
        g = jax.random.normal(jax.random.key(0), (128,)) * 3
        q, s = compress_int8(g)
        err = jnp.abs(decompress_int8(q, s) - g)
        assert float(err.max()) <= float(s) * 0.5 + 1e-6

    def test_error_feedback_unbiased_accumulation(self):
        """With EF, the accumulated applied update converges to the true sum."""
        rng = np.random.default_rng(0)
        true_sum = np.zeros(64)
        applied = np.zeros(64)
        err = jnp.zeros(64)
        for i in range(200):
            g = jnp.asarray(rng.normal(size=64) * 0.01)
            true_sum += np.asarray(g)
            corrected = g + err
            q, s = compress_int8(corrected)
            deq = decompress_int8(q, s)
            applied += np.asarray(deq)
            err = corrected - deq
        # the residual is bounded by one quantization step, not growing
        assert np.abs(true_sum - applied).max() < 0.01


class TestHloCost:
    def test_scan_multiplier(self):
        from repro.launch.hlo_cost import HloCost

        def f(x, w):
            def body(h, _):
                return jnp.tanh(h @ w), None
            return jax.lax.scan(body, x, None, length=10)[0]

        x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        comp = jax.jit(f).lower(x, x).compile()
        flops = HloCost(comp.as_text()).entry_cost()["flops"]
        expected = 10 * 2 * 128 ** 3
        assert 0.9 < flops / expected < 1.2
