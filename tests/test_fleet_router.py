"""Router behavior (DESIGN.md §12): admission control / backpressure,
fingerprint-affine routing observed through aggregated CacheStats, and
reproducible deadline flushes under the injectable clock.
"""
import numpy as np
import pytest

from repro.core import Atom, Database, JoinQuery
from repro.core.delta import DeltaBatch
from repro.engine import CacheStats, QueryEngine, query_fingerprint
from repro.launch.fleet import (
    DOWN, Fleet, JoinSampleRequest, Rejected, UpdateRequest, serve_fleet,
)


@pytest.fixture(scope="module")
def db():
    rng = np.random.default_rng(7)
    return Database.from_columns({
        "R": {"x": rng.integers(0, 10, 60), "p": rng.random(60) * 0.5},
        "S": {"x": rng.integers(0, 10, 90), "y": rng.integers(0, 8, 90)},
        "T": {"y": rng.integers(0, 8, 40), "z": np.arange(40)},
    })


@pytest.fixture(scope="module")
def shapes(db):
    q1 = JoinQuery((Atom.of("R", "x", "p"),), prob_var="p")
    q2 = JoinQuery((Atom.of("R", "x", "p"), Atom.of("S", "x", "y")),
                   prob_var="p")
    q3 = JoinQuery((Atom.of("R", "x", "p"), Atom.of("S", "x", "y"),
                    Atom.of("T", "y", "z")), prob_var="p")
    return (q1, q2, q3)


# -- backpressure ------------------------------------------------------------

def test_admission_queue_full_returns_rejected_never_drops(db, shapes):
    fleet = Fleet(db, replicas=2, max_batch=100, max_wait_ms=1e9,
                  max_inflight=4)
    accepted, rejected = [], []
    for i in range(7):
        req = JoinSampleRequest(query=shapes[0], seed=i)
        res = fleet.submit(req)
        (accepted if res is None else rejected).append(res or req)
    # the window is 4: requests 5-7 got explicit Rejected responses
    assert len(accepted) == 4 and len(rejected) == 3
    assert all(isinstance(r, Rejected) for r in rejected)
    assert all("queue full" in r.reason for r in rejected)
    assert fleet.router.rejected == 3
    # nothing was silently dropped: every accepted request completes...
    done = fleet.drain()
    assert {id(r) for r in done} == {id(r) for r in accepted}
    assert all(r.count is not None for r in accepted)
    # ...and the rejected ones were never admitted anywhere
    assert fleet.router.accepted == 4


def test_rejected_request_can_be_resubmitted(db, shapes):
    fleet = Fleet(db, replicas=1, max_batch=100, max_wait_ms=5.0,
                  max_inflight=2)
    fleet.submit(JoinSampleRequest(query=shapes[0], seed=1))
    fleet.submit(JoinSampleRequest(query=shapes[0], seed=2))
    r3 = JoinSampleRequest(query=shapes[0], seed=3)
    assert isinstance(fleet.submit(r3), Rejected)  # window full
    assert len(fleet.advance(0.005)) == 2  # deadline flush clears the window
    assert fleet.submit(r3) is None  # resubmission admitted
    fleet.drain()
    assert r3.count is not None


def test_drained_fleet_rejects_new_work(db, shapes):
    fleet = Fleet(db, replicas=2)
    fleet.submit(JoinSampleRequest(query=shapes[0], seed=0))
    fleet.drain()
    res = fleet.submit(JoinSampleRequest(query=shapes[0], seed=1))
    assert isinstance(res, Rejected) and "no healthy replicas" in res.reason
    assert all(h == DOWN for h in fleet.health().values())


# -- affinity ----------------------------------------------------------------

def test_affinity_one_plan_miss_per_shape_per_replica(db, shapes):
    """Fingerprint-affine routing: each shape compiles on exactly ONE
    replica, so fleet-wide plan misses == number of distinct shapes even
    with every shape drawn many times."""
    fleet = Fleet(db, replicas=3, max_batch=4, max_wait_ms=1e9)
    for i in range(24):
        assert fleet.submit(
            JoinSampleRequest(query=shapes[i % 3], seed=i)) is None
    done = fleet.drain()
    assert len(done) == 24
    agg = fleet.stats()
    assert agg.plan_misses == len(shapes)
    assert agg.shred_builds == len(shapes)
    # and the aggregate really is the field-wise sum over replicas
    manual = CacheStats.aggregate(r.engine.stats for r in fleet.replicas)
    assert agg == manual
    # per-replica: a replica either homes a shape (>=1 miss) or never saw it
    homed = sum(1 for r in fleet.replicas if r.engine.stats.plan_misses)
    assert sum(r.engine.stats.plan_misses for r in fleet.replicas) == 3
    assert homed <= 3


def test_affinity_is_stable_across_runs(db, shapes):
    """The home replica comes from a stable hash (md5, not the salted
    builtin), so two identical fleets route identically."""
    def homes():
        fleet = Fleet(db, replicas=4)
        return [fleet.router._route(query_fingerprint(q)) for q in shapes]
    assert homes() == homes()


# -- injectable clock / deadlines -------------------------------------------

def test_deadline_flush_is_clock_driven_and_reproducible(db, shapes):
    def run():
        fleet = Fleet(db, replicas=2, max_batch=100, max_wait_ms=5.0)
        req = JoinSampleRequest(query=shapes[1], seed=9)
        fleet.submit(req)
        assert fleet.advance(0.004) == []      # 4ms < 5ms: still pending
        done = fleet.advance(0.002)            # deadline passed at 5ms
        assert [id(r) for r in done] == [id(req)]
        return req.latency_s
    lat_a, lat_b = run(), run()
    # sim-time latency is exact and identical between runs: enqueue at t=0,
    # timer fires at t=5ms, response delivered at the same instant
    assert lat_a == lat_b == pytest.approx(0.005)


def test_update_commits_at_log_append(db, shapes):
    fleet = Fleet(db, replicas=2, max_batch=100, max_wait_ms=1e9)
    before = JoinSampleRequest(query=shapes[1], seed=0)
    fleet.submit(before)
    upd = UpdateRequest(DeltaBatch.of(
        S={"insert": {"x": [1, 2], "y": [3, 4]}, "delete": [0]}))
    assert fleet.submit(upd) is None
    assert upd.applied_version == 1  # committed immediately (log append)
    assert fleet.log.entry(1).lsn == 1
    after = JoinSampleRequest(query=shapes[1], seed=1)
    fleet.submit(after)
    fleet.drain()
    # version stamps straddle the update; both draws match their snapshots
    assert before.db_version == 0 and after.db_version == 1
    ref = QueryEngine(db)
    import jax
    want0 = ref.sample(shapes[1], jax.random.key(0))
    want1 = QueryEngine(db.apply(upd.delta)).sample(shapes[1],
                                                    jax.random.key(1))
    assert before.count == int(want0.count)
    assert after.count == int(want1.count)


def test_serve_fleet_closed_loop_equals_baseline(db, shapes):
    from repro.launch.fleet import serve_join_samples

    def stream():
        s = []
        for i in range(17):
            s.append(JoinSampleRequest(query=shapes[i % 3], seed=i))
            if i % 6 == 5:
                s.append(UpdateRequest(DeltaBatch.of(
                    S={"insert": {"x": [i], "y": [i % 8]}})))
        return s

    done, fleet = serve_fleet(db, stream(), replicas=3, max_batch=4,
                              collect_rows=True)
    draws = [r for r in done if isinstance(r, JoinSampleRequest)]
    assert len(draws) == 17
    base = {(r.seed, r.db_version): r
            for r in serve_join_samples(QueryEngine(db), stream(),
                                        max_batch=4, collect_rows=True)
            if isinstance(r, JoinSampleRequest)}
    for r in draws:
        b = base[(r.seed, r.db_version)]
        assert (r.count, r.overflow) == (b.count, b.overflow)
        assert set(r.rows) == set(b.rows)
        for c in b.rows:
            assert np.array_equal(r.rows[c], b.rows[c])
