"""Multi-device sharded Poisson sampling (subprocess with 4 host devices).

The main test process keeps the default single-device platform (the dry-run
is the only place that forces 512); correctness across real device shards is
exercised in a subprocess.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import *
    from repro.core.distributed import ShardedPoissonSampler
    from repro.engine import QueryEngine

    rng = np.random.default_rng(2)
    NPER, NPOOL, NAGE = 90, 8, 3
    db = Database.from_columns({
        "Person": {"pers": np.arange(NPER), "age": rng.integers(0,NAGE,NPER),
                   "pool": rng.integers(0,NPOOL,NPER)},
        "ContactProb": {"pool": np.repeat(np.arange(NPOOL), NAGE*NAGE),
                        "age1": np.tile(np.repeat(np.arange(NAGE),NAGE), NPOOL),
                        "age2": np.tile(np.arange(NAGE), NPOOL*NAGE),
                        "prob": rng.random(NPOOL*NAGE*NAGE)*0.3},
    })
    q = JoinQuery((
        Atom.of("ContactProb", "pool", "age1", "age2", "prob"),
        Atom.of("Person", "per1", "age1", "pool", alias="P1"),
        Atom.of("Person", "per2", "age2", "pool", alias="P2"),
    ), prob_var="prob")

    assert len(jax.devices()) == 4, jax.devices()
    mesh = jax.make_mesh((4,), ("data",))
    ds = ShardedPoissonSampler(db, q, mesh, axes=("data",))
    engine = QueryEngine(db)
    ref = engine.compile(q)
    exp = ref.expected_k()
    totals = [int(ds.sample_step(jax.random.key(i))[1]) for i in range(30)]
    sd = float(estimate.sample_std(ref.w, ref.p))
    z = (np.mean(totals)-exp)/(sd/30**0.5)
    assert abs(z) < 4.5, (np.mean(totals), exp, z)

    smp, _ = ds.sample_step(jax.random.key(99))
    full = engine.full_join(q)
    fullset = set(zip(*[np.asarray(full[k]) for k in ("per1","per2","pool")]))
    cnt = np.asarray(smp.count)
    for sh in range(4):
        c = int(cnt[sh])
        tup = list(zip(np.asarray(smp.columns['per1'][sh])[:c],
                       np.asarray(smp.columns['per2'][sh])[:c],
                       np.asarray(smp.columns['pool'][sh])[:c]))
        assert all(t in fullset for t in tup), sh
    print("DISTRIBUTED_OK")
""")


@pytest.mark.slow
def test_sharded_sampler_multidevice():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert "DISTRIBUTED_OK" in r.stdout
