"""Test-wide config.

x64 is enabled for the whole test process: repro.core requires it (int64
join offsets) and enables it on import anyway; forcing it here makes test
ordering irrelevant. Model code is dtype-explicit and unaffected.

NOTE: XLA_FLAGS --xla_force_host_platform_device_count is deliberately NOT
set here — smoke tests and benches must see the real single device; only
launch/dryrun.py (and explicit subprocess tests) force 512/4 devices.
"""
import jax

jax.config.update("jax_enable_x64", True)
