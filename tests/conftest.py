"""Test-wide config.

x64 is enabled for the whole test process: repro.core requires it (int64
join offsets) and enables it on import anyway; forcing it here makes test
ordering irrelevant. Model code is dtype-explicit and unaffected.

NOTE: XLA_FLAGS --xla_force_host_platform_device_count is deliberately NOT
set here — smoke tests and benches must see the real device count. The CI
test matrix has an 8-virtual-device leg that sets it process-wide so the
shard_map paths (sharded engine, pipeline, distributed core) run on a real
multi-device mesh; subprocess tests pin their own counts either way, and
launch/dryrun.py forces 512.
"""
import jax

jax.config.update("jax_enable_x64", True)
