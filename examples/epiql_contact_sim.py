"""EpiQL-style epidemic simulation (the paper's motivating application,
Example 1.1): a discrete SIR model where each timestep's contact events are
an independent Poisson sample of

    Q_c = beta_prob( Person(per1,age1,pool) |><| Person(per2,age2,pool)
                     |><| ContactProb(pool,age1,age2,prob) )

The contact join (~|pools| x pool_size^2 tuples) is NEVER materialized: the
index is built once and each simulation step probes it — the Monte-Carlo
amortization the paper measures on 1.1e7 Belgians (1.3e10 join tuples,
sample ~1e8).

    PYTHONPATH=src python examples/epiql_contact_sim.py [--pop 3000] [--days 20]
"""
import argparse

import jax
import numpy as np

from repro.core import Atom, Database, JoinQuery
from repro.engine import QueryEngine


def build_population(pop: int, pools: int, ages: int, seed: int):
    rng = np.random.default_rng(seed)
    grid = [(g, a1, a2) for g in range(pools) for a1 in range(ages)
            for a2 in range(ages)]
    # diary-study-like contact probabilities, mean ~2.4% (paper §6.2)
    probs = np.clip(rng.gamma(2.0, 0.012, len(grid)), 0, 1)
    db = Database.from_columns({
        "Person": {"pers": np.arange(pop), "age": rng.integers(0, ages, pop),
                   "pool": rng.integers(0, pools, pop)},
        "ContactProb": {"pool": [g for g, _, _ in grid],
                        "age1": [a for _, a, _ in grid],
                        "age2": [a for _, _, a in grid],
                        "prob": probs},
    })
    q = JoinQuery((
        Atom.of("ContactProb", "pool", "age1", "age2", "prob"),
        Atom.of("Person", "per1", "age1", "pool", alias="P1"),
        Atom.of("Person", "per2", "age2", "pool", alias="P2"),
    ), prob_var="prob")
    return db, q


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pop", type=int, default=3000)
    ap.add_argument("--pools", type=int, default=75)
    ap.add_argument("--ages", type=int, default=6)
    ap.add_argument("--days", type=int, default=20)
    ap.add_argument("--seeds", type=int, default=5, help="initially infected")
    ap.add_argument("--p-transmit", type=float, default=0.35)
    ap.add_argument("--days-infectious", type=int, default=4)
    args = ap.parse_args()

    db, q = build_population(args.pop, args.pools, args.ages, seed=0)
    sampler = QueryEngine(db).compile(q)  # index built once, probed daily
    print(f"population={args.pop}  contact-join size={sampler.join_size:,} "
          f"(never materialized)  E[contacts/day]={sampler.expected_k():.0f}")

    rng = np.random.default_rng(1)
    # disease state: 0=S, >0 = infectious days remaining, -1 = recovered
    state = np.zeros(args.pop, np.int32)
    state[rng.choice(args.pop, args.seeds, replace=False)] = args.days_infectious

    key = jax.random.key(42)
    history = []
    for day in range(args.days):
        kday = jax.random.fold_in(key, day)
        contacts = sampler.sample(kday)          # fresh Poisson draw, O(k log n)
        k = int(contacts.count)
        p1 = np.asarray(contacts.columns["per1"])[:k]
        p2 = np.asarray(contacts.columns["per2"])[:k]
        # transmission: S meets I
        inf1 = state[p1] > 0
        inf2 = state[p2] > 0
        sus1 = state[p1] == 0
        sus2 = state[p2] == 0
        coin = rng.random(k) < args.p_transmit
        newly = np.unique(np.concatenate([
            p2[inf1 & sus2 & coin], p1[inf2 & sus1 & coin]])).astype(np.int64)
        # progress disease clocks: I ticks down; expiring -> recovered (-1)
        ticking = state > 0
        state[ticking] -= 1
        state[ticking & (state == 0)] = -1
        newly = newly[state[newly] == 0]  # only susceptibles get infected
        state[newly] = args.days_infectious
        s = int((state == 0).sum())
        i = int((state > 0).sum())
        r = int((state < 0).sum())
        history.append((day, k, len(newly)))
        print(f"day {day:3d}: contacts={k:6d} new_infections={len(newly):5d} "
              f"S={s:5d} I={i:5d} R={r:5d}")
    print(f"attack rate: {(args.pop - int((state == 0).sum())) / args.pop:.1%}")


if __name__ == "__main__":
    main()
