"""End-to-end training driver: an LM trained on batches drawn by Poisson
sampling over a joined corpus (quality-weighted data selection — the paper's
technique as a first-class data-pipeline feature, DESIGN.md §2).

Default: the reduced smollm-family config, a few hundred steps on CPU with
checkpoint/resume and the straggler watchdog active.

    PYTHONPATH=src python examples/train_lm_joinsampled.py --steps 300

Full 135M run (same code path, sized for real hardware):
    PYTHONPATH=src python examples/train_lm_joinsampled.py --full --steps 300
"""
import argparse

from repro.launch.train import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--full", action="store_true",
                    help="train the full smollm-135m (sized for TPU; slow on CPU)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_joinsampled_ckpt")
    args = ap.parse_args()

    tc = TrainConfig(
        arch="smollm_135m",
        reduced=not args.full,
        steps=args.steps,
        batch=args.batch,
        seq_len=args.seq_len,
        data="poisson_join",
        ckpt_dir=args.ckpt_dir,
        ckpt_every=100,
    )
    out = train(tc)
    first, last = out["losses"][0], out["losses"][-1]
    print(f"\ntrained {args.steps} steps on Poisson-join-sampled batches")
    print(f"loss: {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    print(f"straggler events observed: {len(out['straggler_events'])}")
    assert last < first, "training did not reduce loss"


if __name__ == "__main__":
    main()
