"""End-to-end training on an engine-native, *live* Poisson-join corpus.

An LM trains on batches drawn by Poisson sampling over a joined corpus
(quality-weighted data selection — the paper's technique as a first-class
data-pipeline feature, DESIGN.md §2/§13), while the corpus itself moves
mid-run: a scheduled ``DeltaBatch`` inserts and retires documents at a
step-aligned version barrier through ``engine.apply_delta``.

Run as an integration test (the default), this script executes the full
determinism contract:

  1. run A trains ``--steps`` straight through, with a corpus delta at
     ``--delta-step``;
  2. run B trains the same config but is "killed" after ``--kill-at``
     steps, then restarted — resume replays the delta schedule from the
     base snapshot and the checkpoint's recorded ``data_version`` is
     verified against it;
  3. losses AND sampled doc ids of the resumed run must be bit-identical
     to run A's, and the per-step ``db_version`` trace must flip exactly
     at the barrier.

    PYTHONPATH=src python examples/train_lm_joinsampled.py

Plain training (no kill/resume verification; sized for real hardware with
``--full``):

    PYTHONPATH=src python examples/train_lm_joinsampled.py --train-only --steps 300
"""
import argparse
import dataclasses
import tempfile
from pathlib import Path

import numpy as np

from repro import configs
from repro.data import corpus_delta, make_corpus_db
from repro.launch.train import TrainConfig, train


def _delta_schedule(tc: TrainConfig, delta_step: int):
    """The live-corpus event: built against the *same* deterministic base
    snapshot ``train()`` constructs, so a restarted process re-derives the
    identical schedule from the config alone."""
    cfg = configs.get_config(tc.arch)
    if tc.reduced:
        cfg = configs.reduced(cfg)
    db = make_corpus_db(n_docs=512, n_clusters=16, seq_len=tc.seq_len + 1,
                        vocab=cfg.vocab, seed=tc.seed)
    delta = corpus_delta(db, tc.seq_len + 1, cfg.vocab,
                         insert=64, retire=range(8), seed=tc.seed + 1)
    return ((delta_step, delta),)


def run_integration(steps: int, kill_at: int, delta_step: int,
                    batch: int, seq_len: int, workdir: Path) -> None:
    base = TrainConfig(arch="smollm_135m", steps=steps, batch=batch,
                       seq_len=seq_len, data="poisson_join",
                       ckpt_every=kill_at, log_every=1000)
    deltas = _delta_schedule(base, delta_step)

    print(f"[integration] run A: {steps} steps, delta at {delta_step}")
    a = train(dataclasses.replace(base, deltas=deltas,
                                  ckpt_dir=str(workdir / "a")))

    print(f"[integration] run B: kill after step {kill_at}, then resume")
    train(dataclasses.replace(base, deltas=deltas, steps=kill_at,
                              ckpt_dir=str(workdir / "b")))
    b = train(dataclasses.replace(base, deltas=deltas,
                                  ckpt_dir=str(workdir / "b")))

    # -- the contract ------------------------------------------------------
    assert a["data_versions"] == [0] * delta_step + [1] * (steps - delta_step), \
        f"version trace must flip exactly at the barrier: {a['data_versions']}"
    assert b["data_versions"] == a["data_versions"][kill_at:], \
        "resumed run must replay the same version trace"
    tail = a["losses"][kill_at:]
    if not np.array_equal(np.asarray(tail), np.asarray(b["losses"])):
        raise AssertionError(
            f"resumed losses are not bit-identical: {tail} vs {b['losses']}")
    for i, (da, db_) in enumerate(zip(a["doc_ids"][kill_at:], b["doc_ids"])):
        if not np.array_equal(da, db_):
            raise AssertionError(
                f"sampled doc ids diverge at resumed step {kill_at + i}")
    print(f"[integration] OK: {steps - kill_at} resumed steps bit-identical "
          f"(losses + doc ids), version barrier at step {delta_step}")
    print(f"loss: {a['losses'][0]:.4f} -> {a['losses'][-1]:.4f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--kill-at", type=int, default=12)
    ap.add_argument("--delta-step", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--train-only", action="store_true",
                    help="plain training run, no kill/resume verification")
    ap.add_argument("--full", action="store_true",
                    help="train the full smollm-135m (sized for TPU; slow on CPU)")
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    if not args.train_only:
        workdir = Path(args.ckpt_dir or tempfile.mkdtemp(prefix="joinsampled_"))
        run_integration(args.steps, args.kill_at, args.delta_step,
                        args.batch, args.seq_len, workdir)
        return

    tc = TrainConfig(
        arch="smollm_135m",
        reduced=not args.full,
        steps=args.steps,
        batch=args.batch,
        seq_len=args.seq_len,
        data="poisson_join",
        ckpt_dir=args.ckpt_dir or "/tmp/repro_joinsampled_ckpt",
        ckpt_every=100,
    )
    out = train(tc)
    first, last = out["losses"][0], out["losses"][-1]
    print(f"\ntrained {args.steps} steps on Poisson-join-sampled batches")
    print(f"loss: {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    print(f"straggler events observed: {len(out['straggler_events'])}")
    assert last < first, "training did not reduce loss"


if __name__ == "__main__":
    main()
