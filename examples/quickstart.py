"""Quickstart: Poisson sampling over an acyclic join in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import Atom, Database, JoinQuery, PoissonSampler, yannakakis

# A tiny movie database: every (title, actor, company) combination of a title
# is a join tuple; each title carries its own sampling probability p.
db = Database.from_columns({
    "Title": {"t": [0, 1, 2, 3], "p": [0.9, 0.5, 0.1, 0.7]},
    "Cast": {"t": [0, 0, 1, 1, 1, 2, 3], "actor": [10, 11, 12, 13, 14, 15, 16]},
    "Comp": {"t": [0, 1, 1, 2, 3, 3], "comp": [100, 101, 102, 103, 104, 105]},
})
query = JoinQuery(
    (Atom.of("Title", "t", "p"), Atom.of("Cast", "t", "actor"),
     Atom.of("Comp", "t", "comp")),
    prob_var="p",
)

# Index once (O(|db|)) ...
sampler = PoissonSampler(db, query)
print(f"full join size |Q(db)| = {sampler.join_size} "
      f"(never materialized), expected sample size = {sampler.expected_k():.1f}")

# ... then draw independent Poisson samples per step (O(k log |db|) each).
for step in range(3):
    s = sampler.sample(jax.random.key(step))
    k = int(s.count)
    rows = list(zip(*(np.asarray(s.columns[c])[:k] for c in ("t", "actor", "comp", "p"))))
    print(f"step {step}: k={k} sample={rows}")

# The same index computes the full join (Yannakakis "without regret"):
full = yannakakis.flatten(sampler.shred)
print("full join tuples:", len(next(iter(full.values()))))
