"""Quickstart: one engine, one index — full joins AND Poisson samples.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import Atom, Database, JoinQuery
from repro.engine import QueryEngine

# A tiny movie database: every (title, actor, company) combination of a title
# is a join tuple; each title carries its own sampling probability p.
db = Database.from_columns({
    "Title": {"t": [0, 1, 2, 3], "p": [0.9, 0.5, 0.1, 0.7]},
    "Cast": {"t": [0, 0, 1, 1, 1, 2, 3], "actor": [10, 11, 12, 13, 14, 15, 16]},
    "Comp": {"t": [0, 1, 1, 2, 3, 3], "comp": [100, 101, 102, 103, 104, 105]},
})
query = JoinQuery(
    (Atom.of("Title", "t", "p"), Atom.of("Cast", "t", "actor"),
     Atom.of("Comp", "t", "comp")),
    prob_var="p",
)

# One engine binds the database; the first call on a query plans (GYO),
# builds the shred index, and jit-compiles the executors — everything after
# that is served from the compiled-plan cache.
engine = QueryEngine(db)
print(f"full join size |Q(db)| = {engine.join_size(query)} (never materialized)")

# Independent Poisson samples per step (O(k log |db|) each, warm-cache).
for step in range(3):
    s = engine.poisson_sample(query, jax.random.key(step))
    k = int(s.count)
    rows = list(zip(*(np.asarray(s.columns[c])[:k] for c in ("t", "actor", "comp", "p"))))
    print(f"step {step}: k={k} sample={rows}")

# The same cached index computes the full join (Yannakakis "without regret"):
full = engine.full_join(query)
print("full join tuples:", len(next(iter(full.values()))))
print(engine.explain(query))
