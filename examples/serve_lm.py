"""Batched serving example: prefill + lockstep decode over a request batch
(the serve_step the dry-run lowers at decode_32k / long_500k scale).

    PYTHONPATH=src python examples/serve_lm.py --arch zamba2_1p2b
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main()
